"""§Roofline — derive the three roofline terms per (arch x shape x mesh)
from the dry-run artifacts (benchmark for the multi-pod deliverable).

  compute_s    = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory_s     = HLO_bytes / (chips * 819 GB/s)
  collective_s = collective_bytes / (chips * 50 GB/s/link)

cost_analysis() on the partitioned module reports PER-DEVICE numbers, so
chips=1 in the denominators here; collective bytes are parsed from the
post-SPMD HLO (per-device shapes) in repro.launch.hlo_stats.

MODEL_FLOPS uses the 6*N_active*D (train) / 2*N_active*D (inference)
convention with N_active excluding embedding/unembedding tables (their
compute is a gather + one matmul already inside HLO_FLOPs); the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, MoE dispatch einsums and
attention FLOPs not counted by the 6ND convention.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "dryrun", "dryrun.json")

_N_CACHE: Dict[str, Dict[str, float]] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    """Exact param count of our implementation (eval_shape) + active."""
    if arch in _N_CACHE:
        return _N_CACHE[arch]
    from repro.launch.specs import params_shape
    cfg = get_config(arch)
    p = params_shape(cfg)
    total = sum(x.size for x in jax.tree.leaves(p))
    emb = p["embed"]["table"].size
    head = p["lm_head"]["w"].size if "lm_head" in p else 0
    n_flops = total - emb - head          # params that do matmul work
    # MoE: only top_k of the routed experts are active per token
    inactive = 0.0
    if cfg.moe_experts:
        u = cfg.pattern_unit()
        n_moe_layers = cfg.n_units          # one MoE layer per unit
        e_tree = jax.tree.leaves(
            jax.tree.map(lambda x: x.size,
                         p["units"][f"sub{u-1}" if u > 1 else "sub0"]
                         ["ffn"]["experts"]))
        per_layer_expert_params = sum(e_tree) / cfg.n_units
        e_pad = max(cfg.moe_experts, cfg.moe_pad_to or 0)
        inactive = (n_moe_layers * per_layer_expert_params
                    * (e_pad - cfg.moe_top_k) / e_pad)
    _N_CACHE[arch] = {"total": float(total),
                      "active_flops": float(n_flops - inactive)}
    return _N_CACHE[arch]


def model_flops(arch: str, shape_name: str) -> float:
    shape = INPUT_SHAPES[shape_name]
    n = _param_counts(arch)["active_flops"]
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def _advice(dominant: str, rec: Dict) -> str:
    if dominant == "collective_s":
        coll = rec["collective_bytes_per_device"]
        worst = max((k for k in coll if k != "total"),
                    key=lambda k: coll[k])
        return (f"cut {worst} traffic (resharding/axis choice, "
                f"overlap with compute)")
    if dominant == "memory_s":
        return ("raise arithmetic intensity: fuse elementwise chains, "
                "larger per-step tiles, fewer remat recomputes")
    return "already MXU-bound: reduce non-model FLOPs (remat, dispatch)"


def run(verbose: bool = True, mesh: Optional[str] = None) -> List[Dict]:
    with open(DRYRUN_JSON) as f:
        records = json.load(f)
    rows = []
    for rec in records:
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_total = rec["hlo_flops_per_device"] * rec["n_devices"]
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "advice": _advice(r["dominant"], rec),
        })
    rows.sort(key=lambda x: (x["mesh"], x["arch"], x["shape"]))
    if verbose:
        hdr = (f"{'arch':<26} {'shape':<12} {'mesh':<8} {'compute_s':>10} "
               f"{'memory_s':>10} {'collect_s':>10} {'dominant':>12} "
               f"{'useful':>7}")
        print(hdr)
        for x in rows:
            print(f"{x['arch']:<26} {x['shape']:<12} {x['mesh']:<8} "
                  f"{x['compute_s']:>10.2e} {x['memory_s']:>10.2e} "
                  f"{x['collective_s']:>10.2e} "
                  f"{x['dominant'].replace('_s',''):>12} "
                  f"{x['useful_ratio']:>7.2f}")
    from .common import save_json
    save_json("roofline.json", rows)
    return rows


if __name__ == "__main__":
    run()
