"""Table II — the physical-cluster experiment (16 GPUs, 30 jobs):
makespan and average JCT per policy.

Two modes:

* paper mode (default) — the calibrated simulator over the 2080 Ti
  hardware model and the synthesized paper-task profiles (DESIGN.md §8);
  the expected ordering is the paper's: sharing policies (SJF-FFS,
  SJF-BSBF) beat exclusive ones, SJF-BSBF beats SJF-FFS.

* calibrated mode (``--calibrated [PATH]``) — the closed loop of
  DESIGN.md §13: job performance comes from a HOST-MEASURED calibration
  artifact (fitted Eq.-3 alpha/beta per arch via the schedule executor,
  measured pairwise xi on fused pair programs) instead of the
  synthesized tables; ``InterferenceModel.from_artifact`` replaces
  ``paper_interference_model`` on this path, and the artifact's fitted
  coefficients are embedded in the benchmark payload."""
from __future__ import annotations

import argparse
import os

from repro.core import InterferenceModel, calibrated_trace, physical_trace
from repro.core.calibration import load_artifact

from .common import ARTIFACTS, run_all_policies, save_json, summaries, table

DEFAULT_CALIBRATION = os.path.join(ARTIFACTS, "calibration.json")


def _calibrated_capacity(payload) -> float:
    """Capacity admitting every measured arch at full batch with head-
    room for one half-batch co-tenant — the same C=2 sharing regime the
    paper's 11 GB cards give its tasks."""
    needs = [e["mem_base"] + e["mem_per_sample"] * e["batch"]
             for e in payload["archs"].values()]
    halves = [e["mem_per_sample"] * max(1, e["batch"] // 2)
              + e["mem_base"] for e in payload["archs"].values()]
    return max(needs) + max(halves) + 0.25 * max(
        e["mem_per_sample"] for e in payload["archs"].values())


def run(seed: int = 0, verbose: bool = True, calibrated: str | None = None):
    jobs = physical_trace(seed=seed)
    results = run_all_policies(jobs, n_servers=4, gpus_per_server=4)
    if verbose:
        print(table(results, "Table II (physical 16-GPU cluster, 30 jobs)"))
    payload = summaries(results)
    # the paper's headline checks
    s = payload
    ok_sharing = s["sjf-bsbf"]["avg_jct"] < s["sjf"]["avg_jct"]
    ok_wise = s["sjf-bsbf"]["avg_jct"] <= s["sjf-ffs"]["avg_jct"] * 1.05
    if verbose:
        print(f"  sharing beats exclusive: {ok_sharing}; "
              f"BSBF <= FFS(+5%): {ok_wise}")

    if calibrated:
        cal = load_artifact(calibrated)
        cjobs = calibrated_trace(cal, n_jobs=30, seed=seed, load=6.0)
        # a 4-GPU host-scale cluster: the measured jobs are small, so
        # contention (and the sharing policies' edge) needs a small box
        cresults = run_all_policies(
            cjobs, n_servers=2, gpus_per_server=2,
            interference=InterferenceModel.from_artifact(cal),
            capacity_gb=_calibrated_capacity(cal) / 2 ** 30)
        if verbose:
            print(table(cresults, "Table II (host-calibrated profiles, "
                                  "30 jobs, 4 GPUs)"))
        payload = {
            "paper": payload,
            "calibrated": summaries(cresults),
            "calibration": {
                "artifact": calibrated,
                "archs": {n: {k: e[k] for k in ("alpha_comp", "beta_comp",
                                                "t_iter_solo")}
                          for n, e in cal["archs"].items()},
                "pairs": {k: {kk: e[kk] for kk in ("xi_a", "xi_b")}
                          for k, e in cal["pairs"].items()},
            },
        }
    save_json("table2_physical.json", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calibrated", nargs="?", const=DEFAULT_CALIBRATION,
                    default=None, metavar="PATH",
                    help="also run the trace over host-measured profiles "
                         "from a calibration artifact (default: "
                         f"{DEFAULT_CALIBRATION})")
    args = ap.parse_args(argv)
    run(seed=args.seed, calibrated=args.calibrated)


if __name__ == "__main__":
    main()
