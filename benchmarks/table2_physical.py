"""Table II — the physical-cluster experiment (16 GPUs, 30 jobs):
makespan and average JCT per policy. Our 'physical' cluster is the
calibrated simulator over the 2080 Ti hardware model (DESIGN.md §8);
the expected ordering is the paper's: sharing policies (SJF-FFS,
SJF-BSBF) beat exclusive ones, SJF-BSBF beats SJF-FFS."""
from __future__ import annotations

from repro.core import physical_trace

from .common import run_all_policies, save_json, summaries, table


def run(seed: int = 0, verbose: bool = True):
    jobs = physical_trace(seed=seed)
    results = run_all_policies(jobs, n_servers=4, gpus_per_server=4)
    if verbose:
        print(table(results, "Table II (physical 16-GPU cluster, 30 jobs)"))
    payload = summaries(results)
    save_json("table2_physical.json", payload)
    # the paper's headline checks
    s = payload
    ok_sharing = s["sjf-bsbf"]["avg_jct"] < s["sjf"]["avg_jct"]
    ok_wise = s["sjf-bsbf"]["avg_jct"] <= s["sjf-ffs"]["avg_jct"] * 1.05
    if verbose:
        print(f"  sharing beats exclusive: {ok_sharing}; "
              f"BSBF <= FFS(+5%): {ok_wise}")
    return payload


if __name__ == "__main__":
    run()
