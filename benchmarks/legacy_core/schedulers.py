"""Scheduling policies of Section VI-A.

* FIFO           — strict arrival order, exclusive GPUs, head-of-line blocks.
* SJF            — shortest-remaining-solo-time first, exclusive GPUs.
* Tiresias       — preemptive discretized-2Q LAS (attained service =
                   gpus x seconds), restart penalty on resume.
* PolluxLike     — preemptive elastic baseline: periodic marginal-gain GPU
                   reallocation on each job's speedup curve (user batch kept
                   fixed; see DESIGN.md §8).
* SJF-FFS        — SJF + aggressive first-fit GPU sharing (no benefit check).
* SJF-BSBF       — the paper's Algorithm 1 (+ Algorithm 2 / Theorem 1).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .batch_scaling import best_sharing_config, candidate_sub_batches
from .job import ClusterState, Job, JobState
from .simulator import SchedulerBase, Simulator


# ---------------------------------------------------------------------- #
# helpers
# ---------------------------------------------------------------------- #
def solo_sub_batch(job: Job, capacity: float) -> Optional[int]:
    """Largest power-of-two sub-batch that fits device memory alone
    (gradient accumulation supplies the rest)."""
    for b in candidate_sub_batches(job.batch):
        if job.perf.fits(b, capacity):
            return b
    return None


def shared_sub_batch(job: Job, capacity: float, other_mem: float) -> Optional[int]:
    for b in candidate_sub_batches(job.batch):
        if job.perf.fits(b, capacity, other_mem=other_mem):
            return b
    return None


def _start_exclusive(sim: Simulator, job: Job) -> bool:
    free = sim.cluster.free_gpus()
    want = job.alloc_gpus or job.gpus
    if len(free) < want:
        return False
    sub = solo_sub_batch(job, sim.cluster.gpu_capacity_bytes)
    if sub is None:
        raise RuntimeError(f"job {job.jid} cannot fit memory even at b=1")
    gpus = sim.cluster.consolidated_pick(free, want)
    sim.start_job(job, gpus, sub_batch=sub)
    return True


# ---------------------------------------------------------------------- #
class FIFO(SchedulerBase):
    name = "fifo"

    def schedule(self, sim: Simulator) -> None:
        for job in sorted(sim.pending, key=lambda j: (j.arrival, j.jid)):
            if not _start_exclusive(sim, job):
                break  # strict FIFO: head-of-line blocks the queue


class SJF(SchedulerBase):
    """Shortest-job-first, exclusive GPUs, strict priority order: if the
    currently-shortest job cannot be placed, later jobs wait (no backfill —
    matching the queueing structure the paper reports for SJF)."""

    name = "sjf"

    def schedule(self, sim: Simulator) -> None:
        order = sorted(sim.pending,
                       key=lambda j: (j.expected_remaining_time, j.jid))
        for job in order:
            if not _start_exclusive(sim, job):
                break


# ---------------------------------------------------------------------- #
class Tiresias(SchedulerBase):
    """Discretized two-queue least-attained-service, preemptive."""

    name = "tiresias"
    preemptive = True

    def __init__(self, threshold_gpu_seconds: float = 3600.0,
                 tick_interval: float = 60.0) -> None:
        self.threshold = threshold_gpu_seconds
        self.tick_interval = tick_interval

    def schedule(self, sim: Simulator) -> None:
        active: List[Job] = list(sim.running.values()) + list(sim.pending)
        if not active:
            return
        queue = lambda j: 0 if j.attained_service < self.threshold else 1
        order = sorted(active, key=lambda j: (queue(j), j.arrival, j.jid))
        total = sim.cluster.n_gpus
        chosen: List[Job] = []
        cap = total
        for j in order:
            if j.gpus <= cap:
                chosen.append(j)
                cap -= j.gpus
        chosen_ids = {j.jid for j in chosen}
        for j in list(sim.running.values()):
            if j.jid not in chosen_ids:
                sim.preempt_job(j)
        for j in chosen:
            if j.state == JobState.PENDING:
                _start_exclusive(sim, j)


# ---------------------------------------------------------------------- #
class SRSF(SchedulerBase):
    """Clairvoyant shortest-remaining-service-first (the policy Tiresias
    approximates without duration knowledge; Tiresias paper shows SRSF is
    near-optimal when durations are known). Preemptive: whenever a job
    with smaller remaining service (gpus x remaining seconds) arrives, it
    may evict enough larger jobs to run."""

    name = "srsf"
    preemptive = True

    def schedule(self, sim: Simulator) -> None:
        active: List[Job] = list(sim.running.values()) + list(sim.pending)
        if not active:
            return
        service = lambda j: j.gpus * j.expected_remaining_time
        order = sorted(active, key=lambda j: (service(j), j.jid))
        cap = sim.cluster.n_gpus
        chosen: List[Job] = []
        for j in order:
            if j.gpus <= cap:
                chosen.append(j)
                cap -= j.gpus
        chosen_ids = {j.jid for j in chosen}
        for j in list(sim.running.values()):
            if j.jid not in chosen_ids:
                sim.preempt_job(j)
        for j in chosen:
            if j.state == JobState.PENDING:
                _start_exclusive(sim, j)


# ---------------------------------------------------------------------- #
class PolluxLike(SchedulerBase):
    """Elastic preemptive baseline: every tick, reassign GPU counts by
    greedy marginal goodput gain, capped at each job's requested G_k
    (the real Pollux can also overshoot and retune batch size; we keep the
    user batch to mirror the accuracy-preserving comparison in the paper)."""

    name = "pollux"
    preemptive = True
    tick_only = True   # real Pollux acts on a fixed optimization interval

    def __init__(self, tick_interval: float = 60.0,
                 min_gpus: int = 1) -> None:
        self.tick_interval = tick_interval
        self.min_gpus = min_gpus

    @staticmethod
    def _rate(job: Job, n: int) -> float:
        """User-iterations/sec at allocation n (weak scaling)."""
        if n <= 0:
            return 0.0
        p = job.perf
        sub = job.batch / job.accum_steps
        tc = p.t_comp(sub)
        tn = (p.alpha_comm * max(1, math.ceil(math.log2(max(2, n))))
              + p.beta_comm * 2.0 * p.param_bytes * (n - 1) / n)
        d = p.delta
        t_phys = (job.accum_steps - 1) * tc + (tc ** d + tn ** d) ** (1 / d)
        return (n / job.gpus) / t_phys

    def schedule(self, sim: Simulator) -> None:
        active: List[Job] = list(sim.running.values()) + list(sim.pending)
        if not active:
            return
        total = sim.cluster.n_gpus
        # Fair-share allocation in powers of two up to G_k (Pollux optimizes
        # goodput *subject to fairness*; fair shares, then goodput-aware
        # upgrades for whoever is furthest below its request).
        alloc: Dict[int, int] = {j.jid: 0 for j in active}
        levels = lambda j: [n for n in (1, 2, 4, 8, 12, 16, 24, 32)
                            if n <= j.gpus] or [j.gpus]
        budget = total
        order = sorted(active, key=lambda j: (j.arrival, j.jid))
        for j in order:
            first = levels(j)[0]
            if budget >= first:
                alloc[j.jid] = first
                budget -= first
        upgraded = True
        while upgraded and budget > 0:
            upgraded = False
            # furthest below fair share first; break ties by marginal rate
            cands = []
            for j in active:
                cur = alloc[j.jid]
                if cur == 0:
                    continue
                nxt = next((n for n in levels(j) if n > cur), None)
                if nxt is None or nxt - cur > budget:
                    continue
                gain = (self._rate(j, nxt) - self._rate(j, cur)) / (nxt - cur)
                cands.append((cur / j.gpus, -gain, j.jid, j, nxt))
            if cands:
                cands.sort()
                _, _, _, j, nxt = cands[0]
                budget -= nxt - alloc[j.jid]
                alloc[j.jid] = nxt
                upgraded = True

        # Apply: preempt mismatched running jobs, then start.
        for j in list(sim.running.values()):
            if alloc.get(j.jid, 0) != (j.alloc_gpus or j.gpus):
                sim.preempt_job(j)
        for j in sorted(sim.pending, key=lambda x: (x.arrival, x.jid)):
            n = alloc.get(j.jid, 0)
            if n <= 0:
                continue
            free = sim.cluster.free_gpus()
            if len(free) < n:
                continue
            j.alloc_gpus = n
            sub = solo_sub_batch(j, sim.cluster.gpu_capacity_bytes)
            gpus = sim.cluster.consolidated_pick(free, n)
            sim.start_job(j, gpus, sub_batch=sub)


# ---------------------------------------------------------------------- #
class SJF_FFS(SchedulerBase):
    """SJF + first-fit sharing: when free GPUs are insufficient, greedily
    take single-occupancy GPUs (no Theorem-1 benefit check) — the paper's
    comparison baseline showing that *wise* sharing matters."""

    name = "sjf-ffs"

    def schedule(self, sim: Simulator) -> None:
        cap = sim.cluster.gpu_capacity_bytes
        order = sorted(sim.pending,
                       key=lambda j: (j.expected_remaining_time, j.jid))
        for job in order:
            if _start_exclusive(sim, job):
                continue
            free = sim.cluster.free_gpus()
            singles = sim.cluster.single_occupancy_gpus()
            if len(free) + len(singles) < job.gpus:
                continue
            # first fit: free GPUs first, then single-occupancy in id order
            chosen = list(free)
            max_other_mem = 0.0
            for g in singles:
                if len(chosen) >= job.gpus:
                    break
                other = sim.jobs[sim.cluster.occupancy[g][0]]
                max_other_mem = max(
                    max_other_mem, other.perf.mem_bytes(other.sub_batch))
                chosen.append(g)
            if len(chosen) < job.gpus:
                continue
            chosen = chosen[:job.gpus]
            sub = shared_sub_batch(job, cap, max_other_mem)
            if sub is None:
                continue  # does not fit next to the co-runners
            sim.start_job(job, chosen, sub_batch=sub)


# ---------------------------------------------------------------------- #
class SJF_BSBF(SchedulerBase):
    """Algorithm 1 — Shortest Job First with Best Sharing Benefit First."""

    name = "sjf-bsbf"

    def schedule(self, sim: Simulator) -> None:
        cap = sim.cluster.gpu_capacity_bytes
        order = sorted(sim.pending,
                       key=lambda j: (j.expected_remaining_time, j.jid))
        for job in order:
            # Lines 6-8: enough free GPUs -> exclusive consolidated pick.
            if _start_exclusive(sim, job):
                continue
            free = sim.cluster.free_gpus()
            singles = sim.cluster.single_occupancy_gpus()
            if len(free) + len(singles) < job.gpus:
                continue  # Line 9 fails: stay pending
            # Lines 10-13: evaluate every running job owning single-occupancy
            # GPUs with Algorithm 2; keep those with sharing benefit.
            donor_jids = {sim.cluster.occupancy[g][0] for g in singles}
            donors = []
            for jid in donor_jids:
                run = sim.jobs[jid]
                cfg = best_sharing_config(run, job, sim.interference, cap)
                if cfg.share:
                    donors.append((cfg, run))
            if not donors:
                continue  # SF False for all pairs: defer (put back in pool)
            # Line 14: sort candidate pairs by pair-JCT ascending.
            donors.sort(key=lambda t: (t[0].avg_jct, t[1].jid))
            # Lines 15-17: take donors' GPUs until the request is met
            # (shared GPUs first — they pace the job — then free ones).
            chosen: List[int] = []
            sub = job.batch
            for cfg, run in donors:
                if len(chosen) >= job.gpus:
                    break
                for g in sorted(run.placement):
                    if len(sim.cluster.occupancy[g]) == 1:
                        chosen.append(g)
                        if len(chosen) >= job.gpus:
                            break
                sub = min(sub, cfg.sub_batch)
            if len(chosen) < job.gpus:
                chosen.extend(free[: job.gpus - len(chosen)])
            if len(chosen) < job.gpus:
                continue
            chosen = chosen[:job.gpus]
            sim.start_job(job, chosen, sub_batch=sub)


ALL_POLICIES = {
    "fifo": FIFO,
    "sjf": SJF,
    "srsf": SRSF,
    "tiresias": Tiresias,
    "pollux": PolluxLike,
    "sjf-ffs": SJF_FFS,
    "sjf-bsbf": SJF_BSBF,
}


def make_scheduler(name: str, **kwargs) -> SchedulerBase:
    try:
        return ALL_POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; "
                         f"choose from {sorted(ALL_POLICIES)}") from None
