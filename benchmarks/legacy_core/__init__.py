"""Frozen copy of the pre-refactor simulator core (seed commit 43415e0).

This package exists ONLY as the "before" side of
``benchmarks/sim_throughput.py``: it preserves the original per-event
``min()``-scan event loop, the full-rescan interference refresh, and the
original (cache-free) scheduler implementations, so before/after
events-per-second numbers compare against what the code actually did
before the event-heap engine landed — not against a baseline that
silently inherits the new caches. Do not import it from ``src/``; do
not "fix" or optimize it. See DESIGN.md §9.
"""
from .interference import paper_interference_model
from .job import ClusterState
from .schedulers import ALL_POLICIES, make_scheduler
from .simulator import SimResults, Simulator
from .trace import simulation_trace

__all__ = [
    "ALL_POLICIES", "ClusterState", "SimResults", "Simulator",
    "make_scheduler", "paper_interference_model", "simulation_trace",
]
