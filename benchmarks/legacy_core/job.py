"""Job and cluster state for the scheduling model (Section IV)."""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .perf_model import PerfParams, ring_allreduce_bytes


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    SUSPENDED = "suspended"   # only preemptive baselines use this
    FINISHED = "finished"


@dataclass
class Job:
    """One DDL training job J_k (Table I notation in comments)."""

    jid: int
    model: str                  # DL task name (indexes the xi table)
    arrival: float              # a_k
    gpus: int                   # G_k
    iters: float                # I_k
    batch: int                  # B_k - user-requested per-GPU batch size
    perf: PerfParams            # Eq. 3/4/7 coefficients at G_k workers

    # --- mutable scheduling state -------------------------------------
    state: JobState = JobState.PENDING
    placement: FrozenSet[int] = frozenset()     # GPU ids
    sub_batch: int = 0          # chosen per-GPU sub-batch (Algorithm 2)
    accum_steps: int = 1        # s = batch / sub_batch
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    first_start_time: Optional[float] = None
    iters_done: float = 0.0
    last_progress_at: float = 0.0
    current_rate: float = 0.0   # iterations / second right now
    preemptions: int = 0
    attained_service: float = 0.0   # gpus * seconds (Tiresias)
    alloc_gpus: Optional[int] = None  # elastic allocation (Pollux-like only)
    waiting_time: float = 0.0       # total time not holding GPUs (queue + preempted)

    def __post_init__(self) -> None:
        if self.sub_batch == 0:
            self.sub_batch = self.batch

    # ------------------------------------------------------------------ #
    @property
    def solo_t_iter(self) -> float:
        return self.perf.t_iter(self.batch, self.accum_steps)

    def base_t_iter(self) -> float:
        """Iteration time in *user iterations* given the current elastic
        allocation (equals ``solo_t_iter`` unless a Pollux-like scheduler
        resized the job). Weak scaling: per-GPU batch fixed, progress
        normalized so that n workers advance n/G_k user iterations per
        physical iteration (same total samples => same convergence)."""
        n = self.alloc_gpus or self.gpus
        if n == self.gpus:
            return self.solo_t_iter
        p = self.perf
        sub = self.batch / self.accum_steps
        tc = p.t_comp(sub)
        tn = (p.alpha_comm * max(1, math.ceil(math.log2(max(2, n))))
              + p.beta_comm * ring_allreduce_bytes(p.param_bytes, n))
        d = p.delta
        t_phys = (self.accum_steps - 1) * tc + (tc ** d + tn ** d) ** (1.0 / d)
        return t_phys * self.gpus / n

    def t_iter_at(self, sub_batch: int) -> float:
        s = max(1, int(round(self.batch / max(1, sub_batch))))
        return self.perf.t_iter(self.batch, s)

    @property
    def remaining_iters(self) -> float:
        return max(0.0, self.iters - self.iters_done)

    @property
    def expected_remaining_time(self) -> float:
        """L_k = t_iter * remaining iterations (solo estimate, used by SJF)."""
        return self.solo_t_iter * self.remaining_iters

    @property
    def service_size(self) -> float:
        """Job 'size' used for the large/small split in Tables III-IV."""
        return self.gpus

    def jct(self) -> float:
        if self.finish_time is None:
            raise RuntimeError(f"job {self.jid} not finished")
        return self.finish_time - self.arrival

    def queueing_delay(self) -> float:
        """Total time spent without GPUs (initial queueing + time spent
        re-queued after preemption) — the paper's 'queuing delay', which
        charges preemptive policies for their migrations."""
        return self.waiting_time

    def first_start_delay(self) -> float:
        if self.first_start_time is None:
            raise RuntimeError(f"job {self.jid} never started")
        return self.first_start_time - self.arrival


@dataclass
class ClusterState:
    """Servers x GPUs with <= C jobs per GPU (C=2 in the paper)."""

    n_servers: int
    gpus_per_server: int
    max_jobs_per_gpu: int = 2
    gpu_capacity_bytes: float = 16 * 2**30

    occupancy: Dict[int, List[int]] = field(default_factory=dict)  # gpu -> [jid]

    def __post_init__(self) -> None:
        for g in range(self.n_gpus):
            self.occupancy.setdefault(g, [])

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.gpus_per_server

    def server_of(self, gpu: int) -> int:
        return gpu // self.gpus_per_server

    # ------------------------------------------------------------------ #
    def free_gpus(self) -> List[int]:
        return [g for g in range(self.n_gpus) if not self.occupancy[g]]

    def single_occupancy_gpus(self) -> List[int]:
        return [g for g in range(self.n_gpus) if len(self.occupancy[g]) == 1]

    def jobs_on(self, gpu: int) -> List[int]:
        return list(self.occupancy[gpu])

    def consolidated_pick(self, candidates: List[int], k: int) -> List[int]:
        """Pick ``k`` GPUs from ``candidates`` packed onto as few servers as
        possible (the paper's 'as consolidated on the nodes as possible')."""
        by_server: Dict[int, List[int]] = {}
        for g in candidates:
            by_server.setdefault(self.server_of(g), []).append(g)
        # Prefer servers with the most candidate GPUs; stable by server id.
        order = sorted(by_server.items(), key=lambda kv: (-len(kv[1]), kv[0]))
        picked: List[int] = []
        for _, gpus in order:
            for g in sorted(gpus):
                picked.append(g)
                if len(picked) == k:
                    return picked
        return picked  # may be < k; caller checks

    def allocate(self, jid: int, gpus: FrozenSet[int]) -> None:
        for g in gpus:
            occ = self.occupancy[g]
            if len(occ) >= self.max_jobs_per_gpu:
                raise RuntimeError(f"GPU {g} already holds {occ}")
            occ.append(jid)

    def release(self, jid: int, gpus: FrozenSet[int]) -> None:
        for g in gpus:
            occ = self.occupancy[g]
            if jid not in occ:
                raise RuntimeError(f"GPU {g} does not hold job {jid}")
            occ.remove(jid)

    def co_runners(self, job: Job) -> Set[int]:
        others: Set[int] = set()
        for g in job.placement:
            for j in self.occupancy[g]:
                if j != job.jid:
                    others.add(j)
        return others
