"""Trace-driven discrete-event simulator for the multi-tenant cluster
(Section VI). Jobs progress in continuous iterations; every event (arrival,
completion, scheduler tick, preemption) re-derives each running job's
effective rate 1 / (t_iter * max xi over co-runners) — gang scheduling means
the slowest (most-contended) GPU paces the whole job."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .interference import InterferenceModel
from .job import ClusterState, Job, JobState

_EPS = 1e-9


@dataclass
class SimResults:
    jobs: List[Job]
    makespan: float
    events: int
    name: str = ""

    # ------------------------------------------------------------------ #
    def _sel(self, large: Optional[bool]) -> List[Job]:
        if large is None:
            return self.jobs
        return [j for j in self.jobs if (j.gpus > 4) == large]

    def avg_jct(self, large: Optional[bool] = None) -> float:
        sel = self._sel(large)
        return sum(j.jct() for j in sel) / len(sel) if sel else 0.0

    def avg_queueing(self, large: Optional[bool] = None) -> float:
        sel = self._sel(large)
        return sum(j.queueing_delay() for j in sel) / len(sel) if sel else 0.0

    def jct_list(self) -> List[float]:
        return sorted(j.jct() for j in self.jobs)

    def summary(self) -> Dict[str, float]:
        return {
            "makespan": self.makespan,
            "avg_jct": self.avg_jct(),
            "avg_jct_large": self.avg_jct(True),
            "avg_jct_small": self.avg_jct(False),
            "avg_queue": self.avg_queueing(),
            "avg_queue_large": self.avg_queueing(True),
            "avg_queue_small": self.avg_queueing(False),
        }


class Simulator:
    def __init__(
        self,
        cluster: ClusterState,
        jobs: Sequence[Job],
        scheduler: "SchedulerBase",
        interference: Optional[InterferenceModel] = None,
        restart_penalty: float = 30.0,
        max_events: int = 2_000_000,
    ) -> None:
        self.cluster = cluster
        self.jobs: Dict[int, Job] = {j.jid: j for j in jobs}
        self.arrivals = sorted(jobs, key=lambda j: (j.arrival, j.jid))
        self.scheduler = scheduler
        self.interference = interference or InterferenceModel()
        self.restart_penalty = restart_penalty
        self.max_events = max_events

        self.time = 0.0
        self.pending: List[Job] = []
        self.running: Dict[int, Job] = {}
        self._arrival_idx = 0
        self._blocked_until: Dict[int, float] = {}
        self._next_tick = (scheduler.tick_interval
                           if scheduler.tick_interval else None)
        self._events = 0
        self.log: List[tuple] = []

    # ------------------------------------------------------------------ #
    # Scheduler-facing API
    # ------------------------------------------------------------------ #
    def start_job(self, job: Job, gpus: Sequence[int],
                  sub_batch: Optional[int] = None) -> None:
        if job.state == JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} already running")
        gset = frozenset(gpus)
        want = job.alloc_gpus or job.gpus
        if len(gset) != want:
            raise RuntimeError(
                f"job {job.jid} needs {want} GPUs, got {len(gset)}")
        self.cluster.allocate(job.jid, gset)
        job.placement = gset
        if sub_batch is not None:
            job.sub_batch = int(sub_batch)
            job.accum_steps = max(1, int(round(job.batch / job.sub_batch)))
        job.state = JobState.RUNNING
        job.start_time = self.time
        if job.first_start_time is None:
            job.first_start_time = self.time
        job.last_progress_at = self.time
        penalty = self.restart_penalty if job.preemptions > 0 else 0.0
        self._blocked_until[job.jid] = self.time + penalty
        self.running[job.jid] = job
        if job in self.pending:
            self.pending.remove(job)
        self.log.append((self.time, "start", job.jid, sorted(gset)))

    def preempt_job(self, job: Job) -> None:
        if job.state != JobState.RUNNING:
            raise RuntimeError(f"job {job.jid} not running")
        self._accrue(job, self.time)
        self.cluster.release(job.jid, job.placement)
        job.placement = frozenset()
        job.state = JobState.PENDING
        job.preemptions += 1
        job.current_rate = 0.0
        del self.running[job.jid]
        self._blocked_until.pop(job.jid, None)
        self.pending.append(job)
        self.log.append((self.time, "preempt", job.jid))

    # ------------------------------------------------------------------ #
    # Progress accounting
    # ------------------------------------------------------------------ #
    def effective_t_iter(self, job: Job) -> float:
        base = job.base_t_iter()
        xi = 1.0
        for other_id in self.cluster.co_runners(job):
            other = self.jobs[other_id]
            mem = (job.perf.mem_bytes(job.sub_batch)
                   + other.perf.mem_bytes(other.sub_batch))
            xi = max(xi, self.interference.xi(
                job.model, other.model,
                t_me=base,
                t_other=other.perf.t_iter(other.batch, other.accum_steps),
                mem_frac=mem / self.cluster.gpu_capacity_bytes))
        return base * xi

    def _refresh_rates(self) -> None:
        for job in self.running.values():
            job.current_rate = 1.0 / self.effective_t_iter(job)

    def _accrue(self, job: Job, now: float) -> None:
        blocked_until = self._blocked_until.get(job.jid, 0.0)
        begin = max(job.last_progress_at, blocked_until)
        if now > begin and job.current_rate > 0:
            job.iters_done = min(
                job.iters, job.iters_done + (now - begin) * job.current_rate)
        if now > job.last_progress_at:
            job.attained_service += job.gpus * (now - job.last_progress_at)
            # time stalled on restart/migration counts as queueing delay
            stalled = min(now, blocked_until) - job.last_progress_at
            if stalled > 0:
                job.waiting_time += stalled
        job.last_progress_at = now

    def _predicted_finish(self, job: Job) -> float:
        if job.current_rate <= 0:
            return math.inf
        begin = max(self.time, self._blocked_until.get(job.jid, 0.0))
        return begin + job.remaining_iters / job.current_rate

    # ------------------------------------------------------------------ #
    def run(self) -> SimResults:
        finished = 0
        total = len(self.jobs)
        self._refresh_rates()
        while finished < total:
            self._events += 1
            if self._events > self.max_events:
                raise RuntimeError(
                    f"simulator exceeded {self.max_events} events "
                    f"({finished}/{total} finished at t={self.time:.1f}; "
                    f"pending={len(self.pending)})")
            # -- next event time ---------------------------------------
            candidates: List[float] = []
            if self._arrival_idx < len(self.arrivals):
                candidates.append(self.arrivals[self._arrival_idx].arrival)
            for job in self.running.values():
                candidates.append(self._predicted_finish(job))
            if self._next_tick is not None:
                candidates.append(self._next_tick)
            if not candidates:
                raise RuntimeError(
                    f"deadlock: {len(self.pending)} pending jobs, none "
                    f"running, no arrivals left (t={self.time:.1f})")
            t_next = min(candidates)
            if t_next < self.time - _EPS:
                raise RuntimeError("time went backwards")
            t_next = max(t_next, self.time)

            # -- advance all running jobs to t_next --------------------
            for job in list(self.running.values()):
                self._accrue(job, t_next)
            for job in self.pending:
                job.waiting_time += t_next - self.time
            self.time = t_next

            # -- completions -------------------------------------------
            for job in list(self.running.values()):
                if job.remaining_iters <= 1e-6 * max(1.0, job.iters):
                    job.iters_done = job.iters
                    job.state = JobState.FINISHED
                    job.finish_time = self.time
                    self.cluster.release(job.jid, job.placement)
                    job.placement = frozenset()
                    del self.running[job.jid]
                    self._blocked_until.pop(job.jid, None)
                    finished += 1
                    self.log.append((self.time, "finish", job.jid))

            # -- arrivals ----------------------------------------------
            while (self._arrival_idx < len(self.arrivals)
                   and self.arrivals[self._arrival_idx].arrival
                       <= self.time + _EPS):
                job = self.arrivals[self._arrival_idx]
                self.pending.append(job)
                self._arrival_idx += 1
                self.log.append((self.time, "arrive", job.jid))

            # -- tick bookkeeping --------------------------------------
            tick_crossed = False
            if (self._next_tick is not None
                    and self.time + _EPS >= self._next_tick):
                self._next_tick = self.time + self.scheduler.tick_interval
                tick_crossed = True

            # -- schedule ----------------------------------------------
            if not self.scheduler.tick_only or tick_crossed:
                self.scheduler.schedule(self)
            self._refresh_rates()

        makespan = max(j.finish_time for j in self.jobs.values())
        return SimResults(jobs=list(self.jobs.values()), makespan=makespan,
                          events=self._events, name=self.scheduler.name)


class SchedulerBase:
    """Interface; implementations in ``repro.core.schedulers``."""

    name: str = "base"
    preemptive: bool = False
    tick_interval: Optional[float] = None
    tick_only: bool = False   # act only on ticks (interval schedulers)

    def schedule(self, sim: Simulator) -> None:  # pragma: no cover
        raise NotImplementedError
