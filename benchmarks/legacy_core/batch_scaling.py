"""Algorithm 2 — Batch Size Scaling with Best Sharing Benefit.

Given a running job and a new job that would share the running job's GPUs,
sweep the new job's per-GPU sub-batch b over {B, B/2, B/4, ..., 1}
(gradient accumulation supplies s = B/b to keep the *effective* batch, and
hence convergence, unchanged), check memory feasibility of the pair, apply
Theorem 1 per candidate, and return the best (SF, b, t_bar).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from .interference import InterferenceModel
from .job import Job
from .pair import PairDecision, PairJob, best_pair_schedule


@dataclass(frozen=True)
class SharingConfig:
    share: bool                 # SF
    sub_batch: int              # b (new job's per-GPU sub-batch)
    accum_steps: int            # s = B / b
    avg_jct: float              # t_bar
    decision: Optional[PairDecision]
    xi_new: float = 1.0
    xi_run: float = 1.0


def candidate_sub_batches(batch: int) -> list[int]:
    """B, B/2, ..., 1 (powers-of-two steps, as in Algorithm 2)."""
    out = []
    b = batch
    while b >= 1:
        out.append(int(b))
        if b == 1:
            break
        b = math.ceil(b / 2)
    return out


def best_sharing_config(
    running: Job,
    new: Job,
    interference: InterferenceModel,
    gpu_capacity_bytes: float,
) -> SharingConfig:
    """Algorithm 2. ``running`` keeps its current sub-batch (the paper does
    not re-tune the running job); only the new job's b is swept."""
    run_mem = running.perf.mem_bytes(running.sub_batch)
    best: Optional[SharingConfig] = None

    for b in candidate_sub_batches(new.batch):
        s = max(1, int(round(new.batch / b)))
        if not new.perf.fits(b, gpu_capacity_bytes, other_mem=run_mem):
            continue  # pair does not fit device memory at this sub-batch
        t_new = new.perf.t_iter(new.batch, s)
        t_run = running.perf.t_iter(running.batch, running.accum_steps)
        mem_frac = (run_mem + new.perf.mem_bytes(b)) / gpu_capacity_bytes
        xi_run = interference.xi(running.model, new.model,
                                 t_me=t_run, t_other=t_new, mem_frac=mem_frac)
        xi_new = interference.xi(new.model, running.model,
                                 t_me=t_new, t_other=t_run, mem_frac=mem_frac)
        a = PairJob(t_iter=t_run, iters=running.remaining_iters, xi=xi_run)
        bb = PairJob(t_iter=t_new, iters=new.iters, xi=xi_new)
        dec = best_pair_schedule(a, bb)
        cfg = SharingConfig(
            share=dec.share, sub_batch=b, accum_steps=s,
            avg_jct=dec.avg_jct, decision=dec, xi_new=xi_new, xi_run=xi_run,
        )
        if best is None or cfg.avg_jct < best.avg_jct:
            best = cfg

    if best is None:
        # No sub-batch fits next to the running job -> cannot share.
        return SharingConfig(False, new.batch, 1, float("inf"), None)
    return best
