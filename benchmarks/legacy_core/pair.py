"""Theorem 1 — optimal scheduling of one running job A + one new job B on
the same GPU set.

Timeline (kappa = launch time of the new job B, measured from "now"):
  [0, kappa):            A runs solo at iteration time t_A
  [kappa, first_finish): A and B run concurrently at t_A*xi_A / t_B*xi_B
  afterwards:            the survivor runs solo again

Theorem 1 states the pair-average JCT is minimized at one of the two
extremes: kappa = 0 (launch immediately) or kappa = t_A * i_A (fully
sequential). We implement the exact timeline evaluator and pick the best
endpoint; ``tests/test_theorem1.py`` property-checks the endpoint claim
against a brute-force kappa grid.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PairJob:
    """One side of a sharing pair: solo iteration time, remaining
    iterations, and interference ratio while sharing."""

    t_iter: float   # solo iteration time (s)
    iters: float    # remaining iterations
    xi: float       # interference ratio while co-running (>= 1)

    @property
    def solo_time(self) -> float:
        return self.t_iter * self.iters

    @property
    def shared_t_iter(self) -> float:
        return self.t_iter * self.xi


@dataclass(frozen=True)
class PairDecision:
    share: bool          # SF flag: True -> launch B now (kappa = 0)
    kappa: float         # chosen insertion time
    jct_a: float         # completion time of the running job (from now)
    jct_b: float         # completion time of the new job (from now)
    avg_jct: float

    @property
    def makespan(self) -> float:
        return max(self.jct_a, self.jct_b)


def pair_timeline(a: PairJob, b: PairJob, kappa: float) -> tuple[float, float]:
    """Exact (T_A, T_B) for launching B at time ``kappa``; B's JCT is
    measured from now (its queueing time ``kappa`` is included)."""
    if kappa < 0:
        raise ValueError("kappa must be >= 0")
    t_a_solo_total = a.solo_time
    if kappa >= t_a_solo_total:
        # Fully sequential: A finishes untouched, then B runs solo.
        t_a = t_a_solo_total
        start_b = max(kappa, t_a)
        return t_a, start_b + b.solo_time

    # Phase 1: A solo during [0, kappa).
    iters_a_done = kappa / a.t_iter
    rem_a = a.iters - iters_a_done
    # Phase 2: concurrent from kappa.
    ta_shared = a.shared_t_iter
    tb_shared = b.shared_t_iter
    fin_a_shared = rem_a * ta_shared       # time A needs if sharing persists
    fin_b_shared = b.iters * tb_shared     # time B needs if sharing persists
    if fin_a_shared <= fin_b_shared:
        # A finishes first; B then continues solo.
        t_a = kappa + fin_a_shared
        iters_b_done = fin_a_shared / tb_shared
        t_b = t_a + (b.iters - iters_b_done) * b.t_iter
    else:
        # B finishes first; A then continues solo.
        t_b = kappa + fin_b_shared
        iters_a_done2 = fin_b_shared / ta_shared
        t_a = t_b + (rem_a - iters_a_done2) * a.t_iter
    return t_a, t_b


def best_pair_schedule(a: PairJob, b: PairJob) -> PairDecision:
    """Theorem 1: compare kappa=0 (full overlap) vs kappa=t_A*i_A
    (sequential) and return the better average-JCT decision."""
    t_a0, t_b0 = pair_timeline(a, b, 0.0)
    seq_kappa = a.solo_time
    t_a1, t_b1 = pair_timeline(a, b, seq_kappa)
    avg0 = 0.5 * (t_a0 + t_b0)
    avg1 = 0.5 * (t_a1 + t_b1)
    if avg0 <= avg1:
        return PairDecision(True, 0.0, t_a0, t_b0, avg0)
    return PairDecision(False, seq_kappa, t_a1, t_b1, avg1)


def monotonicity_coefficient(a: PairJob, b: PairJob) -> float:
    """The paper's sign term 2*xi_B + xi_A - 2*xi_A*xi_B (Eq. 24): positive
    -> avg JCT increases with kappa (share now), negative -> sequential."""
    return 2.0 * b.xi + a.xi - 2.0 * a.xi * b.xi
