"""DL task profiles used by the workload generator.

The paper drives its simulator with six Pollux tasks (BERT, CIFAR10,
DeepSpeech2, ImageNet, NCF, YoloV3) measured on 2080 Ti nodes. The raw
coefficients are not published; the profiles below are synthesized from
public model characteristics (params, per-sample train FLOPs, activation
footprints) so that Eq. 3/4/7 reproduce the qualitative throughput
structure of Fig. 2 (BERT compute/memory-bound, YoloV3 network-bound past
12 GPUs, NCF tiny, ...). The assigned-architecture profiles for the TPU
cluster are derived analytically in ``repro.configs`` and converted here
via :func:`profile_from_arch`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .perf_model import (GPU_2080TI, HardwareSpec, PerfParams,
                         derive_perf_params)


@dataclass(frozen=True)
class TaskProfile:
    name: str
    flops_per_sample: float      # fwd+bwd FLOPs per training sample
    param_bytes: float           # gradient message size (fp32 bytes)
    act_bytes_per_sample: float  # activation working set per sample
    default_batch: int           # per-GPU user batch
    opt_state_multiplier: float = 3.0  # adam: master + m + v over grads
    framework_bytes: float = 1.0 * 2**30
    delta: float = 2.0

    def perf_params(self, n_gpus: int,
                    hw: HardwareSpec = GPU_2080TI) -> PerfParams:
        opt = self.param_bytes * self.opt_state_multiplier
        return derive_perf_params(
            flops_per_sample=self.flops_per_sample,
            param_bytes=self.param_bytes,
            n_workers=n_gpus,
            hw=hw,
            act_bytes_per_sample=self.act_bytes_per_sample,
            opt_bytes=opt + self.framework_bytes,
            delta=self.delta,
        )


PAPER_TASK_PROFILES: Dict[str, TaskProfile] = {
    # name                  flops/sample  grad bytes  act/sample   batch
    "bert": TaskProfile("bert", 8.4e10, 440e6, 45e6, 32),
    "cifar10": TaskProfile("cifar10", 1.7e9, 45e6, 5e6, 128),
    "deepspeech2": TaskProfile("deepspeech2", 2.4e10, 350e6, 60e6, 32),
    "imagenet": TaskProfile("imagenet", 1.23e10, 102e6, 110e6, 64),
    "ncf": TaskProfile("ncf", 1.6e8, 120e6, 0.2e6, 1024),
    "yolov3": TaskProfile("yolov3", 1.96e11, 248e6, 380e6, 16),
}


def profile_from_arch(name: str, *, n_params: float, n_active_params: float,
                      seq_len: int, batch: int,
                      act_bytes_per_token: float) -> TaskProfile:
    """Build a TaskProfile for one of the assigned architectures: a job in
    the cluster trace is 'train <arch> at seq_len with per-device batch'."""
    return TaskProfile(
        name=name,
        flops_per_sample=6.0 * n_active_params * seq_len,
        param_bytes=4.0 * n_params,
        act_bytes_per_sample=act_bytes_per_token * seq_len,
        default_batch=batch,
        framework_bytes=0.5 * 2**30,
    )
