"""Figures 4-5 — JCT distributions (CDF deciles) and per-DL-task average
queueing time, physical (30-job) and simulation (240-job) workloads.
Both workloads' policy runs fan out as one parallel sweep; the per-job
metrics are reduced inside the workers (collect=...)."""
from __future__ import annotations

from repro.core.sweep import ScenarioSpec, run_sweep

from .common import POLICIES, save_json

WORKLOADS = (
    # (tag, trace kind, n_jobs, n_servers)
    ("fig4_physical", "physical", 30, 4),
    ("fig5_simulation", "simulation", 240, 16),
)


def run(verbose: bool = True, workers=None):
    specs = [
        ScenarioSpec(policy=p, trace=trace, n_jobs=n_jobs,
                     n_servers=ns, gpus_per_server=4, tag=tag,
                     collect=("jct_deciles", "queue_by_model"))
        for tag, trace, n_jobs, ns in WORKLOADS for p in POLICIES
    ]
    rows = run_sweep(specs, workers=workers)
    payload = {}
    for row in rows:
        payload.setdefault(row["tag"], {})[row["policy"]] = {
            "jct_deciles": row["jct_deciles"],
            "queue_by_model": row["queue_by_model"],
        }
    if verbose:
        for tag, *_ in WORKLOADS:
            print(f"{tag}: median JCT per policy: " + ", ".join(
                f"{p}={payload[tag][p]['jct_deciles'][4]:.0f}s"
                for p in POLICIES))
    save_json("fig4_fig5.json", payload)
    return payload


if __name__ == "__main__":
    run()
