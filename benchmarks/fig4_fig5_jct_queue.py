"""Figures 4-5 — JCT distributions (CDF deciles) and per-DL-task average
queueing time, physical (30-job) and simulation (240-job) workloads."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict

import numpy as np

from repro.core import physical_trace, simulation_trace

from .common import POLICIES, run_all_policies, save_json


def _jct_deciles(res) -> list:
    jcts = res.jct_list()
    return [float(np.percentile(jcts, q)) for q in range(10, 101, 10)]


def _queue_by_model(res) -> Dict[str, float]:
    acc = defaultdict(list)
    for j in res.jobs:
        acc[j.model].append(j.queueing_delay())
    return {m: float(np.mean(v)) for m, v in sorted(acc.items())}


def run(verbose: bool = True):
    payload = {}
    for tag, jobs, ns in (("fig4_physical", physical_trace(), 4),
                          ("fig5_simulation", simulation_trace(240), 16)):
        results = run_all_policies(jobs, n_servers=ns, gpus_per_server=4)
        payload[tag] = {
            p: {"jct_deciles": _jct_deciles(r),
                "queue_by_model": _queue_by_model(r)}
            for p, r in results.items()}
        if verbose:
            print(f"{tag}: median JCT per policy: " + ", ".join(
                f"{p}={payload[tag][p]['jct_deciles'][4]:.0f}s"
                for p in POLICIES))
    save_json("fig4_fig5.json", payload)
    return payload


if __name__ == "__main__":
    run()
