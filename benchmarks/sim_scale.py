"""Datacenter-year scale benchmark: Philly-shaped trace replay through
the vectorized scheduling pass (DESIGN.md §14).

Three questions, one artifact (``BENCH_sim_scale.json``):

1. **Does throughput hold as the cluster grows?** A {64, 256, 1024}-GPU
   ladder replays :func:`repro.core.trace.philly_trace` (job-size /
   duration / diurnal-arrival distributions shaped like the Philly and
   Helios traces) through SJF and SJF-BSBF with the grid decision path.
   Acceptance: events/sec must not decay from 64 to 1024 GPUs — the
   pre-vectorization scheduler was O(pending x donors) *python* work per
   pass and fell over exactly here.
2. **How fast is a datacenter-year?** The headline scenario is 10,240
   GPUs / 100,000 jobs (a Philly-sized cluster over months of trace
   time); acceptance is >= 50k simulated events/sec, where an event is
   one scheduler/engine log record (arrive, start, config, finish — the
   granularity a replay consumer sees). Engine loop iterations/sec are
   reported alongside.
3. **What does +10% load do to p95 queueing?** The capacity-planning
   probe replays the same trace at utilization 0.7 and 0.77 and reports
   the p50/p90/p95/p99 queueing-delay shift — the question an operator
   actually asks of a simulator at this scale.

The grid pass must be a pure optimization: the smallest ladder point is
also replayed with ``decision="scalar"`` and the schedules asserted
identical (event log, summary, per-job finish times).

Usage:
    PYTHONPATH=src python -m benchmarks.sim_scale
    PYTHONPATH=src python -m benchmarks.sim_scale --smoke
    PYTHONPATH=src python -m benchmarks.sim_scale \
        --policies sjf --no-headline --out /tmp/scale.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

from repro.core import (ClusterState, Simulator, make_scheduler,
                        paper_interference_model)
from repro.core.trace import philly_trace

# ladder: gpus -> n_jobs (jobs scale with the cluster so each point
# simulates a comparable span of trace time)
LADDER_JOBS = {64: 2000, 256: 8000, 1024: 20000}
HEADLINE = (10240, 100000)
GPUS_PER_SERVER = 8
GB = 2 ** 30
EVENTS_PER_SEC_BAR = 50_000.0


def _percentiles(values: List[float],
                 qs=(50, 90, 95, 99)) -> Dict[str, float]:
    """Linear-interpolated percentiles of ``values`` (0.0 when empty)."""
    if not values:
        return {f"p{q}": 0.0 for q in qs}
    xs = sorted(values)
    out: Dict[str, float] = {}
    for q in qs:
        pos = (len(xs) - 1) * q / 100.0
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        out[f"p{q}"] = xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
    return out


def run_once(policy: str, n_gpus: int, n_jobs: int, seed: int,
             utilization: float, decision: Optional[str] = None,
             keep_sim: bool = False) -> Dict:
    jobs = philly_trace(n_jobs=n_jobs, seed=seed, n_gpus=n_gpus,
                        utilization=utilization)
    cluster = ClusterState(n_servers=n_gpus // GPUS_PER_SERVER,
                           gpus_per_server=GPUS_PER_SERVER,
                           gpu_capacity_bytes=11 * GB)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=paper_interference_model(),
                    decision=decision, max_events=50_000_000)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    row = {
        "policy": policy,
        "decision": sim.decision_path,
        "n_gpus": n_gpus,
        "n_jobs": n_jobs,
        "utilization": utilization,
        "wall_seconds": wall,
        "log_records": len(sim.log),
        "loop_iterations": res.events,
        "events_per_sec": len(sim.log) / wall,
        "iterations_per_sec": res.events / wall,
        "avg_jct": res.avg_jct(),
        "avg_queueing": res.avg_queueing(),
        "makespan": res.makespan,
        "queueing": _percentiles([j.queueing_delay() for j in res.jobs]),
    }
    if keep_sim:
        row["_sim"] = sim   # stripped before serialization
        row["_res"] = res
    return row


def check_identity(policy: str, n_gpus: int, n_jobs: int, seed: int,
                   utilization: float) -> Dict:
    """Replay the same scenario on the grid and scalar decision paths
    and require bit-identical schedules."""
    a = run_once(policy, n_gpus, n_jobs, seed, utilization,
                 decision="grid", keep_sim=True)
    b = run_once(policy, n_gpus, n_jobs, seed, utilization,
                 decision="scalar", keep_sim=True)
    sim_a, sim_b = a.pop("_sim"), b.pop("_sim")
    res_a, res_b = a.pop("_res"), b.pop("_res")
    if sim_a.log != sim_b.log:
        raise AssertionError(
            f"grid vs scalar event logs diverged at {n_gpus} GPUs "
            f"({len(sim_a.log)} vs {len(sim_b.log)} records)")
    if res_a.summary() != res_b.summary():
        raise AssertionError(
            f"grid vs scalar summaries diverged at {n_gpus} GPUs: "
            f"{res_a.summary()} vs {res_b.summary()}")
    return {"n_gpus": n_gpus, "n_jobs": n_jobs, "policy": policy,
            "identical_log": True, "identical_summary": True,
            "log_records": len(sim_a.log)}


def capacity_probe(policy: str, n_gpus: int, n_jobs: int, seed: int,
                   base_utilization: float, verbose: bool) -> Dict:
    """+10% offered load (utilization * 1.1 compresses the arrival
    horizon by 10%) -> queueing-percentile shift."""
    base = run_once(policy, n_gpus, n_jobs, seed, base_utilization)
    loaded = run_once(policy, n_gpus, n_jobs, seed,
                      base_utilization * 1.1)
    delta = {k: loaded["queueing"][k] - base["queueing"][k]
             for k in base["queueing"]}
    if verbose:
        print(f"  capacity [{policy}] {n_gpus} GPUs: p95 queueing "
              f"{base['queueing']['p95']:.0f}s -> "
              f"{loaded['queueing']['p95']:.0f}s "
              f"(+10% load => {delta['p95']:+.0f}s)")
    return {"policy": policy, "n_gpus": n_gpus, "n_jobs": n_jobs,
            "base_utilization": base_utilization,
            "base": base, "plus_10pct_load": loaded,
            "queueing_delta": delta}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--policies", default="sjf,sjf-bsbf",
                    help="comma-separated policy names")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--utilization", type=float, default=0.7,
                    help="offered load as a fraction of cluster "
                         "GPU-seconds (Philly ran ~0.5-0.8 utilized)")
    ap.add_argument("--no-headline", action="store_true",
                    help="skip the 10240-GPU / 100k-job scenario")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (64 GPUs, 300 jobs; "
                         "no headline, no acceptance bars)")
    ap.add_argument("--out", default=os.path.join(
        "artifacts", "bench", "BENCH_sim_scale.json"))
    args = ap.parse_args(argv)

    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    if args.smoke:
        ladder = [(64, 300)]
        headline = None
        probe_size = (64, 300)
    else:
        ladder = sorted(LADDER_JOBS.items())
        headline = None if args.no_headline else HEADLINE
        probe_size = (1024, LADDER_JOBS[1024])

    rows: List[Dict] = []
    for policy in policies:
        for n_gpus, n_jobs in ladder:
            r = run_once(policy, n_gpus, n_jobs, args.seed,
                         args.utilization)
            rows.append(r)
            print(f"  ladder [{policy}] {n_gpus:>6} GPUs / {n_jobs} jobs: "
                  f"{r['wall_seconds']:7.2f}s  "
                  f"{r['events_per_sec']:9.0f} ev/s  "
                  f"p95 queueing {r['queueing']['p95']:.0f}s")

    headline_rows: List[Dict] = []
    if headline is not None:
        n_gpus, n_jobs = headline
        for policy in policies:
            r = run_once(policy, n_gpus, n_jobs, args.seed,
                         args.utilization)
            headline_rows.append(r)
            print(f"headline [{policy}] {n_gpus} GPUs / {n_jobs} jobs: "
                  f"{r['wall_seconds']:7.2f}s  "
                  f"{r['events_per_sec']:9.0f} ev/s  "
                  f"({r['iterations_per_sec']:.0f} loop-iter/s)")

    # grid == scalar on the smallest ladder point, sharing policy only
    # (the grid pass is a no-op for non-sharing policies)
    id_gpus, id_jobs = ladder[0]
    identity = [check_identity(p, id_gpus, min(id_jobs, 2000), args.seed,
                               args.utilization)
                for p in policies if "bsbf" in p] or None
    if identity:
        print(f"identity: grid == scalar on {id_gpus} GPUs "
              f"({identity[0]['log_records']} log records)")

    probes = [capacity_probe(p, probe_size[0], probe_size[1], args.seed,
                             args.utilization, verbose=True)
              for p in policies]

    payload = {
        "bench": "sim_scale",
        "smoke": bool(args.smoke),
        "trace": "philly",
        "seed": args.seed,
        "utilization": args.utilization,
        "gpus_per_server": GPUS_PER_SERVER,
        "events_per_sec_bar": EVENTS_PER_SEC_BAR,
        "ladder": rows,
        "headline": headline_rows or None,
        "identity": identity,
        "capacity_probe": probes,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")

    if args.smoke:
        return 0

    # acceptance 1: no throughput decay across the ladder (per policy)
    status = 0
    for policy in policies:
        pts = [r for r in rows if r["policy"] == policy]
        if len(pts) >= 2 and pts[-1]["events_per_sec"] < pts[0][
                "events_per_sec"] * 0.9:
            print(f"WARNING: [{policy}] events/sec decays "
                  f"{pts[0]['events_per_sec']:.0f} -> "
                  f"{pts[-1]['events_per_sec']:.0f} across "
                  f"{pts[0]['n_gpus']} -> {pts[-1]['n_gpus']} GPUs")
            status = 1
    # acceptance 2: the headline scenario clears the events/sec bar
    if headline_rows:
        best = max(r["events_per_sec"] for r in headline_rows)
        if best < EVENTS_PER_SEC_BAR:
            print(f"WARNING: headline events/sec {best:.0f} below the "
                  f"{EVENTS_PER_SEC_BAR:.0f} bar")
            status = 1
    return status


if __name__ == "__main__":
    raise SystemExit(main())
