"""Trainable-kernel microbenchmark: fwd and fwd+bwd walltime of the
Pallas flash-attention / SSD kernels vs the model's jnp reference paths,
plus HBM-byte accounting for the attention backward at S=1024
(``artifacts/bench/BENCH_kernels.json``).

Byte accounting (DESIGN.md §11): the REFERENCE path is measured with the
existing ``launch/hlo_flops.py`` trip-count-aware analysis over the
XLA-compiled fwd+bwd program — it materializes the (S, S) score /
probability / dS tensors, so its traffic is O(S^2). The KERNEL path's
HBM traffic is its DMA boundary, computed exactly from the grid /
BlockSpec geometry (``flash_attention_hbm_bytes``): score tiles and
running statistics are VMEM-resident by construction and never hit HBM.
The interpret-mode HLO of the kernel is also run through ``hlo_flops``
and recorded for transparency — it spills every VMEM tile to a buffer,
so it overstates TPU traffic by orders of magnitude and is NOT the
headline number.

Walltime on this CPU container compares interpret-mode kernels (traced
jnp emulation of the TPU algorithm) against the jnp reference — the
kernel path is expected to be SLOWER here; the numbers exist to track
regressions and to be re-run on real TPU hardware.

Usage:
    PYTHONPATH=src python -m benchmarks.kernels_bench            # full
    PYTHONPATH=src python -m benchmarks.kernels_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_hbm_bytes
from repro.kernels.flash_attention import flash_attention as flash_raw
from repro.launch.hlo_flops import hlo_flops_bytes
from repro.models.attention import full_attention
from repro.models.ssm import ssd_chunked

from .common import save_json

BYTES_SHAPE = (1, 8, 1024, 64)      # (B, H, S, D) for the S=1024 analysis
BYTES_BLOCK = 512                   # 2x2 kv/q blocks at S=1024


def _time(fn, args, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _vjp_fn(f):
    def run(*args):
        out, pull = jax.vjp(f, *args[:-1])
        return pull(args[-1])
    return run


# ---------------------------------------------------------------------- #
# walltime
# ---------------------------------------------------------------------- #
def time_attention(shapes, iters: int):
    # blocks pinned to the hard-coded defaults: this bench measures the
    # RAW Pallas kernel vs XLA (the autotuner's input, recorded by
    # benchmarks.autotune_sweep) — a loaded autotune table must not
    # silently reroute the "kernel" rows to the reference
    from repro.kernels.autotune import DEFAULTS
    blocks = dict(DEFAULTS["flash_attention"])
    out = {}
    for (b, s, h, d) in shapes:            # model layout (B, S, H, D)
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q, k, v, do = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        kern = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v,
                                                           **blocks))
        ref = jax.jit(lambda q, k, v: full_attention(q, k, v))
        row = {
            "fwd": {"kernel": _time(kern, (q, k, v), iters),
                    "ref": _time(ref, (q, k, v), iters)},
            "fwd_bwd": {
                "kernel": _time(
                    jax.jit(_vjp_fn(lambda q, k, v:
                                    ops.flash_attention(q, k, v,
                                                        **blocks))),
                    (q, k, v, do), iters),
                "ref": _time(
                    jax.jit(_vjp_fn(lambda q, k, v:
                                    full_attention(q, k, v))),
                    (q, k, v, do), iters)},
        }
        out[f"b{b}_s{s}_h{h}_d{d}"] = row
    return out


def time_ssd(shapes, iters: int):
    out = {}
    for (b, s, h, p, n, chunk) in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        Bm = jax.random.normal(ks[3], (b, s, n))
        Cm = jax.random.normal(ks[4], (b, s, n))
        dy = jax.random.normal(ks[5], (b, s, h, p))
        kern = jax.jit(lambda *a: ops.ssd(*a, chunk=chunk))
        ref = jax.jit(lambda *a: ssd_chunked(*a, chunk=chunk))
        row = {
            "fwd": {"kernel": _time(kern, (x, dt, A, Bm, Cm), iters),
                    "ref": _time(ref, (x, dt, A, Bm, Cm), iters)},
            "fwd_bwd": {
                "kernel": _time(
                    jax.jit(_vjp_fn(lambda *a: ops.ssd(*a, chunk=chunk))),
                    (x, dt, A, Bm, Cm, dy), iters),
                "ref": _time(
                    jax.jit(_vjp_fn(lambda *a: ssd_chunked(*a, chunk=chunk))),
                    (x, dt, A, Bm, Cm, dy), iters)},
        }
        out[f"b{b}_s{s}_h{h}_p{p}_n{n}"] = row
    return out


# ---------------------------------------------------------------------- #
# attention-backward byte accounting at S=1024
# ---------------------------------------------------------------------- #
def attention_bytes(include_interpret_hlo: bool = True):
    b, h, s, d = BYTES_SHAPE
    spec = jax.ShapeDtypeStruct((b, h, s, d), jnp.float32)

    def ref_prog(q, k, v, do):      # fwd + bwd of the full-softmax path
        out, pull = jax.vjp(
            lambda q, k, v: full_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3)), q, k, v)
        return pull(do.transpose(0, 2, 1, 3))

    hlo = jax.jit(ref_prog).lower(spec, spec, spec, spec).compile().as_text()
    ref_bytes = hlo_flops_bytes(hlo)["bytes"]

    dma = flash_attention_hbm_bytes(b, h, s, d, block_q=BYTES_BLOCK,
                                    block_k=BYTES_BLOCK)
    # both sides are the full vjp program (forward + backward): the
    # reference forward's residual traffic IS part of its backward cost,
    # and the kernel's recompute strategy trades residuals for refetches
    row = {
        "shape_bhsd": list(BYTES_SHAPE),
        "block": BYTES_BLOCK,
        "ref_hlo_bytes_fwd_bwd": ref_bytes,
        "kernel_dma_bytes_fwd_bwd": dma["fwd_bwd"],
        "kernel_dma_bytes_bwd_only": dma["bwd"],
        "fwd_bwd_bytes_reduction": ref_bytes / dma["fwd_bwd"],
    }
    if include_interpret_hlo:
        def ker_prog(q, k, v, do):
            out, pull = jax.vjp(
                lambda q, k, v: flash_raw(
                    q, k, v, block_q=BYTES_BLOCK, block_k=BYTES_BLOCK,
                    interpret=True), q, k, v)
            return pull(do)
        hlo2 = jax.jit(ker_prog).lower(
            spec, spec, spec, spec).compile().as_text()
        # VMEM tiles spilled to buffers by the interpreter — overcount,
        # recorded for transparency only (see module docstring)
        row["kernel_interpret_hlo_bytes"] = hlo_flops_bytes(hlo2)["bytes"]
    return row


def run(smoke: bool = False, verbose: bool = True):
    iters = 2 if smoke else 5
    attn_shapes = [(1, 256, 2, 32)] if smoke else \
        [(1, 256, 2, 32), (1, 512, 4, 64), (1, 1024, 4, 64)]
    ssd_shapes = [(1, 256, 2, 16, 16, 128)] if smoke else \
        [(1, 256, 2, 16, 16, 128), (1, 512, 4, 32, 32, 128)]

    payload = {
        "attention": {"timing": time_attention(attn_shapes, iters),
                      "bytes_s1024": attention_bytes()},
        "ssd": {"timing": time_ssd(ssd_shapes, iters)},
        "meta": {"backend": jax.default_backend(), "smoke": smoke,
                 "iters": iters,
                 "note": "kernel timings are interpret-mode on CPU"},
    }
    path = save_json("BENCH_kernels.json", payload)
    if verbose:
        by = payload["attention"]["bytes_s1024"]
        print(f"attention vjp (fwd+bwd) bytes @ S=1024 (block {by['block']}): "
              f"ref {by['ref_hlo_bytes_fwd_bwd'] / 2**20:.0f} MiB (hlo_flops) "
              f"vs kernel {by['kernel_dma_bytes_fwd_bwd'] / 2**20:.0f} MiB "
              f"(DMA) -> {by['fwd_bwd_bytes_reduction']:.1f}x reduction")
        for sec in ("attention", "ssd"):
            for key, row in payload[sec]["timing"].items():
                fb = row["fwd_bwd"]
                print(f"{sec} {key}: fwd+bwd kernel {fb['kernel']:.3f}s "
                      f"ref {fb['ref']:.3f}s")
        print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
