"""Benchmark harness entry point — one module per paper table/figure plus
the roofline analysis over the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--skip-slow]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the 480-job table and xi calibration")
    args = ap.parse_args(argv)

    from . import (fig4_fig5_jct_queue, fig6a_load, fig6b_xi,
                   replay_validation, roofline, sim_throughput,
                   table2_physical, table3_240, table4_480, xi_calibration)

    stages = [
        ("table2_physical (Table II)", table2_physical.run),
        ("table3_240 (Table III)", table3_240.run),
        ("fig4_fig5 (JCT dists / queueing)", fig4_fig5_jct_queue.run),
        ("fig6a_load (load sweep)", fig6a_load.run),
        ("fig6b_xi (xi sweep)", fig6b_xi.run),
    ]
    if not args.skip_slow:
        stages.insert(2, ("table4_480 (Table IV)", table4_480.run))
        stages.append(("xi_calibration (calibration pipeline)",
                       xi_calibration.run))
        stages.append(("replay_validation (closed-loop executor replay)",
                       replay_validation.run))
        stages.append(("sim_throughput (engine before/after)",
                       sim_throughput.run))
    stages.append(("roofline (§Roofline from dry-run)", roofline.run))

    failures = 0
    for name, fn in stages:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"--- {name}: {time.time() - t0:.1f}s")
        except FileNotFoundError as e:
            print(f"--- {name}: SKIPPED (missing artifact: {e})")
        except Exception as e:
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"--- {name}: FAILED ({e})")
    print(f"\nbenchmarks complete, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
