"""Prefix-sharing serving benchmark: copy-on-write paged KV cache with
radix-trie admission (DESIGN.md §18) vs the private-pages baseline at
EQUAL cache memory (``artifacts/bench/BENCH_prefix.json``).

Workload: tenants with Zipf-distributed popularity, each owning a fixed
system prompt (the shared prefix); every request is that prefix plus a
short unique user suffix, so >= 50% of prompt tokens are shared.  Three
sections:

* **capacity** — a prompt-heavy burst against both engines at the same
  page pool.  The private baseline reserves every prompt page per
  request; the sharing engine charges credit only for unique pages, so
  it admits >= 2x the concurrent requests (the acceptance ratio), with
  tokens bit-identical to the baseline and to solo generation.
* **diurnal** — sinusoidal arrival waves (day/night load), sustained
  req/s for both engines draining the same trace.
* **admission latency** — walltime of the admission step for a prefix
  hit (gather + suffix-extend prefill) vs a miss (full prefill), warm
  jits, plus prefill-compute-saved ratios (token count and a quadratic
  attention-FLOPs proxy).

The prefill savings are arithmetic, not sampling: the suffix-extend
path recomputes at least two prompt rows (the bitwise floor) and every
non-shared row, nothing else.

Usage:
    PYTHONPATH=src python -m benchmarks.prefix_bench            # full
    PYTHONPATH=src python -m benchmarks.prefix_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import serve
from repro.launch.engine import DecodeEngine

from .common import save_json

ARCH = "minicpm-2b"


def _cfg():
    import dataclasses
    return dataclasses.replace(get_config(ARCH).reduced(), dtype="float32")


def _zipf_weights(n: int, a: float = 2.0):
    w = np.array([1.0 / (r + 1) ** a for r in range(n)])
    return w / w.sum()


def _make_workload(rng, *, n_tenants, n_requests, prefix_len, suffix_len,
                   vocab):
    """Zipf-popular tenants, each with a fixed system prompt; every
    request appends a unique user suffix."""
    prefixes = [rng.integers(0, vocab, prefix_len) for _ in range(n_tenants)]
    tenants = rng.choice(n_tenants, size=n_requests,
                         p=_zipf_weights(n_tenants))
    prompts = [np.concatenate([prefixes[t],
                               rng.integers(0, vocab, suffix_len)])
               for t in tenants]
    return prompts, tenants.tolist()


def _drain(eng, prompts, tokens):
    rids = [eng.submit(p, tokens) for p in prompts]
    eng.run()
    return {r: eng.outputs[r] for r in rids}


# ---------------------------------------------------------------------- #
def bench_capacity(cfg, params, *, smoke: bool):
    """Concurrent-request capacity at equal cache memory.  Sized so the
    sharing engine's credit admits >= 2x the private baseline under ANY
    FIFO arrival order of the Zipf trace (worst case: every tenant's
    first request is a full-reserve miss)."""
    if smoke:
        n_tenants, n_requests = 2, 10
        prefix_len, suffix_len, tokens = 16, 8, 8
        n_slots, max_len, ps, n_pages = 8, 32, 8, 16
    else:
        n_tenants, n_requests = 2, 22
        prefix_len, suffix_len, tokens = 48, 8, 8
        n_slots, max_len, ps, n_pages = 12, 64, 8, 32
    rng = np.random.default_rng(0)
    prompts, tenants = _make_workload(
        rng, n_tenants=n_tenants, n_requests=n_requests,
        prefix_len=prefix_len, suffix_len=suffix_len, vocab=cfg.vocab)

    def engine(prefix):
        return DecodeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                            segment=8, paged=True, page_size=ps,
                            n_pages=n_pages, prefix_share=prefix)

    private = engine(False)
    shared = engine(True)
    out_private = _drain(private, prompts, tokens)
    out_shared = _drain(shared, prompts, tokens)
    identical = out_private == out_shared
    assert identical, "prefix-shared tokens diverge from private baseline"

    # solo-generation identity for one hit and one miss request
    solo_identical = True
    checked = {}
    for rid in (0, len(prompts) - 1):
        toks = serve.generate(cfg, params,
                              jnp.asarray(prompts[rid])[None, :],
                              max_new_tokens=tokens, max_len=max_len)
        same = list(np.asarray(toks)[0]) == out_shared[rid]
        checked[rid] = same
        solo_identical &= same
    assert solo_identical, f"engine tokens diverge from solo: {checked}"

    ratio = (shared.stats["peak_active_slots"]
             / max(1, private.stats["peak_active_slots"]))
    return {
        "n_tenants": n_tenants, "n_requests": n_requests,
        "prefix_len": prefix_len, "suffix_len": suffix_len,
        "shared_token_frac": prefix_len / (prefix_len + suffix_len),
        "tokens_per_request": tokens, "n_slots": n_slots,
        "page_size": ps, "n_pages": n_pages, "cache_rows": n_pages * ps,
        "private": {"peak_concurrent": private.stats["peak_active_slots"],
                    "stats": dict(private.stats)},
        "shared": {"peak_concurrent": shared.stats["peak_active_slots"],
                   "stats": dict(shared.stats)},
        "capacity_ratio": ratio,
        "tokens_identical": identical,
        "solo_identical": solo_identical,
    }


# ---------------------------------------------------------------------- #
def bench_diurnal(cfg, params, *, smoke: bool):
    """Sustained throughput over sinusoidal arrival waves: requests land
    in per-phase batches sized by a day/night curve, both engines drain
    the same trace at equal memory, neither sheds (no deadlines), so
    req/s is directly comparable."""
    if smoke:
        phases, base, amp = 2, 3, 2
        prefix_len, suffix_len, tokens = 16, 8, 8
        n_slots, max_len, ps, n_pages = 8, 32, 8, 16
    else:
        phases, base, amp = 6, 4, 3
        prefix_len, suffix_len, tokens = 48, 8, 16
        n_slots, max_len, ps, n_pages = 12, 80, 8, 40
    rng = np.random.default_rng(1)
    waves = [base + int(round(amp * math.sin(2 * math.pi * i / phases)))
             for i in range(phases)]
    prompts, _ = _make_workload(
        rng, n_tenants=4, n_requests=sum(waves),
        prefix_len=prefix_len, suffix_len=suffix_len, vocab=cfg.vocab)

    def run(prefix):
        eng = DecodeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                           segment=8, paged=True, page_size=ps,
                           n_pages=n_pages, prefix_share=prefix)
        it = iter(prompts)
        _drain(eng, [next(it) for _ in range(waves[0])], tokens)  # warm jits
        t0 = time.perf_counter()
        for w in waves[1:]:
            _drain(eng, [next(it) for _ in range(w)], tokens)
        dt = time.perf_counter() - t0
        return sum(waves[1:]) / dt, eng

    rps_private, _ = run(False)
    rps_shared, eng = run(True)
    return {
        "phases": phases, "wave_sizes": waves, "n_tenants": 4,
        "prefix_len": prefix_len, "suffix_len": suffix_len,
        "tokens_per_request": tokens, "n_pages": n_pages,
        "private_req_s": rps_private, "shared_req_s": rps_shared,
        "speedup": rps_shared / rps_private,
        "shed_rate_both": 0.0,            # no deadlines: equal by design
        "shared_stats": dict(eng.stats),
    }


# ---------------------------------------------------------------------- #
def bench_admission(cfg, params, *, smoke: bool):
    """Admission latency, warm jits: a prefix hit runs the pool gather +
    suffix-extend prefill; a miss runs the full solo prefill.  Also
    derives prefill-compute-saved from the engine counters."""
    prefix_len, suffix_len = (16, 8) if smoke else (48, 8)
    plen = prefix_len + suffix_len
    n_slots, max_len, ps = 4, 64, 8
    rng = np.random.default_rng(2)
    eng = DecodeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                       segment=8, paged=True, page_size=ps,
                       n_pages=n_slots * max_len // ps, prefix_share=True)
    prefix = rng.integers(0, cfg.vocab, prefix_len)

    def admit_once(prompt):
        eng.submit(prompt, 8)
        t0 = time.perf_counter()
        eng._admit()
        jax.block_until_ready(eng.cache["units"])
        dt = time.perf_counter() - t0
        eng.run()
        return dt

    def fresh_miss():
        return np.concatenate([rng.integers(0, cfg.vocab, prefix_len),
                               rng.integers(0, cfg.vocab, suffix_len)])

    def hit():
        return np.concatenate([prefix,
                               rng.integers(0, cfg.vocab, suffix_len)])

    admit_once(fresh_miss())                       # compile full prefill
    admit_once(hit())                              # seed trie
    admit_once(hit())                              # compile gather+extend
    iters = 2 if smoke else 5
    t_miss = min(admit_once(fresh_miss()) for _ in range(iters))
    t_hit = min(admit_once(hit()) for _ in range(iters))

    st = eng.stats
    token_frac = (st["prefill_tokens_saved"]
                  / max(1, st["prompt_tokens_total"]))
    # quadratic attention proxy: a full prefill costs ~plen^2 row-key
    # products; the extend path's suffix rows still attend all plen keys
    L = min(prefix_len, plen - 2)
    flops_frac = 1.0 - ((plen - L) * plen) / (plen * plen)
    return {
        "prompt_len": plen, "matched_len": L,
        "admit_ms_miss": 1e3 * t_miss, "admit_ms_hit": 1e3 * t_hit,
        "hit_speedup": t_miss / t_hit,
        "prefill_tokens_saved_frac": token_frac,
        "prefill_flops_saved_frac_per_hit": flops_frac,
        "stats": dict(eng.stats),
    }


# ---------------------------------------------------------------------- #
def run(smoke: bool = False, verbose: bool = True):
    cfg = _cfg()
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))

    capacity = bench_capacity(cfg, params, smoke=smoke)
    diurnal = bench_diurnal(cfg, params, smoke=smoke)
    admission = bench_admission(cfg, params, smoke=smoke)

    hit_rate = capacity["shared"]["stats"]["prefix_hit_rate"]
    assert hit_rate > 0, "no prefix hits on a Zipf-shared workload"
    assert capacity["tokens_identical"] and capacity["solo_identical"]
    if not smoke:
        assert capacity["capacity_ratio"] >= 2.0, (
            f"capacity ratio {capacity['capacity_ratio']:.2f} < 2x")

    payload = {
        "arch": ARCH,
        "capacity": capacity, "diurnal": diurnal, "admission": admission,
        "meta": {"backend": jax.default_backend(), "smoke": smoke},
    }
    path = save_json("BENCH_prefix.json", payload)
    if verbose:
        c = capacity
        print(f"capacity @ {c['cache_rows']} cache rows, "
              f"{c['shared_token_frac']:.0%} shared prompt tokens: "
              f"{c['shared']['peak_concurrent']} vs "
              f"{c['private']['peak_concurrent']} concurrent "
              f"({c['capacity_ratio']:.2f}x), hit rate {hit_rate:.0%}, "
              f"identical={c['tokens_identical']} "
              f"solo={c['solo_identical']}")
        d = diurnal
        print(f"diurnal waves {d['wave_sizes']}: shared "
              f"{d['shared_req_s']:.2f} vs private "
              f"{d['private_req_s']:.2f} req/s ({d['speedup']:.2f}x)")
        a = admission
        print(f"admission: hit {a['admit_ms_hit']:.1f}ms vs miss "
              f"{a['admit_ms_miss']:.1f}ms ({a['hit_speedup']:.2f}x), "
              f"prefill tokens saved {a['prefill_tokens_saved_frac']:.0%}")
        print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
