"""Shared benchmark plumbing: run a trace through every policy, format
paper-style tables, write JSON artifacts."""
from __future__ import annotations

import copy
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import (ClusterState, InterferenceModel, Simulator,
                        make_scheduler, paper_interference_model)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")

POLICIES = ("fifo", "sjf", "srsf", "tiresias", "pollux", "sjf-ffs",
            "sjf-bsbf")


def run_policy(policy: str, jobs, *, n_servers=16, gpus_per_server=4,
               interference: Optional[InterferenceModel] = None,
               capacity_gb: float = 11.0, engine: Optional[str] = None):
    cluster = ClusterState(n_servers=n_servers,
                           gpus_per_server=gpus_per_server,
                           gpu_capacity_bytes=capacity_gb * 2 ** 30)
    sim = Simulator(cluster, copy.deepcopy(jobs), make_scheduler(policy),
                    interference=interference or paper_interference_model(),
                    engine=engine)
    return sim.run()


def run_all_policies(jobs, policies: Sequence[str] = POLICIES, **kw
                     ) -> Dict[str, object]:
    out = {}
    for p in policies:
        t0 = time.time()
        out[p] = run_policy(p, jobs, **kw)
        out[p].wall_seconds = time.time() - t0
    return out


def table(results: Dict[str, object], title: str) -> str:
    return policy_table({p: r.summary() for p, r in results.items()}, title)


def save_json(name: str, payload) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def summaries(results: Dict[str, object]) -> Dict[str, Dict]:
    return {p: r.summary() for p, r in results.items()}


def policy_table(payload: Dict[str, Dict], title: str) -> str:
    """`table()` over {policy: summary} dicts (sweep-row payloads)."""
    lines = [title, f"{'policy':<10} {'makespan':>10} {'avg JCT':>10} "
                    f"{'JCT lg':>9} {'JCT sm':>9} {'queue':>9} "
                    f"{'q lg':>8} {'q sm':>8}"]
    for p, s in payload.items():
        lines.append(
            f"{p:<10} {s['makespan']:>10.1f} {s['avg_jct']:>10.1f} "
            f"{s['avg_jct_large']:>9.1f} {s['avg_jct_small']:>9.1f} "
            f"{s['avg_queue']:>9.1f} {s['avg_queue_large']:>8.1f} "
            f"{s['avg_queue_small']:>8.1f}")
    return "\n".join(lines)
