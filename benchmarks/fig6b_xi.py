"""Figure 6b — sensitivity to the interference ratio: inject a global xi
for all sharing pairs and compare the sharing policies. The paper's
finding: at small xi (<=1.25) BSBF == FFS (share everything); at large xi
BSBF avoids harmful pairs and wins by ~8-13%."""
from __future__ import annotations

from repro.core import InterferenceModel, simulation_trace

from .common import run_all_policies, save_json


def run(verbose: bool = True):
    payload = {}
    for xi in (1.0, 1.25, 1.5, 1.75, 2.0):
        jobs = simulation_trace(n_jobs=240)
        interf = InterferenceModel(global_xi=xi)
        results = run_all_policies(
            jobs, n_servers=16, gpus_per_server=4,
            policies=("sjf", "sjf-ffs", "sjf-bsbf"), interference=interf)
        payload[f"xi={xi}"] = {p: r.summary()["avg_jct"]
                               for p, r in results.items()}
        if verbose:
            row = payload[f"xi={xi}"]
            gain = (row["sjf-ffs"] - row["sjf-bsbf"]) / row["sjf-ffs"] * 100
            print(f"xi={xi}: sjf={row['sjf']:.0f}s ffs={row['sjf-ffs']:.0f}s "
                  f"bsbf={row['sjf-bsbf']:.0f}s (bsbf vs ffs: {gain:+.1f}%)")
    save_json("fig6b_xi.json", payload)
    return payload


if __name__ == "__main__":
    run()
