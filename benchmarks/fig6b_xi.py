"""Figure 6b — sensitivity to the interference ratio: inject a global xi
for all sharing pairs and compare the sharing policies. The paper's
finding: at small xi (<=1.25) BSBF == FFS (share everything); at large xi
BSBF avoids harmful pairs and wins by ~8-13%. All (xi, policy) scenarios
fan out as one parallel sweep."""
from __future__ import annotations

from repro.core.sweep import ScenarioSpec, run_sweep

from .common import save_json

XIS = (1.0, 1.25, 1.5, 1.75, 2.0)
SHARING_POLICIES = ("sjf", "sjf-ffs", "sjf-bsbf")


def run(verbose: bool = True, workers=None):
    specs = [
        ScenarioSpec(policy=p, n_jobs=240, global_xi=xi,
                     n_servers=16, gpus_per_server=4, tag=f"xi={xi}")
        for xi in XIS for p in SHARING_POLICIES
    ]
    rows = run_sweep(specs, workers=workers)
    payload = {}
    for row in rows:
        payload.setdefault(row["tag"], {})[row["policy"]] = \
            row["summary"]["avg_jct"]
    if verbose:
        for xi in XIS:
            r = payload[f"xi={xi}"]
            gain = (r["sjf-ffs"] - r["sjf-bsbf"]) / r["sjf-ffs"] * 100
            print(f"xi={xi}: sjf={r['sjf']:.0f}s ffs={r['sjf-ffs']:.0f}s "
                  f"bsbf={r['sjf-bsbf']:.0f}s (bsbf vs ffs: {gain:+.1f}%)")
    save_json("fig6b_xi.json", payload)
    return payload


if __name__ == "__main__":
    run()
