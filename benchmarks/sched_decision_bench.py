"""Scheduler-decision benchmark: batched vs scalar sharing-decision core
at datacenter scale (DESIGN.md §10).

For each cluster size in {64, 256, 1024, 4096} GPUs the bench runs the
same heavy-tailed :func:`repro.core.trace.datacenter_trace` workload
through SJF-BSBF twice — once with the scalar per-(pending, donor)
Algorithm-2 reference, once with the vectorized
:mod:`repro.core.pair_batch` core — and reports per-scheduling-pass
latency, end-to-end events/sec, and the speedup. The two runs must
produce *identical* schedules (asserted on ``avg_jct`` and event
counts); the acceptance bar is a >= 3x scheduler-pass speedup at the
1024-GPU / 5k-job scenario.

Usage:
    PYTHONPATH=src python -m benchmarks.sched_decision_bench
    PYTHONPATH=src python -m benchmarks.sched_decision_bench --smoke
    PYTHONPATH=src python -m benchmarks.sched_decision_bench \
        --sizes 64,256 --out artifacts/bench/BENCH_sched_decision.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

from repro.core import (ClusterState, Simulator, make_scheduler,
                        paper_interference_model)
from repro.core.trace import datacenter_trace

# gpus -> n_jobs for the full bench (the 1024/5000 point is the
# acceptance scenario; 4096/10000 is the ROADMAP's Philly/Helios regime)
DEFAULT_JOBS = {64: 600, 256: 2000, 1024: 5000, 4096: 10000}
GPUS_PER_SERVER = 8
GB = 2 ** 30


class TimedScheduler:
    """Transparent wrapper measuring time spent inside ``schedule()``;
    forwards the attributes the engine reads from the policy."""

    def __init__(self, inner) -> None:
        self.inner = inner
        self.name = inner.name
        self.preemptive = inner.preemptive
        self.tick_interval = inner.tick_interval
        self.tick_only = inner.tick_only
        self.reads_running_progress = inner.reads_running_progress
        self.progress_scope = inner.progress_scope
        self.passes = 0
        self.seconds = 0.0

    def reset(self) -> None:
        self.inner.reset()

    def schedule(self, sim) -> None:
        t0 = time.perf_counter()
        self.inner.schedule(sim)
        self.seconds += time.perf_counter() - t0
        self.passes += 1


def run_once(policy: str, decision: str, n_gpus: int, n_jobs: int,
             seed: int, utilization: float) -> Dict:
    jobs = datacenter_trace(n_jobs=n_jobs, seed=seed, n_gpus=n_gpus,
                            utilization=utilization)
    cluster = ClusterState(n_servers=n_gpus // GPUS_PER_SERVER,
                           gpus_per_server=GPUS_PER_SERVER,
                           gpu_capacity_bytes=11 * GB)
    sched = TimedScheduler(make_scheduler(policy))
    sim = Simulator(cluster, jobs, sched,
                    interference=paper_interference_model(),
                    decision=decision, max_events=5_000_000)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    return {
        "decision": decision,
        "events": res.events,
        "avg_jct": res.avg_jct(),
        "makespan": res.makespan,
        "wall_seconds": wall,
        "events_per_sec": res.events / wall,
        "sched_passes": sched.passes,
        "sched_seconds": sched.seconds,
        "sched_pass_ms": 1e3 * sched.seconds / max(1, sched.passes),
    }


def run_size(policy: str, n_gpus: int, n_jobs: int, seed: int,
             utilization: float, verbose: bool = True) -> Dict:
    row: Dict = {"policy": policy, "n_gpus": n_gpus, "n_jobs": n_jobs,
                 "seed": seed, "utilization": utilization}
    for decision in ("scalar", "batched"):
        r = run_once(policy, decision, n_gpus, n_jobs, seed, utilization)
        row[decision] = r
        if verbose:
            print(f"  {decision:>7}: {r['wall_seconds']:8.2f}s wall  "
                  f"{r['events_per_sec']:9.0f} ev/s  "
                  f"{r['sched_pass_ms']:8.3f} ms/pass  "
                  f"avg_jct={r['avg_jct']:.3f}")
    a, b = row["scalar"], row["batched"]
    if a["avg_jct"] != b["avg_jct"] or a["events"] != b["events"]:
        raise AssertionError(
            f"decision paths diverged at {n_gpus} GPUs: "
            f"scalar avg_jct={a['avg_jct']!r} events={a['events']} vs "
            f"batched avg_jct={b['avg_jct']!r} events={b['events']}")
    row["identical_avg_jct"] = True
    row["sched_pass_speedup"] = a["sched_pass_ms"] / b["sched_pass_ms"]
    row["events_per_sec_speedup"] = (b["events_per_sec"]
                                     / a["events_per_sec"])
    if verbose:
        print(f"  => pass speedup {row['sched_pass_speedup']:.2f}x, "
              f"end-to-end {row['events_per_sec_speedup']:.2f}x")
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--policy", default="sjf-bsbf")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated GPU counts (default: 64,256,"
                         "1024,4096; jobs scale with the size)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override the per-size job count")
    ap.add_argument("--seed", type=int, default=0)
    # offered load of 1.5x capacity: the decision layer is exercised
    # hardest when jobs queue and every pass walks pending x donors (the
    # paper's own load sweep reaches 2.0x)
    ap.add_argument("--utilization", type=float, default=1.5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (64 GPUs, 200 jobs)")
    ap.add_argument("--out", default=os.path.join(
        "artifacts", "bench", "BENCH_sched_decision.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        scenarios = [(64, 200)]
    else:
        sizes = ([int(s) for s in args.sizes.split(",")] if args.sizes
                 else sorted(DEFAULT_JOBS))
        scenarios = [(g, args.jobs or DEFAULT_JOBS.get(g, 5 * g))
                     for g in sizes]

    rows = []
    for n_gpus, n_jobs in scenarios:
        print(f"[{args.policy}] {n_gpus} GPUs / {n_jobs} jobs "
              f"(utilization={args.utilization})")
        rows.append(run_size(args.policy, n_gpus, n_jobs, args.seed,
                             args.utilization))

    payload = {
        "bench": "sched_decision",
        "policy": args.policy,
        "smoke": bool(args.smoke),
        "gpus_per_server": GPUS_PER_SERVER,
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")

    # acceptance: >= 3x pass speedup at the 1024-GPU scenario
    for row in rows:
        if row["n_gpus"] == 1024 and row["sched_pass_speedup"] < 3.0:
            print(f"WARNING: pass speedup {row['sched_pass_speedup']:.2f}x "
                  f"below the 3x bar at 1024 GPUs")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
