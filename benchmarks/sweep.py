"""Parallel scenario-sweep CLI — fan policy x load x seed grids across
worker processes and aggregate `SimResults.summary()` rows to JSON/CSV.

    PYTHONPATH=src python -m benchmarks.sweep --policies sjf,sjf_bsbf --jobs 40
    PYTHONPATH=src python -m benchmarks.sweep --policies all --jobs 240 \
        --loads 0.5,1.0,1.5,2.0 --seeds 0,1,2 --workers 8 --out load_sweep

Scenario seeding is deterministic: each worker rebuilds its trace from
the spec fields alone, so aggregate output is byte-identical for any
worker count (see repro.core.sweep / DESIGN.md §9).
"""
from __future__ import annotations

import argparse
import os
import time

from repro.core.sweep import (ScenarioSpec, grid, normalize_policy,
                              run_sweep, summary_table, write_csv,
                              write_json)

from .common import ARTIFACTS, POLICIES


def _floats(text: str):
    return tuple(float(x) for x in text.split(",") if x)


def _ints(text: str):
    return tuple(int(x) for x in text.split(",") if x)


def build_specs(args) -> list:
    policies = (POLICIES if args.policies == "all"
                else tuple(normalize_policy(p)
                           for p in args.policies.split(",") if p))
    common = dict(
        n_jobs=args.jobs,
        trace=args.trace,
        n_servers=args.servers,
        gpus_per_server=args.gpus_per_server,
        capacity_gb=args.capacity_gb,
        global_xi=args.xi,
        engine=args.engine,
    )
    return grid(policies, seeds=_ints(args.seeds),
                loads=_floats(args.loads), **common)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--policies", default="all",
                    help="comma list (sjf,sjf-bsbf,... — underscores ok) "
                         "or 'all'")
    ap.add_argument("--jobs", type=int, default=240)
    ap.add_argument("--loads", default="1.0", help="comma list of load "
                    "scales (Fig. 6a style interarrival compression)")
    ap.add_argument("--seeds", default="0", help="comma list of trace seeds")
    ap.add_argument("--trace", choices=("simulation", "physical"),
                    default="simulation")
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--gpus-per-server", type=int, default=4)
    ap.add_argument("--capacity-gb", type=float, default=11.0)
    ap.add_argument("--xi", type=float, default=None,
                    help="inject a global interference ratio (Fig. 6b)")
    ap.add_argument("--engine", choices=("heap", "scan"), default=None,
                    help="simulator engine (default: REPRO_SIM_ENGINE "
                         "env, else heap)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: min(scenarios, CPUs))")
    ap.add_argument("--out", default="sweep",
                    help="artifact basename (writes <out>.json + <out>.csv)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    specs = build_specs(args)
    if not specs:
        ap.error("no scenarios selected (check --policies/--seeds/--loads)")
    t0 = time.time()
    rows = run_sweep(specs, workers=args.workers)
    wall = time.time() - t0

    if not args.quiet:
        print(summary_table(
            rows, f"sweep: {len(rows)} scenarios in {wall:.1f}s "
                  f"(jobs={args.jobs}, trace={args.trace})"))
    json_path = write_json(rows, os.path.join(ARTIFACTS, args.out + ".json"))
    csv_path = write_csv(rows, os.path.join(ARTIFACTS, args.out + ".csv"))
    if not args.quiet:
        sim_time = sum(r["wall_seconds"] for r in rows)
        print(f"wrote {json_path} and {csv_path} "
              f"({sim_time:.1f}s of simulation in {wall:.1f}s wall)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
