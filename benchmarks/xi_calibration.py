"""Interference-ratio calibration on the co-schedule mini-testbed: run two
real (reduced) training jobs as one fused program on this host, measure
structural xi (Fig. 3 analogue), and verify the structural model brackets
the measurement."""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.coschedule import JobSpec, measure_pair, structural_xi

from .common import save_json

PAIRS = (("minicpm-2b", "qwen2-vl-2b"),
         ("minicpm-2b", "minicpm-2b"))


def run(verbose: bool = True, iters: int = 6):
    # iters default was 2 when each step went through the slow jnp path;
    # with the trainable kernel path and donated train steps the per-step
    # cost is low enough to average over more iterations.
    payload = {}
    for a, b in PAIRS:
        sa = JobSpec(dataclasses.replace(get_config(a).reduced(),
                                         dtype="float32"),
                     batch=4, seq=64, seed=0)
        sb = JobSpec(dataclasses.replace(get_config(b).reduced(),
                                         dtype="float32"),
                     batch=4, seq=64, accum_steps=2, seed=1)
        r = measure_pair(sa, sb, iters=iters)
        # structural prediction from solo times only
        pred_a = structural_xi(r["t_a_solo"], r["t_b_solo"])
        pred_b = structural_xi(r["t_b_solo"], r["t_a_solo"])
        # t_a_solo / t_b_solo / t_pair are per-step walltimes (seconds),
        # averaged over `iters` post-warmup steps
        payload[f"{a}+{b}"] = {**r, "xi_a_structural": pred_a,
                               "xi_b_structural": pred_b}
        if verbose:
            print(f"{a}+{b}: measured xi=({r['xi_a']:.2f},{r['xi_b']:.2f}) "
                  f"structural=({pred_a:.2f},{pred_b:.2f}) "
                  f"[{iters} iters, pair {r['t_pair']:.3f}s/step]")
    save_json("xi_calibration.json", payload)
    return payload


if __name__ == "__main__":
    run()
