"""Closed-loop calibration entry point (DESIGN.md §13): run the
calibration pipeline — really train each (reduced) arch on this host
through the schedule executor, fit the Eq.-3 alpha/beta from the
measured sub-batch sweep, measure pairwise xi on the fused pair
programs — and persist the **versioned artifact**
``artifacts/bench/calibration.json`` that the simulator side loads
(``InterferenceModel.from_artifact``, ``repro.core.calibrated_trace``).
Also writes the historical ``xi_calibration.json`` summary with the
structural-model predictions next to the measurements (Fig. 3
analogue)."""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.configs import get_config
from repro.core.calibration import run_calibration, save_artifact
from repro.core.coschedule import JobSpec

from .common import ARTIFACTS, save_json

ARCHS = ("minicpm-2b", "qwen2-vl-2b")
CALIBRATION_PATH = os.path.join(ARTIFACTS, "calibration.json")


def build_specs(archs=ARCHS, batch: int = 4, seq: int = 64):
    specs = {}
    for i, name in enumerate(archs):
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        specs[name] = JobSpec(cfg, batch=batch, seq=seq, seed=i)
    return specs


def run(verbose: bool = True, iters: int = 6, smoke: bool = False):
    if smoke:
        # CI configuration: 2 tiny archs, 1 cross pair, short timing loop
        specs = build_specs(batch=2, seq=32)
        payload = run_calibration(specs, iters=2,
                                  pairs=[tuple(sorted(specs))])
    else:
        specs = build_specs()
        payload = run_calibration(specs, iters=iters)
    save_artifact(payload, CALIBRATION_PATH)

    summary = {}
    for key, entry in payload["pairs"].items():
        summary[key] = dict(entry)
        if verbose:
            print(f"{key}: measured xi=({entry['xi_a']:.2f},"
                  f"{entry['xi_b']:.2f}) structural="
                  f"({entry['xi_a_structural']:.2f},"
                  f"{entry['xi_b_structural']:.2f}) "
                  f"[pair {entry['t_pair']:.3f}s/step]")
    for name, entry in payload["archs"].items():
        if verbose:
            print(f"{name}: t_comp(b) ~= {entry['alpha_comp']:.4f} + "
                  f"{entry['beta_comp']:.4f}*b  (sweep over "
                  f"{entry['sweep']['sub_batches']})")
    save_json("xi_calibration.json", {
        "pairs": summary,
        "archs": {n: {k: e[k] for k in ("alpha_comp", "beta_comp",
                                        "t_iter_solo", "sweep")}
                  for n, e in payload["archs"].items()},
        "calibration_artifact": CALIBRATION_PATH,
    })
    if verbose:
        print(f"calibration artifact -> {CALIBRATION_PATH}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 2 tiny archs, 1 pair, 2 iters")
    args = ap.parse_args(argv)
    run(iters=args.iters, smoke=args.smoke)


if __name__ == "__main__":
    main()
