"""Table IV — the 480-job heavy-load simulation (paper: sharing policies
dominate; SJF-BSBF improves avg JCT by ~17% over SJF-FFS)."""
from __future__ import annotations

from .table3_240 import run as run_240


def run(seed: int = 0, verbose: bool = True, workers=None):
    return run_240(n_jobs=480, seed=seed, verbose=verbose,
                   name="table4_480", workers=workers)


if __name__ == "__main__":
    run()
