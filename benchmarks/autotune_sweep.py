"""Kernel autotune sweep driver: time every block/chunk candidate per
shape class against the XLA reference, persist the winners.

Writes two artifacts:

* ``artifacts/bench/autotune.json`` — the versioned table
  ``repro.kernels.ops`` consults at call time (winner config per shape
  class, or ``backend: "ref"`` where XLA beats every Pallas candidate).
* ``artifacts/bench/BENCH_autotune.json`` — the full sweep record: every
  candidate's walltime per class, the chosen config, and its
  ``speedup_vs_default`` (>= 1.0 by construction — the hard-coded
  default is always in the measured candidate set).

On this CPU container the kernels run in interpret mode, so the sweep
mostly selects the reference for flash-attention (XLA wins at interpret
overheads) and tuned chunks for the SSD scan; a TPU re-run overwrites
the table with native-kernel timings (entries are keyed by backend and
ignored when loaded on a different one).

``flash_decode_paged`` classes (page_size x head_dim x dtype, keyed on
the exact page size) carry no block knobs: their sweep is the pure
kernel-vs-reference routing decision, with the gather-oracle reference
bitwise identical to the engine's jnp paged path.

Usage:
    PYTHONPATH=src python -m benchmarks.autotune_sweep            # full
    PYTHONPATH=src python -m benchmarks.autotune_sweep --smoke    # CI
"""
from __future__ import annotations

import argparse

from repro.kernels import autotune

from .common import save_json


def run(smoke: bool = False, iters=None, verbose: bool = True):
    table, bench = autotune.run_autotune(smoke=smoke, iters=iters)
    table_path = autotune.save_artifact(table)
    bench_path = save_json("BENCH_autotune.json", bench)
    if verbose:
        for key, e in sorted(table["entries"].items()):
            cfg = {k: v for k, v in e.items()
                   if k in ("block_q", "block_k", "chunk")}
            print(f"{key:<42} -> {e['backend']:<6} {cfg} "
                  f"{e['speedup_vs_default']:.2f}x vs default "
                  f"(best {e['t_best'] * 1e3:.2f}ms, "
                  f"ref {e['t_ref'] * 1e3:.2f}ms)")
        print(f"wrote {table_path}")
        print(f"wrote {bench_path}")
    return table, bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny candidate grid / few iters for CI")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, iters=args.iters)
