"""Table III — 240-job simulation on the 64-GPU cluster (16 servers x 4):
average JCT and queueing for all/large/small jobs per policy. The
policies fan out across worker processes via repro.core.sweep."""
from __future__ import annotations

from repro.core.sweep import grid, rows_by_policy, run_sweep

from .common import POLICIES, policy_table, save_json


def run(n_jobs: int = 240, seed: int = 0, verbose: bool = True,
        name: str = "table3_240", workers=None):
    specs = grid(POLICIES, seeds=(seed,), n_jobs=n_jobs,
                 n_servers=16, gpus_per_server=4)
    rows = run_sweep(specs, workers=workers)
    payload = rows_by_policy(rows)
    if verbose:
        print(policy_table(payload, f"Table ({n_jobs} jobs, 16x4 GPUs)"))
    save_json(f"{name}.json", payload)
    s = payload
    if verbose:
        print(f"  BSBF vs FFS JCT: "
              f"{s['sjf-bsbf']['avg_jct']:.1f} vs {s['sjf-ffs']['avg_jct']:.1f}; "
              f"small-job queue BSBF {s['sjf-bsbf']['avg_queue_small']:.1f}s "
              f"(lowest: {min(v['avg_queue_small'] for v in s.values()):.1f}s)")
    return payload


if __name__ == "__main__":
    run()
