"""Fleet-recovery benchmark (DESIGN.md §17): detection latency,
recovery time, and goodput of the master/agent runtime vs agent-failure
rate.

§16's ``fault_recovery`` benchmark measures failure cost inside the
*simulator*; this one measures it in the *real* multi-process runtime:
a 2-agent fleet replays the 4-job replay-validation schedule while
:class:`ChaosKiller` SIGKILLs agents at a ladder of scripted rates
(0, 1, 2 kills per run, with respawn enabled so capacity recovers).
Per level it reports:

* **detection_latency_s** — chaos kill to DEAD declaration, per death
  (the SIGKILL fast path: socket EOF + confirmed process exit).
* **recovery_time_s** — DEAD declaration to the replacement lease
  being dispatched, per death.
* **goodput** — useful steps over executed steps,
  ``plan_steps / (steps_executed + steps_lost)``: work redone after a
  kill (steps past the victim's last checkpoint) is the overhead.
* **makespan_s**, redispatch/fence counters, and ``bit_exact`` — final
  checkpoint CRCs vs the failure-free run at level 0 (recovery must
  never change the answer).

Writes ``artifacts/bench/BENCH_fleet.json``. Smoke mode (CI) runs the
0- and 1-kill levels and asserts goodput >= 0.9 under failure plus
bit-exactness across levels.

Usage:
    PYTHONPATH=src python -m benchmarks.fleet_recovery            # full
    PYTHONPATH=src python -m benchmarks.fleet_recovery --smoke    # CI
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core import (ClusterState, InterferenceModel, Job, PerfParams,
                        Simulator)
from repro.core.schedulers import SJF_BSBF
from repro.launch.cluster import JobSpec, plan_from_sim
from repro.launch.fleet import (ChaosKiller, FleetConfig, FleetMaster,
                                KillSpec)

from .common import save_json

GB = 2 ** 30

# ladder: (label, kill specs) — kills fire on watermark thresholds so
# the same level replays the same failure scenario
LEVELS = (
    ("none", ()),
    ("one-kill", (KillSpec(agent="a0", after_steps=2),)),
    ("two-kills", (KillSpec(agent="a0", after_steps=2),
                   KillSpec(agent="a1", after_steps=4))),
)
SMOKE_LEVELS = LEVELS[:2]


def _perf(alpha=0.01, beta=0.01) -> PerfParams:
    return PerfParams(alpha_comp=alpha, beta_comp=beta, alpha_comm=0.0,
                      beta_comm=0.0, msg_bytes=0.0, delta=2.0,
                      mem_base=4.0 * GB, mem_per_sample=0.25 * GB,
                      param_bytes=1e8, n_workers=1)


def _replay_plan(iters_a: float):
    """The 4-job replay-validation scenario: donor A on both GPUs,
    sharers B/C (3-way group with donor reconfigs), late D."""
    pa, pb = _perf(), _perf(beta=0.008)
    t_a = pa.t_iter(4)
    jobs = [Job(jid=0, model="m0", arrival=0.0, gpus=2, iters=iters_a,
                batch=4, perf=pa),
            Job(jid=1, model="m1", arrival=2 * t_a, gpus=1, iters=3.0,
                batch=4, perf=pb),
            Job(jid=2, model="m1", arrival=4 * t_a, gpus=1, iters=4.0,
                batch=4, perf=pb),
            Job(jid=3, model="m0", arrival=6 * t_a, gpus=1, iters=3.0,
                batch=4, perf=pa)]
    cap = pa.mem_bytes(2) + pb.mem_bytes(2) + 0.25 * 0.25 * GB
    interf = InterferenceModel()
    for a in ("m0", "m1"):
        for b in ("m0", "m1"):
            interf.set_pair(a, b, 1.3, 1.3)
    cluster = ClusterState(n_servers=1, gpus_per_server=2,
                           gpu_capacity_bytes=cap)
    sim = Simulator(cluster, jobs, SJF_BSBF(donor_reconfig=True),
                    interference=interf, reconfig_on_release=True)
    sim.run()
    plan = plan_from_sim(sim.log, sim.jobs, sim.interference, cap,
                         names={0: "A", 1: "B", 2: "C", 3: "D"})

    def spec(seed):
        cfg = dataclasses.replace(get_config("minicpm-2b").reduced(),
                                  dtype="float32")
        return JobSpec(cfg, batch=4, seq=32, seed=seed)

    specs = {"A": spec(0), "B": spec(1), "C": spec(2), "D": spec(3)}
    return plan, specs


def _run_level(label: str, kills, plan, specs, *,
               step_sleep: float) -> Dict[str, object]:
    plan_steps = sum(q for ph in plan.phases for _, q in ph.quotas)
    cfg = FleetConfig(checkpoint_every=1, step_sleep=step_sleep,
                      heartbeat_interval=0.1, suspect_after=0.5,
                      dead_after=1.0, respawn=bool(kills))
    chaos = ChaosKiller(list(kills)) if kills else None
    t0 = time.time()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        with FleetMaster(ckpt_dir, config=cfg, chaos=chaos) as master:
            master.start(n_agents=2)
            up = time.time()
            report = master.run_plan(plan, specs)
            makespan = time.time() - up
            events = list(master.events)
            stats = dict(master.stats)
    deaths = [e for e in events if e["kind"] == "agent_dead"]
    redisp = [e for e in events if e["kind"] == "lease_redispatch"]
    losts = {e["agent"]: e["t"] for e in events
             if e["kind"] == "agent_dead"}
    detection = [e["detection_latency"] for e in deaths if e["killed"]]
    # recovery: each dead agent's DEAD declaration -> the first
    # redispatch dispatched at or after it
    recovery: List[float] = []
    for agent, t_dead in sorted(losts.items(), key=lambda kv: kv[1]):
        later = [e["t"] for e in redisp if e["t"] >= t_dead]
        if later:
            recovery.append(min(later) - t_dead)
    executed = stats["steps_executed"] + stats["steps_lost"]
    goodput = plan_steps / executed if executed else 1.0
    return {
        "level": label,
        "kills": len([e for e in events if e["kind"] == "chaos_kill"]),
        "plan_steps": plan_steps,
        "steps_executed": stats["steps_executed"],
        "steps_lost": stats["steps_lost"],
        "goodput": goodput,
        "detection_latency_s": detection,
        "recovery_time_s": recovery,
        "redispatches": stats["redispatches"],
        "fenced": stats["fenced"],
        "respawns": stats["respawns"],
        "makespan_s": makespan,
        "spawn_s": up - t0,
        "crcs": {name: report[name]["crc"] for name in sorted(specs)},
        "finished": all(report[n]["finished"] for n in specs),
    }


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 0- and 1-kill levels, small plan, "
                         "assert goodput and bit-exactness")
    ap.add_argument("--iters-a", type=float, default=None,
                    help="donor job length (default 6 smoke / 12 full)")
    ap.add_argument("--step-sleep", type=float, default=0.3,
                    help="agent pause between fused calls so kills land "
                         "mid-lease")
    args = ap.parse_args(argv)

    levels = SMOKE_LEVELS if args.smoke else LEVELS
    iters_a = args.iters_a or (6.0 if args.smoke else 12.0)
    plan, specs = _replay_plan(iters_a)

    rows = []
    for label, kills in levels:
        t0 = time.time()
        row = _run_level(label, kills, plan, specs,
                         step_sleep=args.step_sleep)
        row["wall_s"] = time.time() - t0
        rows.append(row)
        det = ", ".join(f"{d * 1e3:.0f}ms" for d in
                        row["detection_latency_s"]) or "-"
        rec = ", ".join(f"{r * 1e3:.0f}ms" for r in
                        row["recovery_time_s"]) or "-"
        print(f"[{label:>10}] kills={row['kills']} "
              f"goodput={row['goodput']:.3f} detect=[{det}] "
              f"recover=[{rec}] makespan={row['makespan_s']:.1f}s")

    baseline = rows[0]
    for row in rows:
        row["bit_exact"] = row["crcs"] == baseline["crcs"]

    payload = {
        "benchmark": "fleet_recovery",
        "agents": 2,
        "iters_a": iters_a,
        "step_sleep": args.step_sleep,
        "smoke": args.smoke,
        "levels": rows,
    }
    path = save_json("BENCH_fleet.json", payload)
    print(f"wrote {path}")

    if args.smoke:
        assert all(r["finished"] for r in rows), "jobs did not finish"
        assert all(r["bit_exact"] for r in rows), \
            "recovery changed final checkpoint CRCs"
        failed = rows[-1]
        assert failed["kills"] >= 1, "chaos kill did not fire"
        assert failed["goodput"] >= 0.9, \
            f"goodput {failed['goodput']:.3f} < 0.9 under failure"
        assert all(d <= 1.5 for d in failed["detection_latency_s"]), \
            "detection slower than dead_after + slack"
        print("smoke OK")


if __name__ == "__main__":
    main()
