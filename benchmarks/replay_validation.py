"""Replay/validation harness (DESIGN.md §13, Table-2 style): simulate a
4-job SJF-BSBF schedule whose performance model is entirely HOST-MEASURED
(calibration pipeline: fitted Eq.-3 alpha/beta + measured pairwise xi —
no synthesized tables anywhere on this path), then EXECUTE that schedule
on this host with the schedule-driven executor and report per-job
predicted-vs-measured execution time.

The scenario is constructed so the schedule exercises the full event
model: job A holds both GPUs of a 1-server/2-GPU cluster; B and C are
admitted onto A's GPUs (a 3-way shared group), with the GPU memory
capacity sized so B's admission requires the donor-rescaling extension —
a mid-run (τ, sub-batch) reconfiguration of A at the sharing time point;
when A's last sharer departs, ``reconfig_on_release`` restores A's full
sub-batch (a second mid-run reconfiguration). D arrives while both GPUs
are doubly tenanted and queues.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.core import ClusterState, InterferenceModel, Job, Simulator
from repro.core.calibration import (load_artifact, profiles_from_artifact,
                                    run_calibration)
from repro.core.schedulers import SJF_BSBF
from repro.launch.cluster import ScheduleExecutor, plan_from_sim

from .common import ARTIFACTS, save_json
from .xi_calibration import build_specs

ARCH_A = "minicpm-2b"     # donor arch (jobs A and D)
ARCH_B = "qwen2-vl-2b"    # sharer arch (jobs B and C)
# canonical artifact (owned by benchmarks.xi_calibration) for --artifact
CALIBRATION_PATH = os.path.join(ARTIFACTS, "calibration.json")


MEM_BASE = 4.0 * 2 ** 30          # scenario memory geometry: the TIMING
MEM_PER_SAMPLE = 0.25 * 2 ** 30   # side is measured (alpha/beta, xi);
                                  # memory is sized so the schedule must
                                  # exercise the (τ, sub-batch) machinery


def build_scenario(payload, iters_a: int = 16):
    """4 jobs + a capacity forcing the (τ, sub-batch) structure. The
    iteration-time coefficients and xi come from the calibration
    artifact; the memory footprint uses the uniform scenario geometry
    above — capacity admits donor@B/2 + sharer@B/2 but not
    donor@B + sharer@1, so B's admission requires the donor-rescaling
    reconfiguration and every sharer runs gradient-accumulated."""
    from repro.core.perf_model import scaled
    profs = profiles_from_artifact(payload)
    geom = dict(mem_base=MEM_BASE, mem_per_sample=MEM_PER_SAMPLE)
    pa = scaled(profs[ARCH_A].params, **geom)
    pb = scaled(profs[ARCH_B].params, **geom)
    batch_a = profs[ARCH_A].default_batch
    batch_b = profs[ARCH_B].default_batch
    half_a, half_b = max(1, batch_a // 2), max(1, batch_b // 2)
    slack = 0.25 * MEM_PER_SAMPLE
    cap = pa.mem_bytes(half_a) + max(pb.mem_bytes(half_b),
                                     pa.mem_bytes(half_a)) + slack
    assert pa.mem_bytes(batch_a) <= cap, "A must fit alone at full batch"
    assert pa.mem_bytes(batch_a) + pb.mem_bytes(1) > cap, \
        "sharer must not fit beside an unreconfigured donor"
    t_a = pa.t_iter(batch_a)
    # Theorem 1 with measured xi ~= 2-2.5 only admits a sharer whose
    # remaining work is a small fraction of the donor's (and the
    # donor-rescaling variant additionally charges the donor's slowdown,
    # roughly R_A * 4*beta against the sharer's queue-jump gain), so the
    # donor runs long and the sharers are short.
    jobs = [
        Job(jid=0, model=ARCH_A, arrival=0.0, gpus=2,
            iters=float(iters_a), batch=batch_a, perf=pa),
        Job(jid=1, model=ARCH_B, arrival=2.0 * t_a, gpus=1,
            iters=float(max(2, iters_a // 12)), batch=batch_b, perf=pb),
        Job(jid=2, model=ARCH_B, arrival=4.0 * t_a, gpus=1,
            iters=float(max(3, iters_a // 8)), batch=batch_b, perf=pb),
        Job(jid=3, model=ARCH_A, arrival=6.0 * t_a, gpus=1,
            iters=float(max(2, iters_a // 12)), batch=batch_a, perf=pa),
    ]
    return jobs, cap


def _structure(log, jobs):
    """Schedule-shape facts for the artifact: largest sharing component
    and the mid-run reconfiguration events."""
    placements, by_gpu = {}, {}
    max_component = 0
    reconfigs = []
    for entry in log:
        kind = entry[1]
        if kind == "start":
            placements[entry[2]] = set(entry[3])
            for g in entry[3]:
                by_gpu.setdefault(g, set()).add(entry[2])
            # component of the newly placed job
            comp, frontier = set(), {entry[2]}
            while frontier:
                j = frontier.pop()
                comp.add(j)
                for g in placements.get(j, ()):
                    frontier.update(by_gpu[g] - comp)
            max_component = max(max_component, len(comp))
        elif kind == "finish":
            for g in placements.pop(entry[2], ()):
                by_gpu[g].discard(entry[2])
        elif kind == "reconfig":
            reconfigs.append({"t": entry[0], "jid": entry[2],
                              "sub_batch": entry[3],
                              "accum_steps": entry[4]})
    return max_component, reconfigs


def run(verbose: bool = True, smoke: bool = False,
        artifact: str | None = None):
    if artifact:
        payload = load_artifact(artifact)
        archs = sorted(payload["archs"])
        if set(archs) != {ARCH_A, ARCH_B}:
            raise ValueError(
                f"artifact archs {archs} do not match the "
                f"scenario archs {sorted((ARCH_A, ARCH_B))}")
        # the physical jobs must match what the artifact measured
        # (artifact keys are registry arch names — see xi_calibration)
        entries = payload["archs"]
        batches = {entries[n]["batch"] for n in archs}
        seqs = {entries[n]["seq"] for n in archs}
        if len(batches) != 1 or len(seqs) != 1:
            raise ValueError("scenario needs uniform batch/seq across "
                             f"the artifact archs, got {batches}/{seqs}")
        specs = build_specs(archs, batch=batches.pop(), seq=seqs.pop())
    else:
        # self-contained: measure a scenario-sized calibration here and
        # embed it in the replay artifact. The canonical
        # artifacts/bench/calibration.json is owned by xi_calibration
        # and is deliberately NOT overwritten (pass --artifact to replay
        # against it instead).
        specs = build_specs((ARCH_A, ARCH_B), batch=4,
                            seq=32 if smoke else 48)
        payload = run_calibration(specs, iters=2 if smoke else 3)

    jobs, cap = build_scenario(payload, iters_a=24 if smoke else 40)
    cluster = ClusterState(n_servers=1, gpus_per_server=2,
                           gpu_capacity_bytes=cap)
    interference = InterferenceModel.from_artifact(payload)
    sim = Simulator(cluster, jobs, SJF_BSBF(donor_reconfig=True),
                    interference=interference, reconfig_on_release=True)
    res = sim.run()

    max_component, reconfigs = _structure(sim.log, sim.jobs)
    names = {0: "A", 1: "B", 2: "C", 3: "D"}
    plan = plan_from_sim(sim.log, sim.jobs, interference, cap, names=names)

    ex = ScheduleExecutor(donate=True)
    for jid, job in sim.jobs.items():
        arch = ARCH_A if job.model == ARCH_A else ARCH_B
        spec = dataclasses.replace(specs[arch], seed=10 + jid)
        ex.submit(names[jid], spec, int(job.iters))
    report = ex.execute(plan)

    rows = {}
    abs_errors = []
    for jid, job in sorted(sim.jobs.items()):
        name = names[jid]
        rep = report[name]
        rows[name] = {
            "model": job.model,
            "gpus": job.gpus,
            "iters": int(job.iters),
            "final_sub_batch": rep["sub_batch"],
            "reconfigs": rep["reconfigs"],
            "predicted_exec_s": rep["predicted_exec"],
            "measured_exec_s": rep["measured_exec"],
            "error": rep["error"],
            "predicted_jct_s": plan.predicted[name]["jct"],
        }
        abs_errors.append(abs(rep["error"]))
    payload_out = {
        "jobs": rows,
        "summary": {
            "mean_abs_error": sum(abs_errors) / len(abs_errors),
            "max_abs_error": max(abs_errors),
            "makespan_predicted_s": res.makespan,
        },
        "structure": {
            "max_sharing_group": max_component,
            "reconfig_events": reconfigs,
        },
        "executor": {"compiles": ex.compiles, "fused_calls": ex.calls},
        "calibration": {
            "archs": {n: {k: e[k] for k in ("alpha_comp", "beta_comp",
                                            "t_iter_solo")}
                      for n, e in payload["archs"].items()},
            "pairs": {k: {kk: e[kk] for kk in ("xi_a", "xi_b")}
                      for k, e in payload["pairs"].items()},
        },
    }
    save_json("replay_validation.json", payload_out)

    if verbose:
        print("Replay validation (predicted vs measured execution time)")
        print(f"{'job':<4} {'model':<14} {'iters':>5} {'b_final':>7} "
              f"{'pred (s)':>9} {'meas (s)':>9} {'error':>7}")
        for name, r in rows.items():
            print(f"{name:<4} {r['model']:<14} {r['iters']:>5} "
                  f"{r['final_sub_batch']:>7} "
                  f"{r['predicted_exec_s']:>9.3f} "
                  f"{r['measured_exec_s']:>9.3f} "
                  f"{100 * r['error']:>6.1f}%")
        print(f"mean |error| {100 * payload_out['summary']['mean_abs_error']:.1f}%"
              f"  max sharing group {max_component}"
              f"  reconfig events {len(reconfigs)}")
    return payload_out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter jobs and timing loops")
    ap.add_argument("--artifact", nargs="?", const=CALIBRATION_PATH,
                    default=None, metavar="PATH",
                    help="replay against an existing calibration.json "
                         "instead of measuring one here (default path: "
                         f"{CALIBRATION_PATH})")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, artifact=args.artifact)


if __name__ == "__main__":
    main()
