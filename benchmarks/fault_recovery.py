"""Fault-recovery benchmark (DESIGN.md §16): goodput and JCT inflation
vs failure rate, SJF-BSBF against SJF.

Failures burn a real share of multi-tenant GPU-hours (Philly: Jeon et
al. 1901.05758), so a sharing policy must justify itself under churn,
not just in the fault-free steady state. This benchmark replays one
trace through SJF and SJF-BSBF at a ladder of failure levels — each a
seeded :class:`repro.core.FaultModel` with per-job crash processes and
correlated server kills — and reports, per (policy, level):

* **goodput** — the fraction of GPU iteration-work that survived:
  ``sum(iters) / (sum(iters) + sum(lost_iters))``. Lost work is what
  failures rolled back past the last checkpoint.
* **JCT inflation** — avg JCT at this level over the same policy's
  fault-free avg JCT (1.0 = failures cost nothing).
* failure/preemption counts and makespan, for context.

Checkpointing (``checkpoint_interval`` iterations) bounds the rollback;
the ladder includes a no-checkpoint point so the artifact shows the
checkpoint interval doing its job.

The fault timeline is precomputed from the model seed alone, so both
policies face the *same* failure sequence at each level (the scheduler
changes which jobs are running when the hammer falls — that difference
is the measurement).

Writes ``artifacts/bench/BENCH_faults.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.fault_recovery            # full
    PYTHONPATH=src python -m benchmarks.fault_recovery --smoke    # CI
"""
from __future__ import annotations

import argparse
import copy
import time
from typing import Dict, List, Optional

from repro.core import (ClusterState, FaultModel, Simulator,
                        make_scheduler, paper_interference_model)
from repro.core.trace import datacenter_trace

from .common import save_json

GB = 2 ** 30
POLICIES = ("sjf", "sjf-bsbf")

# failure ladder: (label, job_mtbf s, server_mtbf s, ckpt interval iters)
LEVELS = (
    ("none", 0.0, 0.0, 200.0),
    ("low", 40_000.0, 200_000.0, 200.0),
    ("medium", 15_000.0, 80_000.0, 200.0),
    ("high", 6_000.0, 30_000.0, 200.0),
    ("high-nockpt", 6_000.0, 30_000.0, 0.0),
)
SMOKE_LEVELS = (LEVELS[0], LEVELS[2], LEVELS[4])


def _fault_model(job_mtbf: float, server_mtbf: float,
                 ckpt: float, seed: int) -> Optional[FaultModel]:
    if job_mtbf <= 0 and server_mtbf <= 0:
        return None
    return FaultModel(seed=seed, job_mtbf=job_mtbf,
                      server_mtbf=server_mtbf, server_repair=600.0,
                      correlated_servers=2, checkpoint_interval=ckpt)


def run_once(policy: str, jobs, *, n_servers: int, gpus_per_server: int,
             fault_model: Optional[FaultModel]) -> Dict:
    jobs = copy.deepcopy(jobs)
    cluster = ClusterState(n_servers=n_servers,
                           gpus_per_server=gpus_per_server,
                           gpu_capacity_bytes=11 * GB)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=paper_interference_model(),
                    fault_model=fault_model, max_events=20_000_000)
    t0 = time.time()
    res = sim.run()
    useful = sum(j.iters for j in jobs)
    lost = sum(j.lost_iters for j in jobs)
    return {
        "avg_jct": res.avg_jct(),
        "makespan": res.makespan,
        "goodput": useful / (useful + lost) if useful + lost else 1.0,
        "lost_iters": lost,
        "failures": sum(j.failures for j in jobs),
        "preemptions": sum(j.preemptions for j in jobs),
        "fault_events": sum(1 for e in sim.log
                            if e[1] in ("fail_job", "fail_server")),
        "wall_seconds": time.time() - t0,
    }


def run(smoke: bool = False, seed: int = 0, verbose: bool = True) -> Dict:
    n_jobs = 60 if smoke else 240
    n_servers = 8 if smoke else 16
    jobs = datacenter_trace(n_jobs=n_jobs, seed=seed,
                            n_gpus=n_servers * 4)
    levels = SMOKE_LEVELS if smoke else LEVELS

    rows: List[Dict] = []
    base_jct: Dict[str, float] = {}
    for label, job_mtbf, server_mtbf, ckpt in levels:
        fm = _fault_model(job_mtbf, server_mtbf, ckpt, seed)
        for policy in POLICIES:
            row = run_once(policy, jobs, n_servers=n_servers,
                           gpus_per_server=4, fault_model=fm)
            row.update(level=label, policy=policy, job_mtbf=job_mtbf,
                       server_mtbf=server_mtbf, checkpoint_interval=ckpt)
            if fm is None:
                base_jct[policy] = row["avg_jct"]
            row["jct_inflation"] = (row["avg_jct"] / base_jct[policy]
                                    if base_jct.get(policy) else 1.0)
            rows.append(row)

    payload = {
        "smoke": smoke, "seed": seed, "n_jobs": n_jobs,
        "n_gpus": n_servers * 4, "policies": list(POLICIES),
        "rows": rows,
    }
    path = save_json("BENCH_faults.json", payload)
    if verbose:
        print(f"{'level':<12} {'policy':<9} {'goodput':>8} "
              f"{'JCT x':>7} {'fails':>6} {'lost':>10}")
        for r in rows:
            print(f"{r['level']:<12} {r['policy']:<9} "
                  f"{r['goodput']:>8.4f} {r['jct_inflation']:>7.3f} "
                  f"{r['failures']:>6d} {r['lost_iters']:>10.0f}")
        print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small trace / fewer levels for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)
