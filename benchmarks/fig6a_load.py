"""Figure 6a — sensitivity to workload intensity: the 240-job trace scaled
0.5x-2x in submission rate (120..480 jobs at matching arrival rates).
All (load, policy) scenarios fan out as one parallel sweep."""
from __future__ import annotations

from repro.core.sweep import ScenarioSpec, run_sweep

from .common import POLICIES, save_json

SCALES = ((0.5, 120), (1.0, 240), (1.5, 360), (2.0, 480))


def run(verbose: bool = True, workers=None):
    specs = [
        ScenarioSpec(policy=p, n_jobs=n_jobs, load_scale=scale,
                     n_servers=16, gpus_per_server=4, tag=f"{scale}x")
        for scale, n_jobs in SCALES for p in POLICIES
    ]
    rows = run_sweep(specs, workers=workers)
    payload = {}
    for row in rows:
        payload.setdefault(row["tag"], {})[row["policy"]] = \
            row["summary"]["avg_jct"]
    if verbose:
        for scale, n_jobs in SCALES:
            r = payload[f"{scale}x"]
            print(f"load {scale}x ({n_jobs} jobs): " + ", ".join(
                f"{p}={r[p]:.0f}s" for p in POLICIES))
    save_json("fig6a_load.json", payload)
    return payload


if __name__ == "__main__":
    run()
