"""Figure 6a — sensitivity to workload intensity: the 240-job trace scaled
0.5x-2x in submission rate (120..480 jobs at matching arrival rates)."""
from __future__ import annotations

from repro.core import simulation_trace

from .common import POLICIES, run_all_policies, save_json


def run(verbose: bool = True):
    payload = {}
    for scale, n_jobs in ((0.5, 120), (1.0, 240), (1.5, 360), (2.0, 480)):
        jobs = simulation_trace(n_jobs=n_jobs, load_scale=scale)
        results = run_all_policies(jobs, n_servers=16, gpus_per_server=4)
        payload[f"{scale}x"] = {p: r.summary()["avg_jct"]
                                for p, r in results.items()}
        if verbose:
            row = payload[f"{scale}x"]
            print(f"load {scale}x ({n_jobs} jobs): " + ", ".join(
                f"{p}={row[p]:.0f}s" for p in POLICIES))
    save_json("fig6a_load.json", payload)
    return payload


if __name__ == "__main__":
    run()
