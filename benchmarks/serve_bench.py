"""Serving-path microbenchmark: decode tok/s and per-token latency of
the fused ``lax.scan`` generation loop vs the eager per-token dispatch
loop it replaces (plus the Pallas flash-decode variant), and one-shot vs
per-token prefill, across the architecture families
(``artifacts/bench/BENCH_serve.json``).

Two eager baselines are recorded:

* ``eager`` — the SEED's loop, reproduced faithfully: ``jax.jit`` is
  re-created on every generate() call, so every call pays retrace +
  compile before dispatching one call per token.  This is the loop the
  fused engine replaces and the acceptance baseline.
* ``eager_cached`` — the same per-token loop with the jitted step cached
  across calls (this PR's satellite fix).  On this CPU container the
  remaining gap to ``scan`` is Python dispatch + functional cache-copy
  overhead per token — modest here, larger on accelerators where
  dispatch latency is not hidden by slow per-op compute.

All decode paths run behind the SAME one-shot prefill and are asserted
token-identical at run time.  The flash-decode kernel runs in interpret
mode on CPU and is expected to be slower — the number exists for
regression tracking and TPU re-runs, like ``kernels_bench``.

Usage:
    PYTHONPATH=src python -m benchmarks.serve_bench            # full
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import autotune as _autotune
from repro.launch import serve
from repro.launch.engine import DecodeEngine
from repro.models import init_cache, init_params

from .common import save_json

BATCH = 8            # the acceptance scenario: batch 8
PROMPT_LEN = 8

# family representatives: dense KV, ring-buffer sliding window, MoE,
# xLSTM state, Mamba2 hybrid, whisper encoder-decoder
FULL_ARCHS = (("minicpm-2b", {}),
              ("glm4-9b", {"sliding_window": 16}),
              # decode never drops tokens; give the batched prefill enough
              # MoE capacity to match it (same note as tests/test_decode.py)
              ("granite-moe-3b-a800m", {"moe_capacity_factor": 8.0}),
              ("xlstm-1.3b", {}),
              ("zamba2-7b", {}),
              ("whisper-tiny", {}))
SMOKE_ARCHS = (("minicpm-2b", {}), ("xlstm-1.3b", {}))


def _cfg(name, **kw):
    return dataclasses.replace(get_config(name).reduced(),
                               dtype="float32", **kw)


def _time(fn, iters: int, warmup: int = 1) -> float:
    out = None
    for _ in range(warmup):
        out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_arch(name, kw, *, tokens: int, max_len: int, iters: int,
               with_kernel: bool = True):
    cfg = _cfg(name, **kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, PROMPT_LEN)),
                         jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.standard_normal(
            (BATCH, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32)
    common = dict(max_new_tokens=tokens, max_len=max_len, frames=frames,
                  prefill_mode="one_shot")

    def gen(engine, use_kernels=False):
        return lambda: serve.generate(cfg, params, prompt, engine=engine,
                                      use_kernels=use_kernels, **common)

    def gen_seed():
        # the seed's generate(), reproduced faithfully: teacher-forced
        # prefill through UNJITTED decode_step dispatches (one per prompt
        # token), then a FRESH jax.jit per call (retrace + compile every
        # generate) dispatching one call per generated token.
        from repro.models import decode_step, init_cache as _ic
        from repro.models import prefill_cache_whisper as _pcw
        if cfg.is_encoder_decoder:
            cache = _pcw(cfg, params, frames, BATCH, max_len)
        else:
            cache = _ic(cfg, BATCH, max_len)
        for t in range(prompt.shape[1]):
            logits, cache = decode_step(cfg, params, cache,
                                        prompt[:, t:t + 1])
        step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = []
        for _ in range(tokens):
            out.append(tok)
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)

    # token identity between the paths is part of the contract
    toks_seed = gen_seed()
    toks_eager = gen("eager")()
    toks_scan = gen("scan")()
    identical = (bool((np.asarray(toks_eager) == np.asarray(toks_scan)).all())
                 and bool((np.asarray(toks_seed)
                           == np.asarray(toks_scan)).all()))
    assert identical, f"{name}: scan tokens diverge from eager"

    n_tok = BATCH * tokens
    t_seed = _time(gen_seed, min(2, iters))   # seconds per call; cap iters
    t_eager = _time(gen("eager"), iters)
    t_scan = _time(gen("scan"), iters)
    row = {
        # end-to-end generate (prefill + decode loop), seed vs fused
        "eager_tok_s": n_tok / t_seed,
        "scan_tok_s": n_tok / t_scan,
        "scan_speedup": t_seed / t_scan,
        "eager_ms_per_tok": 1e3 * t_seed / tokens,
        "scan_ms_per_tok": 1e3 * t_scan / tokens,
        # decode-loop-only baseline with the jitted step cached (the
        # satellite fix): isolates dispatch + cache-copy overhead
        "eager_cached_tok_s": n_tok / t_eager,
        "scan_speedup_vs_cached": t_eager / t_scan,
        "eager_cached_ms_per_tok": 1e3 * t_eager / tokens,
        "tokens_identical": identical,
    }
    if with_kernel and cfg.family != "ssm":   # pure-SSM archs have no KV attn
        t_kern = _time(gen("scan", use_kernels=True), iters)
        row["scan_kernel_tok_s"] = n_tok / t_kern
        row["scan_kernel_ms_per_tok"] = 1e3 * t_kern / tokens

    # prefill: one-shot single dispatch vs T sequential decode_step calls
    def pf(mode):
        def run():
            if cfg.is_encoder_decoder:
                from repro.models import prefill_cache_whisper
                cache = prefill_cache_whisper(cfg, params, frames, BATCH,
                                              max_len)
            else:
                cache = init_cache(cfg, BATCH, max_len)
            fn = (serve.prefill_one_shot if mode == "one_shot"
                  else serve.prefill_per_token)
            return fn(cfg, params, prompt, cache)[0]
        return run

    t_pf1 = _time(pf("one_shot"), iters)
    t_pft = _time(pf("per_token"), iters)
    row["prefill"] = {
        "one_shot_s": t_pf1,
        "per_token_s": t_pft,
        "one_shot_speedup": t_pft / t_pf1,
    }
    return row


def bench_engine(*, tokens: int, iters: int):
    """Continuous-batching throughput: more requests than slots, admitted
    as slots free up (vs serving the same load as sequential batches)."""
    cfg = _cfg("minicpm-2b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_req, n_slots = 8, 4
    prompts = [rng.integers(0, cfg.vocab, (PROMPT_LEN,)) for _ in range(n_req)]
    # ONE engine reused across iterations: slots free up after each
    # drain and the segment/prefill jits stay warm, so the timing
    # measures engine throughput, not retrace + compile
    eng = DecodeEngine(cfg, params, n_slots=n_slots, max_len=64, segment=8)

    def run():
        rids = [eng.submit(p, tokens) for p in prompts]
        eng.run()
        return [eng.outputs[r] for r in rids]

    out = run()                                   # warmup + sanity
    assert all(len(v) == tokens for v in out)
    t = _time(run, iters, warmup=0)
    return {"n_requests": n_req, "n_slots": n_slots,
            "tokens_per_request": tokens,
            "tok_s": n_req * tokens / t,
            # paging wins must be measurable, not just asserted: surface
            # the engine's per-run counters (wasted_slot_steps counts
            # inactive/overrun slot-steps whose tokens are discarded)
            "stats": dict(eng.stats)}


def bench_engine_paged(*, iters: int, smoke: bool):
    """Dense vs paged engine at EQUAL cache memory.  The dense engine
    pays ``max_len`` rows per slot, so 4 slots exhaust the budget; the
    paged engine spends the same rows as a shared page pool and admits
    every request that fits in *pages actually used* — 16 concurrent
    slots for the same footprint (4x), with bitwise-identical tokens."""
    cfg = _cfg("minicpm-2b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = 64 if smoke else 128
    page_size = 8 if smoke else 16
    tokens = 8 if smoke else 16
    dense_slots, paged_slots, n_req, segment = 4, 16, 16, 8
    # equal memory: pool rows == dense rows (dense_slots * max_len)
    n_pages = dense_slots * max_len // page_size
    prompts = [rng.integers(0, cfg.vocab, (PROMPT_LEN,))
               for _ in range(n_req)]

    dense = DecodeEngine(cfg, params, n_slots=dense_slots, max_len=max_len,
                         segment=segment)
    paged = DecodeEngine(cfg, params, n_slots=paged_slots, max_len=max_len,
                         segment=segment, paged=True, page_size=page_size,
                         n_pages=n_pages)

    def run_eng(eng):
        def go():
            rids = [eng.submit(p, tokens) for p in prompts]
            eng.run()
            return [eng.outputs[r] for r in rids]
        return go

    out_d = run_eng(dense)()                      # warmup + identity
    out_p = run_eng(paged)()
    identical = out_d == out_p
    assert identical, "paged engine tokens diverge from dense"
    t_dense = _time(run_eng(dense), iters, warmup=0)
    t_paged = _time(run_eng(paged), iters, warmup=0)
    return {
        "n_requests": n_req, "tokens_per_request": tokens,
        "max_len": max_len, "page_size": page_size, "n_pages": n_pages,
        "cache_rows": dense_slots * max_len,      # equal for both engines
        "dense": {"n_slots": dense_slots,
                  "tok_s": n_req * tokens / t_dense,
                  "stats": dict(dense.stats)},
        "paged": {"n_slots": paged_slots,
                  "tok_s": n_req * tokens / t_paged,
                  "stats": dict(paged.stats)},
        "tokens_identical": identical,
        # the acceptance ratio: concurrent requests at equal cache memory
        "capacity_ratio": (paged.stats["peak_active_slots"]
                           / max(1, dense.stats["peak_active_slots"])),
    }


def run(smoke: bool = False, verbose: bool = True):
    iters = 2 if smoke else 5
    tokens = 16 if smoke else 32
    max_len = 64 if smoke else 128
    archs = SMOKE_ARCHS if smoke else FULL_ARCHS

    decode = {}
    for name, kw in archs:
        decode[name] = bench_arch(name, kw, tokens=tokens, max_len=max_len,
                                  iters=iters)
    payload = {
        "decode": decode,
        "engine": bench_engine(tokens=tokens, iters=max(1, iters - 1)),
        "engine_paged": bench_engine_paged(iters=max(1, iters - 1),
                                           smoke=smoke),
        "meta": {"batch": BATCH, "prompt_len": PROMPT_LEN,
                 "new_tokens": tokens, "backend": jax.default_backend(),
                 "smoke": smoke, "iters": iters,
                 # kernel rows run tuned-or-fallback routing when the
                 # autotune artifact is present (fallback is bitwise
                 # identical, so identity asserts are unaffected)
                 "autotune_active": _autotune.get_table() is not None,
                 "note": "kernel timings are interpret-mode on CPU"},
    }
    path = save_json("BENCH_serve.json", payload)
    if verbose:
        for name, row in decode.items():
            kern = row.get("scan_kernel_tok_s")
            kern_s = f" kernel {kern:7.1f}" if kern else ""
            print(f"{name:<24} eager(seed) {row['eager_tok_s']:7.1f} "
                  f"cached {row['eager_cached_tok_s']:7.1f} "
                  f"scan {row['scan_tok_s']:7.1f} tok/s{kern_s}  "
                  f"({row['scan_speedup']:.1f}x vs seed, "
                  f"{row['scan_speedup_vs_cached']:.1f}x vs cached, "
                  f"prefill one-shot {row['prefill']['one_shot_speedup']:.1f}x)")
        eng = payload["engine"]
        print(f"continuous batching: {eng['n_requests']} reqs / "
              f"{eng['n_slots']} slots -> {eng['tok_s']:.1f} tok/s "
              f"(wasted slot-steps {eng['stats']['wasted_slot_steps']})")
        pg = payload["engine_paged"]
        ps_, pd_ = pg["paged"], pg["dense"]
        print(f"paged vs dense @ {pg['cache_rows']} cache rows: "
              f"{ps_['stats']['peak_active_slots']} vs "
              f"{pd_['stats']['peak_active_slots']} concurrent "
              f"({pg['capacity_ratio']:.1f}x), "
              f"{ps_['tok_s']:.1f} vs {pd_['tok_s']:.1f} tok/s, "
              f"occupancy {ps_['stats']['page_occupancy']:.2f}, "
              f"fragmentation {ps_['stats']['page_fragmentation']:.2f}, "
              f"identical={pg['tokens_identical']}")
        print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
