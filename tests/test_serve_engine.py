"""Fused decode engine: single-shot prefill equivalence, scan-vs-eager
token identity (greedy and seeded sampling) across all cache families,
flash-decode kernel vs oracle, continuous-batching slot invariants, jit
caching, and the memoized interference calibration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.launch import serve
from repro.launch.engine import DecodeEngine
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill, prefill_cache_whisper)
from repro.models.attention import attention_decode, attention_init

B, T = 2, 12
ATOL = 2e-2

# family representatives: dense KV, ring buffer, MoE, VLM, xLSTM, hybrid
FAMILY_ARCHS = [
    ("minicpm-2b", {}),
    ("glm4-9b", {"sliding_window": 8}),
    ("granite-moe-3b-a800m", {"moe_capacity_factor": 8.0}),
    ("qwen2-vl-2b", {}),
    ("xlstm-1.3b", {}),
    ("zamba2-7b", {}),
]


def _cfg(name, **kw):
    return dataclasses.replace(get_config(name).reduced(),
                               dtype="float32", **kw)


def _setup(name, seed=0, **kw):
    cfg = _cfg(name, **kw)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32)
    return cfg, params, tokens, frames


# ====================================================================== #
# single-shot prefill
# ====================================================================== #
class TestPrefillOneShot:
    @pytest.mark.parametrize("name,kw", FAMILY_ARCHS)
    def test_logits_match_forward(self, name, kw):
        cfg, params, tokens, _ = _setup(name, **kw)
        full, _ = forward(cfg, params, {"tokens": tokens}, remat=False)
        logits, cache = prefill(cfg, params, init_cache(cfg, B, 32), tokens)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                                   atol=ATOL, rtol=1e-2)
        assert int(np.asarray(cache["index"])) == T

    def test_logits_match_forward_whisper(self):
        cfg, params, tokens, frames = _setup("whisper-tiny")
        full, _ = forward(cfg, params,
                          {"tokens": tokens, "frames": frames}, remat=False)
        cache = prefill_cache_whisper(cfg, params, frames, B, 32)
        logits, _ = prefill(cfg, params, cache, tokens)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                                   atol=ATOL, rtol=1e-2)

    @pytest.mark.parametrize("name,kw", [
        ("minicpm-2b", {}),
        ("glm4-9b", {"sliding_window": 8}),   # ring: window < prompt len
        ("xlstm-1.3b", {}),
        ("zamba2-7b", {}),
    ])
    def test_cache_matches_per_token_prefill(self, name, kw):
        """Decoding from the one-shot cache == decoding from the cache a
        per-token decode_step prefill loop produced."""
        cfg, params, tokens, _ = _setup(name, **kw)
        _, c1 = prefill(cfg, params, init_cache(cfg, B, 32), tokens)
        c2 = init_cache(cfg, B, 32)
        for t in range(T):
            _, c2 = decode_step(cfg, params, c2, tokens[:, t:t + 1])
        nxt = jnp.full((B, 1), 3, jnp.int32)
        l1, _ = decode_step(cfg, params, c1, nxt)
        l2, _ = decode_step(cfg, params, c2, nxt)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=1e-4, rtol=1e-4)


# ====================================================================== #
# fused scan generation
# ====================================================================== #
class TestScanGeneration:
    @pytest.mark.parametrize("name,kw", FAMILY_ARCHS)
    def test_greedy_token_identical(self, name, kw):
        cfg, params, tokens, frames = _setup(name, **kw)
        common = dict(max_new_tokens=8, max_len=32, frames=frames)
        eager = serve.generate(cfg, params, tokens, engine="eager",
                               prefill_mode="per_token", **common)
        scan = serve.generate(cfg, params, tokens, engine="scan",
                              prefill_mode="one_shot", **common)
        assert (np.asarray(eager) == np.asarray(scan)).all()

    def test_greedy_token_identical_whisper(self):
        cfg, params, tokens, frames = _setup("whisper-tiny")
        common = dict(max_new_tokens=8, max_len=32, frames=frames)
        eager = serve.generate(cfg, params, tokens, engine="eager",
                               prefill_mode="per_token", **common)
        scan = serve.generate(cfg, params, tokens, engine="scan",
                              prefill_mode="one_shot", **common)
        assert (np.asarray(eager) == np.asarray(scan)).all()

    @pytest.mark.parametrize("name", ["minicpm-2b", "xlstm-1.3b"])
    def test_sampled_token_identical(self, name):
        """Seeded categorical sampling: the scan threads the PRNG key
        through the carry in the same split order as the eager loop."""
        cfg, params, tokens, _ = _setup(name)
        key = jax.random.PRNGKey(7)
        common = dict(max_new_tokens=8, max_len=32, greedy=False)
        eager = serve.generate(cfg, params, tokens, engine="eager",
                               prefill_mode="per_token", key=key, **common)
        scan = serve.generate(cfg, params, tokens, engine="scan",
                              prefill_mode="per_token", key=key, **common)
        assert (np.asarray(eager) == np.asarray(scan)).all()

    def test_jit_callables_cached_across_calls(self):
        cfg, params, tokens, _ = _setup("minicpm-2b")
        serve.generate(cfg, params, tokens, max_new_tokens=4, max_len=32)
        n0 = serve.jit_cache_size()
        # fresh-but-equal config object: keyed by config identity
        cfg2 = _cfg("minicpm-2b")
        serve.generate(cfg2, params, tokens, max_new_tokens=4, max_len=32)
        assert serve.jit_cache_size() == n0


# ====================================================================== #
# flash-decode kernel
# ====================================================================== #
class TestFlashDecode:
    def test_matches_softmax_oracle(self):
        rng = np.random.default_rng(0)
        b, s, h, d = 3, 96, 4, 32        # s not a block multiple: pads
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        lengths = jnp.asarray([1, 17, 96], jnp.int32)
        out = ops.flash_decode(q, k, v, lengths)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * d ** -0.5
        valid = jnp.arange(s)[None, :] < lengths[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd",
                         jax.nn.softmax(scores, axis=-1), v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_matches_ref_oracle_last_row(self):
        """Decoding position len-1 against a cache of len keys must equal
        the last row of ``ref.attention_ref`` causal attention over those
        len positions."""
        from repro.kernels import ref
        rng = np.random.default_rng(2)
        b, s, h, d = 2, 32, 2, 16
        q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
        full = ref.attention_ref(q, k, v, causal=True)       # (b, h, s, d)
        for length in (1, 7, 32):
            out = ops.flash_decode(
                q[:, :, length - 1:length].transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                jnp.full((b,), length, jnp.int32))
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, :, length - 1]),
                atol=1e-5, rtol=1e-5)

    def test_attention_decode_kernel_equals_jnp(self):
        rng = np.random.default_rng(1)
        d_model, nh, nkv, hd = 64, 4, 2, 16     # GQA
        p = attention_init(jax.random.PRNGKey(0), d_model, nh, nkv, hd)
        x = jnp.asarray(rng.standard_normal((B, 1, d_model)), jnp.float32)
        cache = {"k": jnp.asarray(rng.standard_normal((B, 24, nkv, hd)),
                                  jnp.float32),
                 "v": jnp.asarray(rng.standard_normal((B, 24, nkv, hd)),
                                  jnp.float32)}
        for index in (jnp.asarray(5), jnp.asarray([3, 11])):
            a, ca = attention_decode(p, x, None, None, cache, index,
                                     n_heads=nh, n_kv_heads=nkv,
                                     head_dim=hd, use_kernel=True)
            b_, cb = attention_decode(p, x, None, None, cache, index,
                                      n_heads=nh, n_kv_heads=nkv,
                                      head_dim=hd, use_kernel=False)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(ca["k"]),
                                       np.asarray(cb["k"]))

    @pytest.mark.parametrize("name", ["minicpm-2b", "zamba2-7b",
                                      "whisper-tiny"])
    def test_decode_step_kernel_logits(self, name):
        cfg, params, tokens, frames = _setup(name)
        if cfg.is_encoder_decoder:
            cache = prefill_cache_whisper(cfg, params, frames, B, 32)
        else:
            cache = init_cache(cfg, B, 32)
        _, cache = prefill(cfg, params, cache, tokens)
        nxt = jnp.full((B, 1), 5, jnp.int32)
        lk, _ = decode_step(cfg, params, cache, nxt, use_kernels=True)
        lj, _ = decode_step(cfg, params, cache, nxt, use_kernels=False)
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lj),
                                   atol=1e-4, rtol=1e-4)

    def test_generate_kernel_token_identical(self):
        cfg, params, tokens, _ = _setup("minicpm-2b")
        common = dict(max_new_tokens=8, max_len=32)
        ref = serve.generate(cfg, params, tokens, **common)
        kern = serve.generate(cfg, params, tokens, use_kernels=True,
                              **common)
        assert (np.asarray(ref) == np.asarray(kern)).all()


# ====================================================================== #
# continuous batching
# ====================================================================== #
class TestContinuousBatching:
    @pytest.mark.parametrize("name,kw", [
        ("minicpm-2b", {}),
        ("glm4-9b", {"sliding_window": 8}),
        ("xlstm-1.3b", {}),
        ("zamba2-7b", {}),
    ])
    def test_slot_reuse_never_leaks(self, name, kw):
        """More requests than slots: every request's tokens must equal its
        solo generation — a reused slot must not expose the previous
        occupant's cache rows."""
        cfg = _cfg(name, **kw)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        eng = DecodeEngine(cfg, params, n_slots=2, max_len=32, segment=4)
        prompts = [rng.integers(0, cfg.vocab, (pl,))
                   for pl in (5, 8, 3, 8, 6)]
        news = [7, 4, 9, 6, 5]
        rids = [eng.submit(p, n) for p, n in zip(prompts, news)]
        out = eng.run()
        assert eng.stats["admitted"] == len(prompts)
        assert not eng.active.any() and not eng.queue
        for rid, p, n in zip(rids, prompts, news):
            solo = serve.generate(
                cfg, params, jnp.asarray(p, jnp.int32)[None, :],
                max_new_tokens=n, max_len=32)
            assert out[rid] == [int(t) for t in np.asarray(solo)[0]], \
                f"request {rid} diverged from its solo generation"

    def test_admission_between_segments(self):
        cfg = _cfg("minicpm-2b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        eng = DecodeEngine(cfg, params, n_slots=2, max_len=32, segment=4)
        for _ in range(3):
            eng.submit(rng.integers(0, cfg.vocab, (4,)), 4)
        eng.step_segment()              # admits 2 of 3, queue holds 1
        assert eng.stats["admitted"] == 2 and len(eng.queue) == 1
        assert not eng.active.all() or len(eng.queue) == 1
        eng.run()
        assert eng.stats["admitted"] == 3
        assert all(len(v) == 4 for v in eng.outputs.values())

    def test_rejects_encoder_decoder(self):
        cfg = _cfg("whisper-tiny")
        params = init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(AssertionError):
            DecodeEngine(cfg, params, n_slots=2, max_len=32)


# ====================================================================== #
# memoized interference calibration
# ====================================================================== #
class TestCalibrationMemo:
    def test_calibrate_measures_solo_once_per_spec(self, monkeypatch):
        from repro.core import coschedule

        solo_calls = []
        pair_solo_kwargs = []
        monkeypatch.setattr(
            coschedule, "measure_solo",
            lambda spec, iters=3: solo_calls.append(spec) or 1.0)

        def fake_pair(a, b, iters=3, *, t_a_solo=None, t_b_solo=None):
            pair_solo_kwargs.append((t_a_solo, t_b_solo))
            return {"xi_a": 2.0, "xi_b": 2.0}
        monkeypatch.setattr(coschedule, "measure_pair", fake_pair)

        specs = {n: object() for n in ("a", "b", "c")}
        coschedule.calibrate_interference(specs, iters=1)
        assert len(solo_calls) == 3                       # O(n), not O(n²)
        assert len(pair_solo_kwargs) == 6                 # n(n+1)/2 pairs
        assert all(ta == 1.0 and tb == 1.0
                   for ta, tb in pair_solo_kwargs)

    def test_measure_pair_skips_solo_when_precomputed(self, monkeypatch):
        from repro.core import coschedule

        def boom(spec, iters=3):
            raise AssertionError("measure_solo should not run")
        monkeypatch.setattr(coschedule, "measure_solo", boom)
        cfg = _cfg("minicpm-2b")
        spec = coschedule.JobSpec(cfg, batch=1, seq=16)
        r = coschedule.measure_pair(spec, spec, iters=1,
                                    t_a_solo=0.5, t_b_solo=0.5)
        assert r["t_a_solo"] == 0.5 and r["t_b_solo"] == 0.5
        assert r["t_pair"] > 0
