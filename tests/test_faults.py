"""Fault injection and recovery in the simulator (DESIGN.md §16):
FaultModel timeline determinism and validation, checkpoint-truncated
job failure accounting, server down/recover with deadlock-free repair
scheduling, graceful peer rescaling on a donor failure, and the
engine/decision-path equivalence guarantees under an active fault
timeline.  The key invariant: a zero-rate FaultModel is bit-identical
to running with no fault model at all, for every policy."""
import math
import random

import pytest

from repro.core import (ClusterState, FaultModel, InterferenceModel,
                        Simulator, make_scheduler,
                        paper_interference_model, simulation_trace)
from repro.core.job import Job, JobState
from repro.core.perf_model import PerfParams
from repro.core.schedulers import ALL_POLICIES, SJF_BSBF

GB = 2 ** 30
REL = 1e-6


def mk_job(jid, arrival, gpus, iters, beta=1e-2, batch=10,
           mem_per_sample=0.01):
    perf = PerfParams(alpha_comp=0.0, beta_comp=beta, alpha_comm=0.0,
                      beta_comm=0.0, msg_bytes=0.0, mem_base=1 * GB,
                      mem_per_sample=mem_per_sample * GB)
    return Job(jid=jid, model="m", arrival=arrival, gpus=gpus, iters=iters,
               batch=batch, perf=perf)


class _Inject:
    """Scheduler wrapper firing scripted fault actions keyed by pass
    count (after the inner pass, like the chaos harness), then running
    one more inner pass so requeued victims are not stranded."""

    def __init__(self, inner, actions):
        self.inner = inner
        self.name = inner.name
        self.preemptive = inner.preemptive
        self.tick_interval = inner.tick_interval
        self.tick_only = inner.tick_only
        self.reads_running_progress = inner.reads_running_progress
        self.progress_scope = inner.progress_scope
        self._actions = dict(actions)
        self.fired = {}
        self.reset()

    def reset(self):
        self.inner.reset()
        self._passes = 0

    def schedule(self, sim):
        self.inner.schedule(sim)
        self._passes += 1
        action = self._actions.pop(self._passes, None)
        if action is not None:
            self.fired[self._passes] = action(sim)
            self.inner.schedule(sim)


# ===================================================================== #
# FaultModel: timeline + truncation unit tests
# ===================================================================== #
class TestFaultModel:
    def test_default_model_injects_nothing(self):
        fm = FaultModel()
        assert not fm.enabled
        assert fm.timeline(8, range(20)) == []

    def test_timeline_deterministic_and_sorted(self):
        fm = FaultModel(seed=5, job_mtbf=3000.0, server_mtbf=20000.0)
        a = fm.timeline(4, range(10))
        b = fm.timeline(4, range(10))
        assert a == b and a
        times = [e[0] for e in a]
        assert times == sorted(times)
        assert [e[1] for e in a] == list(range(len(a)))
        # a different seed reshuffles the whole timeline
        assert a != FaultModel(seed=6, job_mtbf=3000.0,
                               server_mtbf=20000.0).timeline(4, range(10))

    def test_job_only_timeline_targets_given_jids(self):
        fm = FaultModel(seed=1, job_mtbf=5000.0)
        tl = fm.timeline(4, [3, 7])
        assert tl
        assert all(kind == "fail_job" and target in (3, 7)
                   for _t, _s, kind, target in tl)
        assert all(t < fm.horizon for t, *_ in tl)

    def test_correlated_kills_hit_rack_neighbours(self):
        fm = FaultModel(seed=2, server_mtbf=30000.0, server_repair=100.0,
                        correlated_servers=2)
        tl = fm.timeline(3, [])
        fails = [e for e in tl if e[2] == "fail_server"]
        recovers = [e for e in tl if e[2] == "recover_server"]
        assert fails and len(fails) == len(recovers)
        by_time = {}
        for t, _s, _k, sid in fails:
            by_time.setdefault(t, []).append(sid)
        for t, sids in by_time.items():
            assert len(sids) == 2
            # events sort by target, so either orientation of the
            # (origin, origin+1 mod n) pair is a valid neighbour kill
            assert ((sids[0] + 1) % 3 == sids[1]
                    or (sids[1] + 1) % 3 == sids[0])
            # each kill carries its matching repair
            assert sum(1 for tr, _s, _k, sr in recovers
                       if tr == pytest.approx(t + 100.0)
                       and sr in sids) == 2

    def test_weibull_mean_normalization(self):
        # E[lifetime] must equal server_mtbf regardless of shape, so the
        # long-run failure count ~ horizon / (mtbf + repair) for every
        # shape.  Without normalization, shape=2 would drift ~8% high.
        expect = 1_000_000 / (1000.0 + 600.0)
        for shape in (1.0, 2.0):
            fm = FaultModel(seed=4, server_mtbf=1000.0, server_repair=600.0,
                            weibull_shape=shape, horizon=1_000_000.0)
            n = sum(1 for e in fm.timeline(1, []) if e[2] == "fail_server")
            assert abs(n - expect) < 40, (shape, n)

    @pytest.mark.parametrize("kw", [
        {"job_mtbf": -1.0}, {"server_mtbf": -0.5},
        {"server_repair": 0.0}, {"weibull_shape": 0.0},
        {"correlated_servers": 0}, {"checkpoint_interval": -1.0},
    ])
    def test_validation(self, kw):
        with pytest.raises(ValueError):
            FaultModel(**kw)

    def test_truncate_progress(self):
        fm = FaultModel(checkpoint_interval=50.0)
        assert fm.truncate_progress(0.0) == 0.0
        assert fm.truncate_progress(49.9) == 0.0
        assert fm.truncate_progress(120.0) == 100.0
        assert fm.truncate_progress(150.0) == 150.0
        # float-noise rescue: a hair under a boundary still counts as
        # the boundary, capped at the actual progress
        assert fm.truncate_progress(99.99999999) == 99.99999999
        # no checkpointing -> the attempt restarts from scratch
        assert FaultModel().truncate_progress(123.4) == 0.0


# ===================================================================== #
# Engine semantics: fail_job / fail_server / recover_server
# ===================================================================== #
class TestFailJob:
    def test_truncates_to_checkpoint_and_requeues(self):
        j0 = mk_job(0, 0.0, 4, 100)        # t_iter = 0.1s
        j1 = mk_job(1, 4.0, 4, 50)         # arrival event = injection point
        cluster = ClusterState(n_servers=1, gpus_per_server=4)
        sched = _Inject(make_scheduler("fifo"),
                        {2: lambda sim: sim.fail_job(sim.jobs[0])})
        sim = Simulator(cluster, [j0, j1], sched,
                        fault_model=FaultModel(checkpoint_interval=30.0))
        sim.run()
        # at t=4 j0 had 40 iters done: 30 survive, 10 roll back
        assert j0.failures == 1
        assert j0.lost_iters == pytest.approx(10.0)
        assert j0.preemptions >= 1
        assert j0.iters_done == pytest.approx(100.0)   # conservation
        assert j1.iters_done == pytest.approx(50.0)
        assert (4.0, "fail_job", 0) in [(e[0], e[1], e[2]) for e in sim.log]
        # requeued -> restarted: two start events for j0
        assert sum(1 for e in sim.log
                   if e[1] == "start" and e[2] == 0) == 2

    def test_no_fault_model_restarts_attempt_from_scratch(self):
        j0 = mk_job(0, 0.0, 4, 100)
        j1 = mk_job(1, 4.0, 4, 50)
        cluster = ClusterState(n_servers=1, gpus_per_server=4)
        sched = _Inject(make_scheduler("fifo"),
                        {2: lambda sim: sim.fail_job(sim.jobs[0])})
        sim = Simulator(cluster, [j0, j1], sched)
        sim.run()
        assert j0.lost_iters == pytest.approx(40.0)    # everything rolls back
        assert j0.iters_done == pytest.approx(100.0)

    def test_fail_job_requires_running(self):
        j0 = mk_job(0, 0.0, 4, 100)
        cluster = ClusterState(n_servers=1, gpus_per_server=4)
        sim = Simulator(cluster, [j0], make_scheduler("fifo"))
        with pytest.raises(RuntimeError, match="not running"):
            sim.fail_job(j0)


class TestFailServer:
    def test_kill_and_scheduled_repair_no_deadlock(self):
        """A full-cluster kill with nothing else in flight must not
        deadlock: the repair event lives in the fault heap and revives
        the cluster."""
        j0 = mk_job(0, 0.0, 4, 100)
        cluster = ClusterState(n_servers=1, gpus_per_server=4)
        sched = _Inject(make_scheduler("fifo"),
                        {1: lambda sim: sim.fail_server(0, repair_after=5.0)})
        sim = Simulator(cluster, [j0], sched)
        sim.run()
        assert j0.failures == 1
        assert j0.state is JobState.FINISHED
        assert j0.iters_done == pytest.approx(100.0)
        kinds = [(e[1], e[0]) for e in sim.log]
        t_fail = dict((k, t) for k, t in kinds)["fail_server"]
        t_rec = dict((k, t) for k, t in kinds)["recover_server"]
        assert t_rec == pytest.approx(t_fail + 5.0)
        restart = [e[0] for e in sim.log
                   if e[1] == "start" and e[2] == 0][-1]
        assert restart >= t_rec

    def test_down_server_leaves_allocatable_pool(self):
        seen = {}

        def act(sim):
            sid = next(iter(sim.jobs[0].placement)) // 2
            assert sim.fail_server(sid, repair_after=50.0)
            seen["sid"] = sid
            seen["down"] = set(sim.cluster.down_servers)
            # idempotent: a dead server cannot die twice
            assert not sim.fail_server(sid, repair_after=50.0)
            # a healthy server cannot "recover"
            assert not sim.recover_server(1 - sid)
            with pytest.raises(ValueError, match="no server"):
                sim.fail_server(99)
            return True

        j0 = mk_job(0, 0.0, 2, 100)
        j1 = mk_job(1, 1.0, 2, 400)
        cluster = ClusterState(n_servers=2, gpus_per_server=2)
        sched = _Inject(make_scheduler("fifo"), {2: act})
        sim = Simulator(cluster, [j0, j1], sched)
        sim.run()
        assert sched.fired[2] is True
        assert seen["down"] == {seen["sid"]}
        assert not sim.cluster.down_servers    # repaired by the end
        assert j0.failures == 1 and j1.failures == 0
        assert j0.iters_done == pytest.approx(100.0)


class TestPeerRescale:
    def _scenario(self, fault_model):
        """SJF-BSBF donor/sharer pair on one GPU: the donor shrinks its
        sub-batch to admit the sharer; when the sharer is killed the
        donor should be restored — exactly iff rescale_peers."""
        perf = PerfParams(alpha_comp=0.01, beta_comp=0.01, alpha_comm=0.0,
                          beta_comm=0.0, msg_bytes=0.0, delta=2.0,
                          mem_base=4.0 * GB, mem_per_sample=0.25 * GB,
                          param_bytes=1e8, n_workers=1)
        t_a = perf.t_iter(4)
        jobs = [Job(jid=0, model="m0", arrival=0.0, gpus=1, iters=30.0,
                    batch=4, perf=perf),
                Job(jid=1, model="m1", arrival=2 * t_a, gpus=1, iters=8.0,
                    batch=4, perf=perf)]
        cap = 2 * perf.mem_bytes(2) + 0.05 * GB   # both@2 fit, 4+2 do not
        interf = InterferenceModel()
        for a in ("m0", "m1"):
            for b in ("m0", "m1"):
                interf.set_pair(a, b, 1.3, 1.3)
        sched = _Inject(SJF_BSBF(donor_reconfig=True),
                        {2: lambda sim: sim.fail_job(sim.jobs[1])})
        cluster = ClusterState(n_servers=1, gpus_per_server=1,
                               gpu_capacity_bytes=cap)
        sim = Simulator(cluster, jobs, sched, interference=interf,
                        fault_model=fault_model)
        sim.run()
        return sim, jobs

    def test_donor_restored_when_rescale_peers(self):
        sim, jobs = self._scenario(None)
        fail_t = next(e[0] for e in sim.log if e[1] == "fail_job")
        # donor shrank to admit the sharer, then restored at the kill
        assert any(e[1] == "reconfig" and e[2] == 0 and e[3] == 2
                   for e in sim.log)
        assert any(e[1] == "reconfig" and e[2] == 0 and e[3] == 4
                   and e[0] == pytest.approx(fail_t) for e in sim.log)
        assert jobs[0].iters_done == pytest.approx(30.0)
        assert jobs[1].iters_done == pytest.approx(8.0)

    def test_donor_left_alone_without_rescale_peers(self):
        sim, jobs = self._scenario(FaultModel(rescale_peers=False))
        assert any(e[1] == "fail_job" for e in sim.log)
        assert not any(e[1] == "reconfig" and e[2] == 0 and e[3] == 4
                       for e in sim.log)
        assert jobs[1].iters_done == pytest.approx(8.0)


# ===================================================================== #
# Whole-sim invariants: zero-rate identity, cross-engine/path equality
# ===================================================================== #
def _run_trace(policy, fault_model, engine=None, decision=None,
               n_jobs=40, seed=11):
    jobs = simulation_trace(n_jobs=n_jobs, seed=seed)
    cluster = ClusterState(n_servers=8, gpus_per_server=4,
                           gpu_capacity_bytes=11 * GB)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=paper_interference_model(),
                    engine=engine, decision=decision,
                    fault_model=fault_model, max_events=500_000)
    sim.run()
    return sim, jobs


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
def test_zero_rate_model_bit_identical_to_no_model(policy):
    sim_none, jobs_none = _run_trace(policy, None)
    sim_zero, jobs_zero = _run_trace(policy, FaultModel())
    assert sim_none.log == sim_zero.log
    assert ([j.finish_time for j in jobs_none]
            == [j.finish_time for j in jobs_zero])


FAULTY = FaultModel(seed=3, job_mtbf=4000.0, server_mtbf=20000.0,
                    server_repair=300.0, correlated_servers=2,
                    checkpoint_interval=50.0)


@pytest.mark.parametrize("policy", ["fifo", "sjf-bsbf"])
def test_heap_matches_scan_under_faults(policy):
    sim_s, jobs_s = _run_trace(policy, FAULTY, engine="scan", n_jobs=60,
                               seed=7)
    sim_h, jobs_h = _run_trace(policy, FAULTY, engine="heap", n_jobs=60,
                               seed=7)
    assert sum(j.failures for j in jobs_h) > 0   # the ladder actually bites
    for ja, jb in zip(jobs_s, jobs_h):
        assert jb.finish_time == pytest.approx(ja.finish_time, rel=REL)
        assert jb.failures == ja.failures
        assert jb.lost_iters == pytest.approx(ja.lost_iters, rel=REL,
                                              abs=1e-3)
    assert ([e[1] for e in sim_s.log if e[1].startswith(("fail", "recover"))]
            == [e[1] for e in sim_h.log
                if e[1].startswith(("fail", "recover"))])


def test_decision_paths_bit_identical_under_faults():
    sim_g, _ = _run_trace("sjf-bsbf", FAULTY, decision="grid", n_jobs=60,
                          seed=7)
    for decision in ("batched", "scalar"):
        sim_d, _ = _run_trace("sjf-bsbf", FAULTY, decision=decision,
                              n_jobs=60, seed=7)
        assert sim_d.log == sim_g.log, decision


def test_faulty_run_conserves_work_and_accounts_losses():
    _, jobs = _run_trace("sjf", FAULTY, n_jobs=60, seed=7)
    assert all(j.state is JobState.FINISHED for j in jobs)
    for j in jobs:
        assert j.iters_done == pytest.approx(j.iters, rel=1e-6)
        assert j.lost_iters >= 0.0
        if j.failures == 0 and j.preemptions == 0:
            assert j.lost_iters == 0.0
    useful = sum(j.iters for j in jobs)
    lost = sum(j.lost_iters for j in jobs)
    assert 0.0 < useful / (useful + lost) < 1.0
