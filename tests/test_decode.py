"""Decode-vs-forward consistency: autoregressive ``decode_step`` with a
cache must reproduce the teacher-forced ``forward`` logits position by
position — this validates every cache type (KV, ring-buffer sliding
window, Mamba2 conv+SSM state, mLSTM/sLSTM state, whisper cross-attn)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data import make_batch
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill_cache_whisper)

B, T = 2, 16
ATOL = 2e-2  # f32 accumulation-order differences across paths


def _cfg(name, **kw):
    return dataclasses.replace(get_config(name).reduced(),
                               dtype="float32", **kw)


def _decode_all(cfg, params, tokens, cache):
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = decode_step(cfg, params, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if get_config(n).family != "audio"])
def test_decode_matches_forward(name):
    # decode never drops tokens; give forward enough MoE capacity to match
    cfg = _cfg(name, moe_capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full, _ = forward(cfg, params, {"tokens": tokens}, remat=False)
    cache = init_cache(cfg, B, T)
    dec, _ = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=ATOL, rtol=1e-2)


def test_decode_matches_forward_whisper():
    cfg = _cfg("whisper-tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    frames = jnp.asarray(
        rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.02,
        jnp.float32)
    full, _ = forward(cfg, params, {"tokens": tokens, "frames": frames},
                      remat=False)
    cache = prefill_cache_whisper(cfg, params, frames, B, T)
    dec, _ = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=ATOL, rtol=1e-2)


def test_sliding_window_ring_buffer():
    """Dense arch with a window smaller than the sequence: decode with the
    ring-buffer cache must equal forward with the same window."""
    cfg = _cfg("glm4-9b", sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full, _ = forward(cfg, params, {"tokens": tokens}, remat=False)
    cache = init_cache(cfg, B, T)   # allocates min(window, T) slots
    assert cache["units"]["k"].shape[2] == 8
    dec, _ = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=ATOL, rtol=1e-2)


def test_cache_index_advances():
    cfg = _cfg("minicpm-2b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, T)
    assert int(cache["index"]) == 0
    logits, cache = decode_step(
        cfg, params, cache, jnp.zeros((B, 1), jnp.int32))
    assert int(cache["index"]) == 1
    assert logits.shape == (B, 1, cfg.vocab)
