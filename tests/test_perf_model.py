"""Unit + property tests for the Eq. 3/4/7 performance model."""
import math

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.perf_model import (GPU_2080TI, TPU_V5E, PerfParams,
                                   derive_perf_params, fit_comp_params,
                                   infer_xi, ring_allreduce_bytes)


def mk(alpha_c=2e-3, beta_c=1e-2, alpha_n=1e-4, beta_n=8e-10, msg=4e8,
       delta=2.0, **kw):
    return PerfParams(alpha_comp=alpha_c, beta_comp=beta_c,
                      alpha_comm=alpha_n, beta_comm=beta_n, msg_bytes=msg,
                      delta=delta, **kw)


def test_t_iter_s1_is_overlap_formula():
    p = mk()
    tc = p.t_comp(32)
    tn = p.t_comm()
    expect = (tc ** 2 + tn ** 2) ** 0.5
    assert p.t_iter(32, 1) == pytest.approx(expect)


def test_t_iter_eq7_structure():
    p = mk()
    s = 4
    tc = p.t_comp(32 / s)
    tn = p.t_comm()
    expect = (s - 1) * tc + (tc ** p.delta + tn ** p.delta) ** (1 / p.delta)
    assert p.t_iter(32, s) == pytest.approx(expect)


def test_accumulation_reduces_memory_not_batch_semantics():
    p = mk(mem_base=2e9, mem_per_sample=1e8)
    # memory shrinks with sub-batch, effective batch (32) unchanged
    assert p.mem_bytes(32) > p.mem_bytes(8)
    assert p.t_iter(32, 4) > 0


def test_invalid_accum_steps():
    with pytest.raises(ValueError):
        mk().t_iter(32, 0)


@given(st.floats(1e-4, 1e-1), st.floats(1e-4, 1e-1), st.floats(1e-5, 1e-2),
       st.integers(1, 6))
@settings(max_examples=100, deadline=None)
def test_t_iter_positive_and_bounded_below_by_compute(alpha_c, beta_c,
                                                      alpha_n, log2_s):
    s = 2 ** (log2_s - 1)
    p = mk(alpha_c=alpha_c, beta_c=beta_c, alpha_n=alpha_n)
    B = 32
    t = p.t_iter(B, s)
    # total compute alone is a lower bound (communication only adds)
    assert t >= s * p.t_comp(B / s) - 1e-12
    # and compute+comm fully serialized is an upper bound
    assert t <= s * p.t_comp(B / s) + p.t_comm() + 1e-12


@given(st.floats(1e-4, 1.0), st.floats(1e-5, 0.5))
@settings(max_examples=100, deadline=None)
def test_fit_recovers_linear_model(alpha, beta):
    batches = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0]
    times = [alpha + beta * b for b in batches]
    a, b = fit_comp_params(batches, times)
    assert a == pytest.approx(alpha, rel=1e-6, abs=1e-9)
    assert b == pytest.approx(beta, rel=1e-6, abs=1e-9)


def test_fit_rejects_degenerate():
    with pytest.raises(ValueError):
        fit_comp_params([2.0, 2.0], [1.0, 1.0])
    with pytest.raises(ValueError):
        fit_comp_params([1.0], [1.0])


def test_ring_allreduce_bytes():
    assert ring_allreduce_bytes(100.0, 1) == 0.0
    assert ring_allreduce_bytes(100.0, 4) == pytest.approx(150.0)
    # asymptote: 2x message size
    assert ring_allreduce_bytes(100.0, 10**6) == pytest.approx(200.0, rel=1e-4)


def test_infer_xi():
    assert infer_xi(1.0, 1.5) == pytest.approx(1.5)
    with pytest.raises(ValueError):
        infer_xi(0.0, 1.0)


def test_derive_perf_params_tpu_vs_gpu():
    kw = dict(flops_per_sample=8.4e10, param_bytes=4.4e8, n_workers=8,
              act_bytes_per_sample=4.5e7, opt_bytes=1.3e9)
    tpu = derive_perf_params(hw=TPU_V5E, **kw)
    gpu = derive_perf_params(hw=GPU_2080TI, **kw)
    # per-sample compute must be faster on v5e than 2080Ti
    assert tpu.beta_comp < gpu.beta_comp
    assert tpu.msg_bytes == pytest.approx(gpu.msg_bytes)
    assert tpu.param_bytes == 4.4e8


def test_throughput_matches_eq14():
    p = mk()
    assert p.throughput(32, 2) == pytest.approx(32 / p.t_iter(32, 2))
