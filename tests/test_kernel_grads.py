"""Trainable Pallas kernel path (interpret=True on CPU): ``jax.grad``
through the flash-attention / SSD custom_vjp backward kernels vs the
pure-jnp oracles in ``repro.kernels.ref``, the padded (non-block-multiple)
sequence path, the end-to-end ``use_kernels=True`` model gradient, and the
donated jitted train step.

Tolerances are scale-normalized: gradients are compared after dividing by
``max(1, max|g_ref|)``, so "within 1e-5" means 1e-5 relative to the
gradient magnitude (the oracles accumulate in a different order, so tiny
entries of large-magnitude gradients carry O(eps * scale) noise).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import make_batch
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_scan import ssd
from repro.kernels.ref import attention_ref, ssd_ref
from repro.models import init_params
from repro.train import (TrainConfig, adamw_init, loss_fn,
                         make_jit_train_step, make_train_step)


def _assert_grads_close(got, want, tol=1e-5):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        scale = max(1.0, float(jnp.abs(b).max()))
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=tol, rtol=tol)


# ---------------------------------------------------------------------- #
# flash attention backward
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 128, 64), (2, 2, 256, 32), (1, 2, 384, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_grads_sweep(b, h, s, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v = [jax.random.normal(kk, (b, h, s, d), dtype) for kk in ks[:3]]
    w = jax.random.normal(ks[3], (b, h, s, d))

    def loss(f):
        return lambda q, k, v: jnp.sum(
            f(q, k, v).astype(jnp.float32) * w)

    gk = jax.grad(loss(lambda q, k, v: flash_attention(
        q, k, v, causal=causal, interpret=True)), (0, 1, 2))(q, k, v)
    gr = jax.grad(loss(lambda q, k, v: attention_ref(
        q, k, v, causal=causal)), (0, 1, 2))(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    _assert_grads_close(gk, gr, tol)


@pytest.mark.parametrize("window", [32, 100])
def test_flash_grads_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q, k, v = [jax.random.normal(kk, (2, 2, 256, 64)) for kk in ks[:3]]
    w = jax.random.normal(ks[3], (2, 2, 256, 64))
    gk = jax.grad(lambda q, k, v: jnp.sum(flash_attention(
        q, k, v, causal=True, window=window, interpret=True) * w),
        (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(attention_ref(
        q, k, v, causal=True, window=window) * w), (0, 1, 2))(q, k, v)
    _assert_grads_close(gk, gr)


@pytest.mark.parametrize("s,causal", [(100, True), (320, False), (200, True)])
def test_flash_padded_seq_fwd_and_grads(s, causal):
    """S not a multiple of the block: zero-pad + seq_len masking instead
    of the old ``s % block_q == 0`` assert."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q, k, v = [jax.random.normal(kk, (1, 2, s, 32)) for kk in ks[:3]]
    w = jax.random.normal(ks[3], (1, 2, s, 32))
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    gk = jax.grad(lambda q, k, v: jnp.sum(flash_attention(
        q, k, v, causal=causal, interpret=True) * w), (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(attention_ref(
        q, k, v, causal=causal) * w), (0, 1, 2))(q, k, v)
    _assert_grads_close(gk, gr)


def test_flash_grads_block_shapes():
    """Backward must be block-size independent (the accumulators live in
    VMEM scratch across the inner grid dimension)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q, k, v = [jax.random.normal(kk, (1, 2, 256, 64)) for kk in ks[:3]]
    w = jax.random.normal(ks[3], (1, 2, 256, 64))
    grads = []
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        grads.append(jax.grad(lambda q, k, v: jnp.sum(flash_attention(
            q, k, v, block_q=bq, block_k=bk, interpret=True) * w),
            (0, 1, 2))(q, k, v))
    for g in grads[1:]:
        _assert_grads_close(g, grads[0])


# ---------------------------------------------------------------------- #
# ssd backward
# ---------------------------------------------------------------------- #
def _ssd_inputs(key, b, s, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    w = jax.random.normal(ks[5], (b, s, h, p))
    return x, dt, A, Bm, Cm, w


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 1, 8, 4, 64), (2, 256, 2, 16, 8, 128),
    (1, 256, 2, 32, 16, 64), (1, 100, 1, 8, 4, 64),   # last: padded path
])
def test_ssd_grads_sweep(b, s, h, p, n, chunk):
    """d(x, dt, A, B, C) through the reverse-chunk backward kernel vs the
    sequential oracle, including a non-chunk-multiple (padded) length."""
    x, dt, A, Bm, Cm, w = _ssd_inputs(0, b, s, h, p, n)
    gk = jax.grad(lambda *a: jnp.sum(ssd(
        *a, chunk=chunk, interpret=True) * w), (0, 1, 2, 3, 4))(
        x, dt, A, Bm, Cm)
    gr = jax.grad(lambda *a: jnp.sum(ssd_ref(*a) * w), (0, 1, 2, 3, 4))(
        x, dt, A, Bm, Cm)
    _assert_grads_close(gk, gr)


def test_ssd_grads_chunk_continuity():
    """dstate must flow seamlessly across chunk boundaries: gradients are
    chunk-size independent."""
    x, dt, A, Bm, Cm, w = _ssd_inputs(1, 1, 256, 2, 8, 8)
    grads = []
    for chunk in (32, 64, 128, 256):
        grads.append(jax.grad(lambda *a: jnp.sum(ssd(
            *a, chunk=chunk, interpret=True) * w), (0, 1, 2, 3, 4))(
            x, dt, A, Bm, Cm))
    for g in grads[1:]:
        _assert_grads_close(g, grads[0])


# ---------------------------------------------------------------------- #
# end-to-end: use_kernels=True model gradients + donated train step
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["minicpm-2b", "zamba2-7b"])
def test_model_grads_use_kernels(arch):
    """jax.grad through the full model with the kernel path (flash for
    dense, SSD for hybrid) vs the jnp reference path; seq=48 exercises
    the padding path inside both kernels."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 48)
    gk = jax.grad(lambda p: loss_fn(cfg, p, batch, use_kernels=True)[0])(
        params)
    gr = jax.grad(lambda p: loss_fn(cfg, p, batch, use_kernels=False)[0])(
        params)
    _assert_grads_close(gk, gr, 1e-5)


def test_donated_train_step_matches_undonated():
    """make_jit_train_step donates params/opt-state; two threaded steps
    must match the undonated trajectory exactly."""
    cfg = dataclasses.replace(get_config("minicpm-2b").reduced(),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tc = TrainConfig(accum_steps=2)
    undonated = jax.jit(make_train_step(cfg, tc))
    donated = make_jit_train_step(cfg, tc)
    pu, ou = params, opt
    pd, od = params, opt
    for i in range(2):
        batch = make_batch(cfg, 4, 32, step=i)
        pu, ou, mu = undonated(pu, ou, batch)
        pd, od, md = donated(pd, od, batch)
    assert float(mu["loss"]) == float(md["loss"])
    for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
