"""System-invariant tests for all scheduling policies, driven by random
traces (hypothesis). The cluster allocator itself raises on any violation
of the <=C jobs/GPU packing constraint, so a completed simulation already
certifies packing; we additionally check gang semantics, completion,
non-preemption for the non-preemptive policies, and policy-specific
behaviours."""
import pytest

from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core import (ClusterState, InterferenceModel, Simulator,
                        make_scheduler, paper_interference_model)
from repro.core.schedulers import ALL_POLICIES
from repro.core.trace import TraceConfig, generate_trace

NONPREEMPTIVE = ["fifo", "sjf", "sjf-ffs", "sjf-bsbf"]


def run_trace(policy, n_jobs=16, seed=0, servers=2, gps=4, xi=None,
              max_gpus=8):
    demand = tuple((g, p) for g, p in ((1, .4), (2, .25), (4, .2), (8, .15))
                   if g <= max_gpus)
    cfg = TraceConfig(n_jobs=n_jobs, seed=seed, mean_interarrival=60.0,
                      min_iters=50, max_iters=2000, gpu_demand=demand)
    jobs = generate_trace(cfg)
    cluster = ClusterState(n_servers=servers, gpus_per_server=gps,
                           gpu_capacity_bytes=11 * 2**30)
    interf = (InterferenceModel(global_xi=xi) if xi
              else paper_interference_model())
    sim = Simulator(cluster, jobs, make_scheduler(policy), interference=interf)
    return sim.run()


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_all_jobs_complete_and_invariants(policy, seed):
    res = run_trace(policy, seed=seed)
    assert len(res.jobs) == 16
    for j in res.jobs:
        assert j.finish_time is not None
        assert j.iters_done == pytest.approx(j.iters, rel=1e-5)
        assert j.finish_time >= j.arrival
        assert j.jct() >= 0
        # a job can never beat its best-possible execution. For the
        # elastic policy the floor must range over allowed allocations:
        # comm-bound jobs (NCF) are genuinely faster per-sample at FEWER
        # workers (their all-reduce dwarfs compute — the paper's Fig. 2).
        import copy
        floors = [min(j.perf.t_iter(j.batch, s) for s in (1, 2, 4, 8))]
        if policy == "pollux":
            for n in (1, 2, 4, 8):
                if n >= j.gpus:
                    break
                jc = copy.deepcopy(j)
                jc.alloc_gpus = n
                floors.append(jc.base_t_iter())
        assert j.jct() >= 0.95 * min(floors) * j.iters
        if policy in NONPREEMPTIVE:
            assert j.preemptions == 0
    assert res.makespan >= max(j.arrival for j in res.jobs)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_fifo_starts_in_arrival_order(seed):
    res = run_trace("fifo", seed=seed)
    jobs = sorted(res.jobs, key=lambda j: j.arrival)
    starts = [j.first_start_time for j in jobs]
    assert starts == sorted(starts)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_exclusive_policies_never_share(seed):
    """FIFO/SJF/Tiresias must keep <=1 job per GPU at all times; we verify
    via the event log (start/finish/preempt intervals per GPU)."""
    for policy in ("fifo", "sjf"):
        res = run_trace(policy, seed=seed)
        # rebuild occupancy over time from the log
        cluster_busy = {}
        # log entries: (time, kind, jid, [gpus])
        sim_log = res.jobs  # placeholders; occupancy verified via simulator
        # simpler: rerun with a C=1 cluster; identical schedule must succeed
        cfg = TraceConfig(n_jobs=16, seed=seed, mean_interarrival=60.0,
                          min_iters=50, max_iters=2000,
                          gpu_demand=((1, .4), (2, .25), (4, .2), (8, .15)))
        jobs = generate_trace(cfg)
        cluster = ClusterState(n_servers=2, gpus_per_server=4,
                               max_jobs_per_gpu=1,
                               gpu_capacity_bytes=11 * 2**30)
        sim = Simulator(cluster, jobs, make_scheduler(policy),
                        interference=paper_interference_model())
        sim.run()  # raises if the policy ever double-books a GPU


def test_sharing_policies_do_share():
    """Under pressure with mild interference, SJF-FFS and SJF-BSBF must
    actually co-locate jobs (otherwise they degenerate to SJF)."""
    shared_seen = {}
    for policy in ("sjf-ffs", "sjf-bsbf"):
        cfg = TraceConfig(n_jobs=24, seed=3, mean_interarrival=20.0,
                          min_iters=500, max_iters=5000,
                          gpu_demand=((2, .3), (4, .4), (8, .3)))
        jobs = generate_trace(cfg)
        cluster = ClusterState(n_servers=2, gpus_per_server=4,
                               gpu_capacity_bytes=11 * 2**30)
        sim = Simulator(cluster, jobs, make_scheduler(policy),
                        interference=InterferenceModel(global_xi=1.1))
        res = sim.run()
        # detect overlap: two running jobs sharing a GPU at some instant
        intervals = {}
        for j in res.jobs:
            intervals[j.jid] = (j.first_start_time, j.finish_time, j.placement)
        shared = False
        for t, kind, jid, *rest in sim.log:
            if kind == "start" and rest:
                gpus = rest[0]
                for other, (s, f, _) in intervals.items():
                    if other == jid:
                        continue
        # fall back to log-based: any GPU appearing in two concurrent starts
        active = {}
        for entry in sim.log:
            if entry[1] == "start":
                _, _, jid, gpus = entry
                for g in gpus:
                    active.setdefault(g, []).append(jid)
        for g, jids in active.items():
            # overlap iff two jobs on one GPU with overlapping [start,finish)
            for i in range(len(jids)):
                for k in range(i + 1, len(jids)):
                    a, b = intervals[jids[i]], intervals[jids[k]]
                    if max(a[0], b[0]) < min(a[1], b[1]) - 1e-6:
                        shared = True
        shared_seen[policy] = shared
    assert shared_seen["sjf-ffs"], "SJF-FFS never shared under pressure"
    assert shared_seen["sjf-bsbf"], "SJF-BSBF never shared under pressure"


def test_bsbf_avoids_sharing_under_high_interference():
    """Fig. 6b mechanism: with xi large, BSBF must refuse what FFS accepts."""
    def run(policy, xi):
        cfg = TraceConfig(n_jobs=24, seed=7, mean_interarrival=20.0,
                          min_iters=500, max_iters=5000,
                          gpu_demand=((2, .3), (4, .4), (8, .3)))
        jobs = generate_trace(cfg)
        cluster = ClusterState(n_servers=2, gpus_per_server=4,
                               gpu_capacity_bytes=11 * 2**30)
        sim = Simulator(cluster, jobs, make_scheduler(policy),
                        interference=InterferenceModel(global_xi=xi))
        return sim.run()

    res_ffs = run("sjf-ffs", 3.0)
    res_bsbf = run("sjf-bsbf", 3.0)
    assert res_bsbf.avg_jct() <= res_ffs.avg_jct() * 1.001
    # and with negligible interference the two coincide (paper Fig. 6b)
    res_ffs_lo = run("sjf-ffs", 1.05)
    res_bsbf_lo = run("sjf-bsbf", 1.05)
    assert res_bsbf_lo.avg_jct() == pytest.approx(res_ffs_lo.avg_jct(),
                                                  rel=0.15)


def test_make_scheduler_rejects_unknown():
    with pytest.raises(ValueError):
        make_scheduler("nope")


def test_paper_headline_ordering():
    """The paper's headline result on a mid-size workload: SJF-BSBF beats
    SJF-FFS, Tiresias and FIFO on average JCT."""
    import statistics
    out = {}
    for policy in ("fifo", "tiresias", "sjf-ffs", "sjf-bsbf"):
        vals = []
        for seed in range(3):
            cfg = TraceConfig(n_jobs=60, seed=seed, mean_interarrival=45.0,
                              min_iters=200, max_iters=20000,
                              gpu_demand=((1, .22), (2, .15), (4, .2),
                                          (8, .22), (12, .09), (16, .12)))
            jobs = generate_trace(cfg)
            cluster = ClusterState(n_servers=16, gpus_per_server=4,
                                   gpu_capacity_bytes=11 * 2**30)
            sim = Simulator(cluster, jobs, make_scheduler(policy),
                            interference=paper_interference_model())
            vals.append(sim.run().avg_jct())
        out[policy] = statistics.mean(vals)
    assert out["sjf-bsbf"] < out["sjf-ffs"]
    assert out["sjf-bsbf"] < out["tiresias"]
    assert out["sjf-bsbf"] < out["fifo"]
