"""Checkpoint roundtrip + synthetic data pipeline determinism."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, restore, save, save_pytree
from repro.configs import get_config
from repro.data import SyntheticLM, make_batch
from repro.models import init_params
from repro.train import adamw_init


def test_checkpoint_roundtrip():
    cfg = dataclasses.replace(get_config("whisper-tiny").reduced(),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save(path, params=params, opt_state=opt, step=7)
        p2, o2, step = restore(path, params_like=params, opt_like=opt)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((3, 4))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.npz")
        save_pytree(path, tree)
        import pytest
        with pytest.raises(ValueError):
            load_pytree(path, {"a": jnp.ones((4, 3))})


def test_data_determinism_and_labels():
    cfg = get_config("minicpm-2b").reduced()
    b1 = make_batch(cfg, 4, 32, step=5, seed=1)
    b2 = make_batch(cfg, 4, 32, step=5, seed=1)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next-token shifted
    full = make_batch(cfg, 4, 32, step=0, seed=0)
    assert (np.asarray(full["tokens"][:, 1:])
            == np.asarray(full["labels"][:, :-1])).all()
    # iterator yields different steps
    it = iter(SyntheticLM(cfg, 4, 32, seed=0))
    a, b = next(it), next(it)
    assert not (np.asarray(a["tokens"]) == np.asarray(b["tokens"])).all()


def test_modality_stubs_present():
    vlm = get_config("qwen2-vl-2b").reduced()
    audio = get_config("whisper-tiny").reduced()
    bv = make_batch(vlm, 2, 32)
    ba = make_batch(audio, 2, 32)
    assert bv["vision_embeds"].shape == (2, vlm.vision_tokens, vlm.d_model)
    assert ba["frames"].shape == (2, audio.encoder_seq, audio.d_model)


# ====================================================================== #
# Corrupted / missing checkpoint files (DESIGN.md §16)
# ====================================================================== #
def test_load_missing_file_raises_filenotfound(tmp_path):
    import pytest

    from repro.checkpoint import CheckpointError  # noqa: F401  (re-export)
    with pytest.raises(FileNotFoundError):
        load_pytree(str(tmp_path / "nope.npz"), {"a": jnp.ones((2,))})


def test_load_corrupted_file_raises_checkpoint_error(tmp_path):
    import pytest

    from repro.checkpoint import CheckpointError
    path = tmp_path / "garbage.npz"
    path.write_bytes(b"this is not an npz archive")
    with pytest.raises(CheckpointError) as ei:
        load_pytree(str(path), {"a": jnp.ones((2,))})
    assert ei.value.path == str(path)
    assert str(path) in str(ei.value)


def test_load_truncated_file_raises_checkpoint_error(tmp_path):
    import pytest

    from repro.checkpoint import CheckpointError
    path = tmp_path / "trunc.npz"
    save_pytree(str(path), {"a": jnp.arange(4096, dtype=jnp.float32)})
    raw = path.read_bytes()
    path.write_bytes(raw[:len(raw) // 2])
    with pytest.raises(CheckpointError):
        load_pytree(str(path), {"a": jnp.arange(4096, dtype=jnp.float32)})


def test_bit_rot_detected_by_content_crc(tmp_path):
    """A flipped bit inside a still-valid npz archive (the failure mode
    atomicity cannot catch) raises CheckpointError on load instead of
    silently restoring corrupt state."""
    import pytest

    from repro.checkpoint import CheckpointError
    path = tmp_path / "rot.npz"
    tree = {"a": jnp.arange(64, dtype=jnp.float32),
            "b": {"c": jnp.ones((4, 4))}}
    save_pytree(str(path), tree)
    # re-save the archive with one array element flipped but the ORIGINAL
    # stored CRC — a parseable-but-rotten file
    with np.load(str(path)) as data:
        members = {k: data[k].copy() for k in data.files}
    members["a"][17] += 1.0
    np.savez(str(path), **members)
    with pytest.raises(CheckpointError, match="CRC mismatch"):
        load_pytree(str(path), tree)


def test_checkpoint_crc_is_a_content_digest(tmp_path):
    """Equal content -> equal stored CRC (independent of write time and
    path); different content -> different CRC. This is the digest the
    fleet layer compares across processes."""
    from repro.checkpoint import checkpoint_crc
    tree = {"a": jnp.arange(8, dtype=jnp.float32), "step": jnp.asarray(3)}
    p1, p2, p3 = (str(tmp_path / n) for n in ("x.npz", "y.npz", "z.npz"))
    save_pytree(p1, tree)
    save_pytree(p2, tree)
    save_pytree(p3, {**tree, "step": jnp.asarray(4)})
    c1, c2, c3 = map(checkpoint_crc, (p1, p2, p3))
    assert c1 == c2 and c1 is not None
    assert c3 != c1
    # loading a checksummed file still round-trips
    out = load_pytree(p1, tree)
    assert (np.asarray(out["a"]) == np.arange(8)).all()


def test_legacy_checkpoint_without_crc_loads_unchecked(tmp_path):
    from repro.checkpoint import checkpoint_crc
    path = tmp_path / "legacy.npz"
    np.savez(str(path), a=np.arange(4, dtype=np.float32))
    assert checkpoint_crc(str(path)) is None
    out = load_pytree(str(path), {"a": jnp.zeros((4,), jnp.float32)})
    assert (np.asarray(out["a"]) == np.arange(4)).all()


def test_save_pytree_is_atomic_no_tmp_left(tmp_path):
    path = tmp_path / "ck.npz"
    save_pytree(str(path), {"a": jnp.ones((3,))})
    save_pytree(str(path), {"a": jnp.zeros((3,))})   # overwrite in place
    assert sorted(p.name for p in tmp_path.iterdir()) == ["ck.npz"]
    out = load_pytree(str(path), {"a": jnp.ones((3,))})
    assert (np.asarray(out["a"]) == 0).all()
