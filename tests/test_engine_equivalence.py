"""The event-heap engine must reproduce the pre-refactor scan engine
exactly: same event count and per-job finish times on a seeded trace for
every policy, under the paper's pair-table interference model, the
structural fallback model, and a global-xi injection (DESIGN.md §9)."""
import pytest

from repro.core import (ClusterState, InterferenceModel, Simulator,
                        make_scheduler, paper_interference_model,
                        simulation_trace)
from repro.core.schedulers import ALL_POLICIES

REL = 1e-6


def _run(policy, engine, interference=None, n_jobs=100):
    jobs = simulation_trace(n_jobs=n_jobs, seed=7)
    cluster = ClusterState(n_servers=16, gpus_per_server=4,
                           gpu_capacity_bytes=11 * 2 ** 30)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=interference or paper_interference_model(),
                    engine=engine)
    return sim.run()


def _assert_equivalent(a, b):
    assert a.events == b.events
    sa, sb = a.summary(), b.summary()
    for key, val in sa.items():
        assert sb[key] == pytest.approx(val, rel=REL, abs=REL), key
    for ja, jb in zip(sorted(a.jobs, key=lambda j: j.jid),
                      sorted(b.jobs, key=lambda j: j.jid)):
        assert jb.finish_time == pytest.approx(ja.finish_time, rel=REL)
        assert jb.waiting_time == pytest.approx(ja.waiting_time,
                                                rel=REL, abs=1e-3)
        assert jb.preemptions == ja.preemptions


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
def test_heap_matches_scan_paper_model(policy):
    _assert_equivalent(_run(policy, "scan"), _run(policy, "heap"))


@pytest.mark.parametrize("policy", ["sjf-ffs", "sjf-bsbf"])
def test_heap_matches_scan_structural_model(policy):
    """The structural xi fallback exercises the per-candidate xi path
    that the pair-table hoist skips."""
    _assert_equivalent(
        _run(policy, "scan", interference=InterferenceModel()),
        _run(policy, "heap", interference=InterferenceModel()))


@pytest.mark.parametrize("policy", ["sjf-bsbf", "tiresias"])
def test_heap_matches_scan_global_xi(policy):
    _assert_equivalent(
        _run(policy, "scan", interference=InterferenceModel(global_xi=1.4)),
        _run(policy, "heap", interference=InterferenceModel(global_xi=1.4)))


def test_engine_selection():
    res_scan = _run("sjf", "scan", n_jobs=30)
    res_heap = _run("sjf", "heap", n_jobs=30)
    assert res_scan.name == res_heap.name == "sjf"
    with pytest.raises(ValueError, match="unknown simulator engine"):
        _run("sjf", "btree", n_jobs=10)


def test_default_engine_is_heap():
    jobs = simulation_trace(n_jobs=10, seed=0)
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("fifo"))
    assert sim.engine_name == "heap"


def test_static_order_rekeys_requeued_jobs():
    """A job re-entering the queue after a preemption may carry a new
    sort key; the incremental order must detect it (via the preemption
    count) instead of replaying the stale position."""
    from repro.core.job import JobState
    from repro.core.schedulers import _StaticOrder
    from repro.core.perf_model import PerfParams
    from repro.core.job import Job

    def mk(jid, iters):
        perf = PerfParams(alpha_comp=0.0, beta_comp=1e-2, alpha_comm=0.0,
                          beta_comm=0.0, msg_bytes=0.0)
        return Job(jid=jid, model="m", arrival=0.0, gpus=1, iters=iters,
                   batch=10, perf=perf)

    a, b = mk(0, 100.0), mk(1, 200.0)
    order = _StaticOrder(lambda j: j.expected_remaining_time)
    assert order.order([a, b]) == [a, b]
    # b runs, progresses past a's remaining work, and is preempted
    b.state = JobState.RUNNING
    assert order.order([a]) == [a]
    b.iters_done = 150.0
    b.preemptions += 1
    b.state = JobState.PENDING
    assert order.order([a, b]) == [b, a]   # stale key would say [a, b]


def test_heap_deadlock_detection():
    """The heap engine must keep the scan engine's deadlock diagnostics
    (job larger than the cluster, no ticks to hide behind)."""
    jobs = simulation_trace(n_jobs=3, seed=1)
    big = max(jobs, key=lambda j: j.gpus)
    big.gpus = 999
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("fifo"), engine="heap")
    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run()
