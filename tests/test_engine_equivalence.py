"""The event-heap engine must reproduce the pre-refactor scan engine
exactly: same event count and per-job finish times on a seeded trace for
every policy, under the paper's pair-table interference model, the
structural fallback model, and a global-xi injection (DESIGN.md §9).

The second half of this file is the differential fuzz harness
(DESIGN.md §14): hypothesis generates small adversarial traces — random
arrivals, GPU demands, iteration counts — plus a chaos plan that injects
``preempt_job`` / ``reconfigure_job`` calls mid-run, and every spec is
replayed through HeapEngine vs ScanEngine (approximate equality, like
the seeded tests above) and through the grid / batched / scalar
sharing-decision paths on the same engine (bit-identical event logs and
``SimResults.summary()``). Any spec hypothesis ever shrinks to a failure
is appended to ``REGRESSION_SPECS`` so it keeps running as a plain
parametrized test in environments without hypothesis."""
import random

import pytest

from repro.core import (ClusterState, FaultModel, InterferenceModel,
                        Simulator, make_scheduler,
                        paper_interference_model, simulation_trace)
from repro.core.job import Job
from repro.core.perf_model import GPU_2080TI
from repro.core.schedulers import ALL_POLICIES
from repro.core.tasks import PAPER_TASK_PROFILES

from _hypothesis_compat import given, st

REL = 1e-6


def _run(policy, engine, interference=None, n_jobs=100):
    jobs = simulation_trace(n_jobs=n_jobs, seed=7)
    cluster = ClusterState(n_servers=16, gpus_per_server=4,
                           gpu_capacity_bytes=11 * 2 ** 30)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=interference or paper_interference_model(),
                    engine=engine)
    return sim.run()


def _assert_equivalent(a, b):
    assert a.events == b.events
    sa, sb = a.summary(), b.summary()
    for key, val in sa.items():
        assert sb[key] == pytest.approx(val, rel=REL, abs=REL), key
    for ja, jb in zip(sorted(a.jobs, key=lambda j: j.jid),
                      sorted(b.jobs, key=lambda j: j.jid)):
        assert jb.finish_time == pytest.approx(ja.finish_time, rel=REL)
        assert jb.waiting_time == pytest.approx(ja.waiting_time,
                                                rel=REL, abs=1e-3)
        assert jb.preemptions == ja.preemptions
        assert jb.failures == ja.failures
        assert jb.lost_iters == pytest.approx(ja.lost_iters,
                                              rel=REL, abs=1e-3)


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
def test_heap_matches_scan_paper_model(policy):
    _assert_equivalent(_run(policy, "scan"), _run(policy, "heap"))


@pytest.mark.parametrize("policy", ["sjf-ffs", "sjf-bsbf"])
def test_heap_matches_scan_structural_model(policy):
    """The structural xi fallback exercises the per-candidate xi path
    that the pair-table hoist skips."""
    _assert_equivalent(
        _run(policy, "scan", interference=InterferenceModel()),
        _run(policy, "heap", interference=InterferenceModel()))


@pytest.mark.parametrize("policy", ["sjf-bsbf", "tiresias"])
def test_heap_matches_scan_global_xi(policy):
    _assert_equivalent(
        _run(policy, "scan", interference=InterferenceModel(global_xi=1.4)),
        _run(policy, "heap", interference=InterferenceModel(global_xi=1.4)))


def test_engine_selection():
    res_scan = _run("sjf", "scan", n_jobs=30)
    res_heap = _run("sjf", "heap", n_jobs=30)
    assert res_scan.name == res_heap.name == "sjf"
    with pytest.raises(ValueError, match="unknown simulator engine"):
        _run("sjf", "btree", n_jobs=10)


def test_default_engine_is_heap():
    jobs = simulation_trace(n_jobs=10, seed=0)
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("fifo"))
    assert sim.engine_name == "heap"


def test_static_order_rekeys_requeued_jobs():
    """A job re-entering the queue after a preemption may carry a new
    sort key; the incremental order must detect it (via the preemption
    count) instead of replaying the stale position."""
    from repro.core.job import JobState
    from repro.core.schedulers import _StaticOrder
    from repro.core.perf_model import PerfParams
    from repro.core.job import Job

    def mk(jid, iters):
        perf = PerfParams(alpha_comp=0.0, beta_comp=1e-2, alpha_comm=0.0,
                          beta_comm=0.0, msg_bytes=0.0)
        return Job(jid=jid, model="m", arrival=0.0, gpus=1, iters=iters,
                   batch=10, perf=perf)

    a, b = mk(0, 100.0), mk(1, 200.0)
    order = _StaticOrder(lambda j: j.expected_remaining_time)
    assert order.order([a, b]) == [a, b]
    # b runs, progresses past a's remaining work, and is preempted
    b.state = JobState.RUNNING
    assert order.order([a]) == [a]
    b.iters_done = 150.0
    b.preemptions += 1
    b.state = JobState.PENDING
    assert order.order([a, b]) == [b, a]   # stale key would say [a, b]


def test_heap_deadlock_detection():
    """The heap engine must keep the scan engine's deadlock diagnostics
    (job larger than the cluster, no ticks to hide behind)."""
    jobs = simulation_trace(n_jobs=3, seed=1)
    big = max(jobs, key=lambda j: j.gpus)
    big.gpus = 999
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("fifo"), engine="heap")
    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run()


# ===================================================================== #
# Differential fuzz harness (DESIGN.md §14)
# ===================================================================== #
#
# A trace *spec* is a tuple of per-job primitives
#     (gap_centiseconds, model_index, gpus, iters)
# and a *chaos* plan
#     (chaos_seed, preempt_every, reconfig_every
#      [, fail_every, server_fail_every])
# where every-N of 0 disables that injection (the two fault-injection
# slots are optional so older 3-tuple corpus entries stay valid).
# Everything is integers so hypothesis shrinks cleanly and failed
# examples paste verbatim into REGRESSION_SPECS below.

_MODEL_NAMES = sorted(PAPER_TASK_PROFILES)
_FUZZ_GPUS = (1, 2, 4, 8, 12, 16)


def _jobs_from_spec(spec):
    jobs = []
    t = 0.0
    for jid, (gap_cs, model_i, gpus, iters) in enumerate(spec):
        t += gap_cs / 100.0
        name = _MODEL_NAMES[model_i % len(_MODEL_NAMES)]
        prof = PAPER_TASK_PROFILES[name]
        jobs.append(Job(jid=jid, model=name, arrival=t, gpus=gpus,
                        iters=float(iters), batch=prof.default_batch,
                        perf=prof.perf_params(gpus, GPU_2080TI)))
    return jobs


class ChaosScheduler:
    """Wraps a policy and, after every scheduling pass, deterministically
    injects the mutations schedulers are allowed to make — preempting a
    running job, shrinking a running job's sub-batch mid-run
    (``reconfigure_job``) — from a seeded RNG. The injection sequence
    depends only on the pass count and the (sorted) running set, so two
    simulators producing identical schedules receive identical chaos;
    any divergence the chaos amplifies is a real engine/decision-path
    divergence."""

    def __init__(self, inner, chaos_seed, preempt_every, reconfig_every,
                 fail_every=0, server_fail_every=0):
        self.inner = inner
        self.name = inner.name
        self.preemptive = inner.preemptive
        self.tick_interval = inner.tick_interval
        self.tick_only = inner.tick_only
        self.reads_running_progress = inner.reads_running_progress
        self.progress_scope = inner.progress_scope
        self._seed = chaos_seed
        self._preempt_every = preempt_every
        self._reconfig_every = reconfig_every
        self._fail_every = fail_every
        self._server_fail_every = server_fail_every
        self.reset()

    # each fault flavor stops after this many injections: an unbounded
    # kill loop can starve a full-cluster job of its next checkpoint
    # forever (progress truncates to zero every time), so the budget
    # guarantees every fuzz run terminates
    FAULT_BUDGET = 20

    def reset(self):
        self.inner.reset()
        self._rng = random.Random(self._seed)
        self._passes = 0
        self._fails_left = self.FAULT_BUDGET
        self._server_fails_left = self.FAULT_BUDGET

    def schedule(self, sim):
        self.inner.schedule(sim)
        self._passes += 1
        rng = self._rng
        if self._preempt_every and self._passes % self._preempt_every == 0:
            running = sorted(sim.running)
            if running:
                sim.preempt_job(sim.running[
                    running[rng.randrange(len(running))]])
                # preempt-then-place, like a real preemptive pass — the
                # victim must not strand with no future event to revive
                # it (non-preemptive policies never tick)
                self.inner.schedule(sim)
        if self._reconfig_every and self._passes % self._reconfig_every == 0:
            running = sorted(sim.running)
            if running:
                job = sim.running[running[rng.randrange(len(running))]]
                if job.sub_batch > 1:
                    # shrinking the sub-batch only reduces the memory
                    # footprint, so the reconfig is always feasible
                    sim.reconfigure_job(job, (job.sub_batch + 1) // 2)
        if (self._fail_every and self._fails_left
                and self._passes % self._fail_every == 0):
            running = sorted(sim.running)
            if running:
                self._fails_left -= 1
                sim.fail_job(sim.running[
                    running[rng.randrange(len(running))]])
                self.inner.schedule(sim)   # revive, like the preempt path
        if (self._server_fail_every and self._server_fails_left
                and self._passes % self._server_fail_every == 0):
            # repair_after keeps the event loop deadlock-free: the
            # recover event is a real future event in the fault heap
            self._server_fails_left -= 1
            sim.fail_server(rng.randrange(sim.cluster.n_servers),
                            repair_after=120.0)
            self.inner.schedule(sim)


def _fuzz_run(spec, chaos, policy, engine, decision=None):
    jobs = _jobs_from_spec(spec)
    cluster = ClusterState(n_servers=4, gpus_per_server=4,
                           gpu_capacity_bytes=11 * 2 ** 30)
    sched = ChaosScheduler(make_scheduler(policy), *chaos)
    # zero-rate model: empty precomputed timeline (bit-identical event
    # loop), but chaos fail_job injections truncate progress to its
    # 50-iteration checkpoints — exercising the recovery arithmetic on
    # every engine/decision path
    sim = Simulator(cluster, jobs, sched,
                    interference=paper_interference_model(),
                    engine=engine, decision=decision, max_events=500_000,
                    fault_model=FaultModel(checkpoint_interval=50.0))
    res = sim.run()
    return res, list(sim.log), res.summary()


def _fuzz_check(policy, spec, chaos):
    # 1. engines: heap vs scan on the default decision path, equal up to
    #    the accrual-order float tolerance of the seeded tests above
    res_h, log_h, sum_h = _fuzz_run(spec, chaos, policy, "heap")
    res_s, _, _ = _fuzz_run(spec, chaos, policy, "scan")
    _assert_equivalent(res_s, res_h)
    # 2. sharing-decision paths on the heap engine: the grid pass (the
    #    default, re-run explicitly), the per-job batched path, and the
    #    scalar reference must be BIT-identical — same event log record
    #    for record, same summary dict. (Without numpy all three resolve
    #    to scalar and the comparison is trivially true.)
    for decision in ("grid", "batched", "scalar"):
        _, log_d, sum_d = _fuzz_run(spec, chaos, policy, "heap", decision)
        assert log_d == log_h, (
            f"{decision} event log diverged from the default path "
            f"({len(log_d)} vs {len(log_h)} records)")
        assert sum_d == sum_h, f"{decision} summary diverged"


# Shrunk-regression corpus: any spec hypothesis ever shrank to a failure
# is appended here (name, spec, chaos) so it keeps running as a plain
# parametrized test — with or without hypothesis installed. The seeds
# below pin the structurally nasty shapes the harness is built around.
REGRESSION_SPECS = [
    # burst of large jobs saturating the cluster, small jobs queue behind
    ("burst-large-then-small",
     ((0, 0, 16, 300), (0, 1, 16, 300), (0, 2, 1, 60), (1, 3, 1, 60)),
     (7, 3, 2)),
    # lone job repeatedly preempted (restart-penalty accounting)
    ("lone-job-preempt-loop", ((0, 4, 4, 400),), (1, 2, 0)),
    # simultaneous arrivals, jid tie-breaks under chaos reconfigs
    ("simultaneous-arrivals",
     ((0, 0, 2, 150), (0, 1, 2, 150), (0, 2, 2, 150), (0, 3, 2, 150)),
     (11, 0, 2)),
    # staggered mix with both injections active
    ("staggered-mixed-chaos",
     ((50, 5, 8, 500), (200, 0, 1, 40), (0, 1, 12, 800), (300, 2, 4, 90),
      (10, 3, 1, 25)),
     (3, 4, 3)),
    # chaos disabled: pure trace-shape differential
    ("no-chaos-baseline",
     ((0, 0, 1, 20), (10000, 1, 16, 1000), (0, 2, 8, 200)),
     (0, 0, 0)),
    # fault injections (DESIGN.md §16): job crashes truncating progress
    # to checkpoints, plus correlated server kills with in-heap repairs —
    # requeue ordering, peer restore, and down-server placement must stay
    # identical across engines and decision paths
    ("fault-chaos-mixed",
     ((0, 0, 8, 400), (100, 1, 4, 200), (0, 2, 2, 120), (500, 3, 1, 60),
      (0, 4, 4, 300)),
     (13, 0, 2, 3, 5)),
    ("server-kill-storm",
     ((0, 5, 16, 600), (0, 0, 2, 80), (200, 1, 2, 80), (0, 2, 1, 40)),
     (5, 3, 0, 4, 2)),
]


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
@pytest.mark.parametrize(
    "case", REGRESSION_SPECS, ids=[c[0] for c in REGRESSION_SPECS])
def test_fuzz_regression_corpus(policy, case):
    _, spec, chaos = case
    _fuzz_check(policy, spec, chaos)


_JOB_ST = st.tuples(
    st.integers(min_value=0, max_value=30000),            # arrival gap (cs)
    st.integers(min_value=0, max_value=len(_MODEL_NAMES) - 1),
    st.sampled_from(_FUZZ_GPUS),
    st.integers(min_value=20, max_value=2000),            # iterations
)
_SPEC_ST = st.lists(_JOB_ST, min_size=1, max_size=12)
_CHAOS_ST = st.tuples(
    st.integers(min_value=0, max_value=2 ** 16),          # chaos seed
    st.sampled_from((0, 2, 3, 5)),                        # preempt every
    st.sampled_from((0, 2, 4)),                           # reconfig every
    st.sampled_from((0, 3, 5)),                           # fail every
    st.sampled_from((0, 4)),                              # server-fail every
)


@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
@given(spec=_SPEC_ST, chaos=_CHAOS_ST)
def test_fuzz_differential(policy, spec, chaos):
    """Random traces + chaos injections: heap == scan (approx), and
    grid == batched == scalar (bit-identical logs), for every policy."""
    _fuzz_check(policy, tuple(spec), tuple(chaos))
