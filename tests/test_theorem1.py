"""Property tests for Theorem 1: the optimal insertion time kappa for a
job pair is at an endpoint (kappa=0 full overlap, or kappa=t_A*i_A fully
sequential). We verify against a brute-force kappa grid."""
import math

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.pair import (PairJob, best_pair_schedule,
                             monotonicity_coefficient, pair_timeline)

pos_t = st.floats(1e-3, 10.0)
iters = st.floats(1.0, 5000.0)
xi = st.floats(1.0, 6.0)


@given(pos_t, iters, xi, pos_t, iters, xi)
@settings(max_examples=300, deadline=None)
def test_endpoints_are_optimal(ta, ia, xa, tb, ib, xb):
    a = PairJob(t_iter=ta, iters=ia, xi=xa)
    b = PairJob(t_iter=tb, iters=ib, xi=xb)
    dec = best_pair_schedule(a, b)
    grid_n = 33
    best_interior = math.inf
    for k in range(grid_n + 1):
        kappa = a.solo_time * k / grid_n
        t_a, t_b = pair_timeline(a, b, kappa)
        best_interior = min(best_interior, 0.5 * (t_a + t_b))
    assert dec.avg_jct <= best_interior + 1e-6 * max(1.0, best_interior)


@given(pos_t, iters, xi, pos_t, iters, xi)
@settings(max_examples=200, deadline=None)
def test_timeline_sanity(ta, ia, xa, tb, ib, xb):
    a = PairJob(t_iter=ta, iters=ia, xi=xa)
    b = PairJob(t_iter=tb, iters=ib, xi=xb)
    for kappa in (0.0, 0.37 * a.solo_time, a.solo_time, 2.0 * a.solo_time):
        t_a, t_b = pair_timeline(a, b, kappa)
        # A can never finish before its solo time, nor after fully-shared time
        assert t_a >= a.solo_time - 1e-9
        assert t_a <= a.solo_time * a.xi + 1e-9 * max(1, a.solo_time)
        # B finishes after its launch + its solo time
        assert t_b >= kappa + b.solo_time - 1e-9
        # and no later than launch + fully-interfered execution
        assert t_b <= kappa + b.solo_time * b.xi + max(1.0, t_a) * 1e-6 + a.solo_time * a.xi


def test_sequential_matches_sum():
    a = PairJob(t_iter=1.0, iters=100, xi=2.0)
    b = PairJob(t_iter=2.0, iters=50, xi=2.0)
    t_a, t_b = pair_timeline(a, b, a.solo_time)
    assert t_a == pytest.approx(100.0)
    assert t_b == pytest.approx(100.0 + 100.0)


def test_no_interference_prefers_overlap():
    a = PairJob(t_iter=1.0, iters=100, xi=1.0)
    b = PairJob(t_iter=1.0, iters=100, xi=1.0)
    dec = best_pair_schedule(a, b)
    assert dec.share and dec.kappa == 0.0
    assert dec.avg_jct == pytest.approx(100.0)


def test_severe_interference_prefers_sequential():
    # xi=3 for both: sharing doubles+ everyone; sequential is better on avg
    a = PairJob(t_iter=1.0, iters=100, xi=3.0)
    b = PairJob(t_iter=1.0, iters=100, xi=3.0)
    dec = best_pair_schedule(a, b)
    assert not dec.share
    assert dec.avg_jct == pytest.approx(0.5 * (100 + 200))


def test_monotonicity_coefficient_sign_matches_decision():
    # Paper Eq. 24: positive coefficient => avg JCT increases with kappa
    # => kappa=0 optimal. Check consistency when B outlasts A under sharing
    # (the regime where Eq. 24 applies).
    for xa, xb in [(1.1, 1.1), (1.4, 1.2), (2.5, 2.5), (3.0, 1.2)]:
        a = PairJob(t_iter=1.0, iters=50, xi=xa)
        b = PairJob(t_iter=1.0, iters=500, xi=xb)   # B much longer
        coef = monotonicity_coefficient(a, b)
        dec = best_pair_schedule(a, b)
        if coef > 1e-9:
            assert dec.share, (xa, xb, coef)


def test_pair_timeline_rejects_negative_kappa():
    a = PairJob(1.0, 10, 1.5)
    with pytest.raises(ValueError):
        pair_timeline(a, a, -1.0)
