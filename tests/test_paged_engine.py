"""Paged KV cache: paged == dense token identity across cache families,
page-table growth/reclaim on slot reuse, and the paged flash-decode
kernel against its gather oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.launch import serve
from repro.launch.engine import DecodeEngine
from repro.models import init_paged_cache, init_params
from repro.models.attention import attention_decode, attention_init

# every family with a linear KV cache (the ones paging applies to)
PAGED_ARCHS = [
    ("minicpm-2b", {}),                                    # dense
    ("granite-moe-3b-a800m", {"moe_capacity_factor": 8.0}),  # moe
    ("qwen2-vl-2b", {}),                                   # vlm
    ("zamba2-7b", {}),                                     # hybrid + SSM state
]


def _cfg(name, **kw):
    return dataclasses.replace(get_config(name).reduced(),
                               dtype="float32", **kw)


# ====================================================================== #
# paged flash-decode kernel
# ====================================================================== #
class TestFlashDecodePagedKernel:
    def test_matches_gather_oracle(self):
        rng = np.random.default_rng(0)
        b, h, hkv, d = 3, 4, 2, 16               # GQA groups = 2
        ps, n_pg, p_tab = 8, 11, 4               # table covers 32 rows
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        pool_k = jnp.asarray(rng.standard_normal((n_pg, ps, hkv, d)),
                             jnp.float32)
        pool_v = jnp.asarray(rng.standard_normal((n_pg, ps, hkv, d)),
                             jnp.float32)
        lengths = jnp.asarray([5, 17, 32], jnp.int32)
        pages = np.full((b, p_tab), -1, np.int32)
        free = list(range(n_pg))
        for bi in range(b):
            for pi in range(-(-int(lengths[bi]) // ps)):
                pages[bi, pi] = free.pop()
        pages = jnp.asarray(pages)

        out = ops.flash_decode_paged(q, pool_k, pool_v, pages, lengths)

        gk = pool_k[jnp.maximum(pages, 0)].reshape(b, p_tab * ps, hkv, d)
        gv = pool_v[jnp.maximum(pages, 0)].reshape(b, p_tab * ps, hkv, d)
        rep = lambda t: jnp.repeat(t, h // hkv, axis=2)   # noqa: E731
        from repro.kernels.ref import flash_decode_ref
        ref = flash_decode_ref(q, rep(gk), rep(gv), lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


# ====================================================================== #
# attention_decode paged branch
# ====================================================================== #
class TestAttentionDecodePaged:
    def _setup(self, b=3, max_len=32, ps=8, n_pg=16):
        rng = np.random.default_rng(1)
        d_model, nh, nkv, hd = 64, 4, 2, 16
        p = attention_init(jax.random.PRNGKey(0), d_model, nh, nkv, hd)
        x = jnp.asarray(rng.standard_normal((b, 1, d_model)), jnp.float32)
        idx = jnp.asarray([3, 11, 30])
        p_tab = max_len // ps
        dense = {"k": jnp.asarray(rng.standard_normal((b, max_len, nkv, hd)),
                                  jnp.float32),
                 "v": jnp.asarray(rng.standard_normal((b, max_len, nkv, hd)),
                                  jnp.float32)}
        # build pool + tables holding the same rows as the dense cache
        pages = np.full((b, p_tab), -1, np.int32)
        pool_k = np.zeros((n_pg, ps, nkv, hd), np.float32)
        pool_v = np.zeros((n_pg, ps, nkv, hd), np.float32)
        free = list(range(n_pg))
        for bi in range(b):
            for pi in range(-(-(int(idx[bi]) + 1) // ps)):
                pg = free.pop()
                pages[bi, pi] = pg
                pool_k[pg] = np.asarray(dense["k"][bi, pi * ps:(pi + 1) * ps])
                pool_v[pg] = np.asarray(dense["v"][bi, pi * ps:(pi + 1) * ps])
        paged = {"k": jnp.asarray(pool_k), "v": jnp.asarray(pool_v)}
        kw = dict(n_heads=nh, n_kv_heads=nkv, head_dim=hd)
        return p, x, idx, dense, paged, jnp.asarray(pages), kw

    def test_paged_jnp_bitwise_equals_dense(self):
        p, x, idx, dense, paged, pages, kw = self._setup()
        out_d, _ = attention_decode(p, x, None, None, dense, idx, **kw)
        out_p, _ = attention_decode(p, x, None, None, paged, idx,
                                    pages=pages, **kw)
        assert (np.asarray(out_d) == np.asarray(out_p)).all()

    def test_paged_kernel_close_to_dense_kernel(self):
        p, x, idx, dense, paged, pages, kw = self._setup()
        out_d, _ = attention_decode(p, x, None, None, dense, idx,
                                    use_kernel=True, **kw)
        out_p, _ = attention_decode(p, x, None, None, paged, idx,
                                    use_kernel=True, pages=pages, **kw)
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_p),
                                   atol=1e-5, rtol=1e-5)

    def test_write_lands_in_owning_page(self):
        p, x, idx, dense, paged, pages, kw = self._setup()
        _, cache_p = attention_decode(p, x, None, None, paged, idx,
                                      pages=pages, **kw)
        _, cache_d = attention_decode(p, x, None, None, dense, idx, **kw)
        ps = paged["k"].shape[1]
        for bi, i in enumerate(np.asarray(idx)):
            pg = int(pages[bi, i // ps])
            np.testing.assert_array_equal(
                np.asarray(cache_p["k"][pg, i % ps]),
                np.asarray(cache_d["k"][bi, i]))

    def test_unassigned_page_write_drops(self):
        """An example whose table has no page for its index (an inactive
        engine slot) must not corrupt the pool — in particular not the
        LAST page, which a wrapping ``.at[-1]`` would hit."""
        p, x, idx, dense, paged, pages, kw = self._setup()
        blank = jnp.full_like(pages, -1)
        _, cache_p = attention_decode(p, x, None, None, paged, idx,
                                      pages=blank, **kw)
        assert (np.asarray(cache_p["k"]) == np.asarray(paged["k"])).all()
        assert (np.asarray(cache_p["v"]) == np.asarray(paged["v"])).all()


# ====================================================================== #
# init_paged_cache contract
# ====================================================================== #
class TestInitPagedCache:
    def test_rejects_indivisible_page_size(self):
        cfg = _cfg("minicpm-2b")
        with pytest.raises(AssertionError):
            init_paged_cache(cfg, 2, 33, page_size=8, n_pages=8)

    def test_rejects_sliding_window(self):
        cfg = _cfg("glm4-9b", sliding_window=8)
        with pytest.raises(AssertionError):
            init_paged_cache(cfg, 2, 32, page_size=8, n_pages=8)

    def test_rejects_pure_ssm(self):
        cfg = _cfg("xlstm-1.3b")
        with pytest.raises(ValueError):
            init_paged_cache(cfg, 2, 32, page_size=8, n_pages=8)

    def test_pool_shapes(self):
        cfg = _cfg("minicpm-2b")
        cache = init_paged_cache(cfg, 2, 32, page_size=8, n_pages=8)
        assert cache["pages"].shape == (2, 4)
        assert (np.asarray(cache["pages"]) == -1).all()
        k = cache["units"]["k"]
        assert k.shape == (cfg.n_units, 8, 8, cfg.n_kv_heads, cfg.head_dim)


# ====================================================================== #
# paged DecodeEngine
# ====================================================================== #
class TestPagedEngine:
    @pytest.mark.parametrize("name,kw", PAGED_ARCHS)
    def test_paged_tokens_identical_to_dense_and_solo(self, name, kw):
        """The PR-4 slot no-leak scenario, run through the paged engine:
        more requests than slots, every request must match both the dense
        engine and its solo generation bit for bit."""
        cfg = _cfg(name, **kw)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, (pl,))
                   for pl in (5, 8, 3, 8, 6)]
        news = [7, 4, 9, 6, 5]
        dense = DecodeEngine(cfg, params, n_slots=2, max_len=32, segment=4)
        rd = [dense.submit(p, n) for p, n in zip(prompts, news)]
        out_d = dense.run()
        paged = DecodeEngine(cfg, params, n_slots=2, max_len=32, segment=4,
                             paged=True, page_size=8, n_pages=8)
        rp = [paged.submit(p, n) for p, n in zip(prompts, news)]
        out_p = paged.run()
        for a, b, prompt, n in zip(rd, rp, prompts, news):
            assert out_d[a] == out_p[b], f"request {b} diverged from dense"
            solo = serve.generate(
                cfg, params, jnp.asarray(prompt, jnp.int32)[None, :],
                max_new_tokens=n, max_len=32)
            assert out_p[b] == [int(t) for t in np.asarray(solo)[0]], \
                f"request {b} diverged from its solo generation"

    def test_paged_kernel_tokens_identical_to_dense_kernel(self):
        cfg = _cfg("minicpm-2b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, (pl,)) for pl in (5, 8, 3)]
        outs = []
        for paged in (False, True):
            eng = DecodeEngine(cfg, params, n_slots=2, max_len=32,
                               segment=4, use_kernels=True, paged=paged,
                               page_size=8, n_pages=8)
            rids = [eng.submit(p, 6) for p in prompts]
            out = eng.run()
            outs.append([out[r] for r in rids])
        assert outs[0] == outs[1]

    def test_growth_and_reclaim_on_slot_reuse(self):
        """Pages are assigned lazily (prompt pages at admission, decode
        pages one segment ahead) and every page and reservation returns
        to the pool when a slot frees — across slot reuse."""
        cfg = _cfg("minicpm-2b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        eng = DecodeEngine(cfg, params, n_slots=2, max_len=32, segment=4,
                           paged=True, page_size=8, n_pages=8)
        # need = 5 prompt + 8 decode rows = 13 -> reserve 2 pages, but
        # only 1 is assigned at admission (prompt fits one page)
        eng.submit(rng.integers(0, cfg.vocab, (5,)), 8)
        eng._admit()
        assert eng._slot_npages[0] == 1 and eng._slot_reserve[0] == 2
        assert eng._avail_pages == 8 - 2
        eng._grow()           # covers rows [0, 5+4) -> second page assigned
        assert eng._slot_npages[0] == 2
        assert len(eng._free_pages) == 8 - 2
        # drain; then run more requests through the same slots
        while eng.queue or eng.active.any():
            eng.step_segment()
        for _ in range(3):
            eng.submit(rng.integers(0, cfg.vocab, (6,)), 7)
        eng.run()
        # full reclaim: every page free, every reservation returned
        assert sorted(eng._free_pages) == list(range(8))
        assert eng._avail_pages == 8
        assert (eng._pages_np == -1).all()
        assert (eng._slot_npages == 0).all()
        assert (eng._slot_reserve == 0).all()
        assert eng.stats["pages_in_use"] >= 0
        assert eng.stats["peak_pages_in_use"] > 0

    def test_admission_defers_until_pages_free(self):
        """With pages for only two concurrent requests, the rest of the
        queue waits (FIFO) and still completes identical to solo."""
        cfg = _cfg("minicpm-2b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        eng = DecodeEngine(cfg, params, n_slots=4, max_len=32, segment=4,
                           paged=True, page_size=8, n_pages=4)
        prompts = [rng.integers(0, cfg.vocab, (5,)) for _ in range(4)]
        rids = [eng.submit(p, 7) for p in prompts]
        out = eng.run()
        assert eng.stats["admission_deferred_pages"] > 0
        assert eng.stats["peak_active_slots"] == 2   # 4 pages / 2 per req
        for rid, prompt in zip(rids, prompts):
            solo = serve.generate(
                cfg, params, jnp.asarray(prompt, jnp.int32)[None, :],
                max_new_tokens=7, max_len=32)
            assert out[rid] == [int(t) for t in np.asarray(solo)[0]]

    def test_more_slots_than_dense_at_equal_memory(self):
        """The acceptance scenario in miniature: at the same pool rows a
        paged engine runs 4x the concurrent requests of the dense one."""
        cfg = _cfg("minicpm-2b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, (8,)) for _ in range(8)]
        # dense: 2 slots x 64 rows = 128; paged: same 128 rows as 16 pages
        dense = DecodeEngine(cfg, params, n_slots=2, max_len=64, segment=8)
        rd = [dense.submit(p, 8) for p in prompts]
        out_d = dense.run()
        paged = DecodeEngine(cfg, params, n_slots=8, max_len=64, segment=8,
                             paged=True, page_size=8, n_pages=16)
        rp = [paged.submit(p, 8) for p in prompts]
        out_p = paged.run()
        assert [out_d[a] for a in rd] == [out_p[b] for b in rp]
        assert dense.stats["peak_active_slots"] == 2
        assert paged.stats["peak_active_slots"] == 8      # 4x
        assert paged.stats["segments"] < dense.stats["segments"]

    def test_rejects_non_linear_kv(self):
        cfg = _cfg("xlstm-1.3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="linear"):
            DecodeEngine(cfg, params, n_slots=2, max_len=32, paged=True)
        cfg = _cfg("glm4-9b", sliding_window=8)
        params = init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="linear"):
            DecodeEngine(cfg, params, n_slots=2, max_len=32, paged=True)
