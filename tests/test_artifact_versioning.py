"""Versioned on-disk artifacts must fail loudly and descriptively: a
stale or schema-broken ``calibration.json`` / ``autotune.json`` raises
:class:`ArtifactVersionError` naming the file, the found and the
expected version — never a bare KeyError from deep inside a consumer.
The error subclasses ValueError so existing lenient guards (treat a
stale artifact as "no artifact") keep working."""
import json

import pytest

from repro.core.calibration import (CALIBRATION_VERSION, load_artifact,
                                    save_artifact)
from repro.kernels import autotune
from repro.util.errors import ArtifactVersionError


def _calib_payload():
    return {"version": CALIBRATION_VERSION, "gpu": {}, "profiles": {},
            "pairs": []}


class TestCalibrationArtifact:
    def test_roundtrip_ok(self, tmp_path):
        path = save_artifact(_calib_payload(), str(tmp_path / "c.json"))
        assert load_artifact(path)["version"] == CALIBRATION_VERSION

    def test_stale_version_raises_descriptively(self, tmp_path):
        payload = _calib_payload()
        payload["version"] = CALIBRATION_VERSION + 1
        path = tmp_path / "c.json"
        path.write_text(json.dumps(payload))
        with pytest.raises(ArtifactVersionError) as ei:
            load_artifact(str(path))
        err = ei.value
        assert isinstance(err, ValueError)
        assert err.path == str(path)
        assert err.found == CALIBRATION_VERSION + 1
        assert err.expected == CALIBRATION_VERSION
        msg = str(err)
        assert str(path) in msg and "calibration artifact" in msg
        assert "re-run benchmarks/calibrate.py" in msg

    def test_missing_version_field_raises(self, tmp_path):
        payload = _calib_payload()
        del payload["version"]
        path = tmp_path / "c.json"
        path.write_text(json.dumps(payload))
        with pytest.raises(ArtifactVersionError) as ei:
            load_artifact(str(path))
        assert ei.value.found is None


class TestAutotuneArtifact:
    def _payload(self):
        return {"version": autotune.AUTOTUNE_VERSION, "entries": {},
                "meta": {"backend": "cpu"}}

    def test_table_accepts_current_schema(self):
        table = autotune.AutotuneTable(self._payload())
        assert table.entries == {}

    def test_wrong_version_raises(self):
        payload = self._payload()
        payload["version"] = autotune.AUTOTUNE_VERSION + 3
        with pytest.raises(ArtifactVersionError) as ei:
            autotune.AutotuneTable(payload)
        assert ei.value.expected == autotune.AUTOTUNE_VERSION
        assert ei.value.found == autotune.AUTOTUNE_VERSION + 3
        assert "autotune artifact" in str(ei.value)

    @pytest.mark.parametrize("missing", ["entries", "meta"])
    def test_missing_schema_field_raises(self, missing):
        payload = self._payload()
        del payload[missing]
        with pytest.raises(ArtifactVersionError, match=missing):
            autotune.AutotuneTable(payload)

    def test_missing_backend_raises(self):
        payload = self._payload()
        del payload["meta"]["backend"]
        with pytest.raises(ArtifactVersionError, match="backend"):
            autotune.AutotuneTable(payload)

    def test_load_artifact_names_the_file(self, tmp_path):
        path = tmp_path / "autotune.json"
        payload = self._payload()
        payload["version"] = 999
        path.write_text(json.dumps(payload))
        with pytest.raises(ArtifactVersionError) as ei:
            autotune.load_artifact(str(path))
        assert ei.value.path == str(path)

    def test_stale_artifact_still_reads_as_value_error(self):
        # the lenient lazy-load guard catches ValueError; a stale table
        # must stay inside that contract
        payload = self._payload()
        payload["version"] = 0
        with pytest.raises(ValueError):
            autotune.AutotuneTable(payload)
