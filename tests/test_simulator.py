"""Deterministic simulator tests: hand-computed two-job timelines must
match the analytic pair model, progress conservation, waiting accounting."""
import pytest

from repro.core import (ClusterState, InterferenceModel, Job, PerfParams,
                        Simulator, make_scheduler)
from repro.core.pair import PairJob, pair_timeline

GB = 2 ** 30


def mk_job(jid, arrival, gpus, iters, beta=1e-2, batch=10):
    perf = PerfParams(alpha_comp=0.0, beta_comp=beta, alpha_comm=0.0,
                      beta_comm=0.0, msg_bytes=0.0, mem_base=1 * GB,
                      mem_per_sample=0.01 * GB)
    return Job(jid=jid, model="m", arrival=arrival, gpus=gpus, iters=iters,
               batch=batch, perf=perf)


def test_single_job_runs_solo_exactly():
    job = mk_job(0, arrival=0.0, gpus=4, iters=100)
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    sim = Simulator(cluster, [job], make_scheduler("fifo"))
    res = sim.run()
    # t_iter = beta*batch = 0.1s; 100 iters -> 10s
    assert job.finish_time == pytest.approx(10.0)
    assert res.makespan == pytest.approx(10.0)
    assert job.queueing_delay() == 0.0


def test_two_jobs_sequential_when_exclusive():
    j0 = mk_job(0, 0.0, 4, 100)
    j1 = mk_job(1, 1.0, 4, 50)
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    sim = Simulator(cluster, [j0, j1], make_scheduler("fifo"))
    sim.run()
    assert j0.finish_time == pytest.approx(10.0)
    assert j1.first_start_time == pytest.approx(10.0)
    assert j1.finish_time == pytest.approx(15.0)
    assert j1.queueing_delay() == pytest.approx(9.0)


def test_shared_pair_matches_pair_timeline():
    """When SJF-BSBF decides to share, the simulated finish times must
    reproduce the Theorem-1 timeline (same xi both sides)."""
    xi = 1.2
    j0 = mk_job(0, 0.0, 4, 200)          # t_iter 0.1 -> solo 20s
    j1 = mk_job(1, 2.0, 4, 100)          # arrives while j0 runs
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    interf = InterferenceModel(global_xi=xi)
    sim = Simulator(cluster, [j0, j1], make_scheduler("sjf-bsbf"),
                    interference=interf)
    sim.run()
    # at t=2: j0 has 180 iters left; pair model from that instant:
    a = PairJob(t_iter=0.1, iters=180, xi=xi)
    b = PairJob(t_iter=0.1, iters=100, xi=xi)
    t_a, t_b = pair_timeline(a, b, 0.0)
    assert j0.finish_time == pytest.approx(2.0 + t_a, rel=1e-6)
    assert j1.finish_time == pytest.approx(2.0 + t_b, rel=1e-6)
    assert j1.queueing_delay() == pytest.approx(0.0, abs=1e-9)


def test_progress_conservation_under_rate_changes():
    """Total processed iterations at any completion equal the job's I_k even
    when co-runners come and go (rates change mid-flight)."""
    jobs = [mk_job(0, 0.0, 4, 300), mk_job(1, 1.0, 4, 100),
            mk_job(2, 2.0, 4, 50)]
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("sjf-ffs"),
                    interference=InterferenceModel(global_xi=1.3))
    res = sim.run()
    for j in res.jobs:
        assert j.iters_done == pytest.approx(j.iters, rel=1e-9)


def test_gang_all_or_nothing():
    """A job must never run on fewer GPUs than requested."""
    j0 = mk_job(0, 0.0, 3, 100)
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    sim = Simulator(cluster, [j0], make_scheduler("fifo"))
    sim.run()
    # log records the full placement at start
    starts = [e for e in sim.log if e[1] == "start"]
    assert len(starts[0][3]) == 3


def test_deadlock_detection():
    """A job requesting more GPUs than the cluster has must raise."""
    j0 = mk_job(0, 0.0, 8, 100)
    cluster = ClusterState(n_servers=1, gpus_per_server=4)
    sim = Simulator(cluster, [j0], make_scheduler("fifo"))
    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run()


def test_restart_penalty_accounted_as_waiting():
    """Preempted jobs pay the restart penalty and it shows up as waiting."""
    jobs = [mk_job(0, 0.0, 8, 20000), mk_job(1, 10.0, 8, 20)]
    cluster = ClusterState(n_servers=2, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("tiresias"),
                    restart_penalty=30.0)
    res = sim.run()
    j0 = res.jobs[0]
    if j0.preemptions > 0:
        assert j0.waiting_time > 0.0
