"""Degraded-mode serving (DESIGN.md §16): per-request deadlines with
timeout-shedding (queued and mid-decode), admission brown-out under
overload with priority ordering, deadline-miss accounting, and bounded
retry of transient segment faults — all off by default (the engine with
no deadlines/injector is token-identical to the plain engine)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.cluster import FaultSpec, ScriptedFaults
from repro.launch.engine import DecodeEngine
from repro.models import init_params
from repro.util.retry import RetryPolicy


class ManualClock:
    """Injectable engine clock: explicit advance, optional per-call
    auto-increment (to age a request between the shed pre-pass and the
    completion check inside one segment)."""

    def __init__(self, dt=0.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        now = self.t
        self.t += self.dt
        return now

    def advance(self, d):
        self.t += d


def _setup(seed=0):
    cfg = dataclasses.replace(get_config("minicpm-2b").reduced(),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(4)]
    return cfg, params, prompts


def _engine(cfg, params, clock=None, **kw):
    kw.setdefault("n_slots", 1)
    kw.setdefault("max_len", 32)
    kw.setdefault("segment", 4)
    kw.setdefault("sleep", lambda d: None)
    if clock is not None:
        kw["clock"] = clock
    return DecodeEngine(cfg, params, **kw)


class TestBaselineUnchanged:
    def test_degraded_knobs_off_are_token_identical(self):
        cfg, params, prompts = _setup()
        plain = _engine(cfg, params, n_slots=2)
        out_plain = {}
        for p in prompts[:3]:
            out_plain[plain.submit(p, 8)] = None
        out_plain = plain.run()

        clocked = _engine(cfg, params, clock=ManualClock(), n_slots=2,
                          brownout_depth=0,
                          retry_policy=RetryPolicy(attempts=2))
        for p in prompts[:3]:
            clocked.submit(p, 8)
        out_clocked = clocked.run()
        assert out_clocked == out_plain
        assert clocked.shed == {} and clocked.retry_after == {}
        assert clocked.stats["shed_deadline"] == 0
        assert clocked.stats["shed_brownout"] == 0
        assert clocked.stats["deadline_miss"] == 0


class TestDeadlineShedding:
    def test_queued_request_past_deadline_never_admits(self):
        cfg, params, prompts = _setup()
        clock = ManualClock()
        eng = _engine(cfg, params, clock=clock)
        r0 = eng.submit(prompts[0], 8)               # occupies the slot
        r1 = eng.submit(prompts[1], 8, deadline=5.0)
        eng.step_segment()                           # r0 admitted, r1 queued
        clock.advance(10.0)
        out = eng.run()
        assert eng.shed == {r1: "deadline"}
        assert out[r1] == []                         # never decoded
        assert len(out[r0]) == 8
        assert eng.stats["shed_deadline"] == 1
        assert eng.retry_after[r1] >= 0.0

    def test_active_slot_past_deadline_frees_and_keeps_partial(self):
        cfg, params, prompts = _setup()
        clock = ManualClock()
        eng = _engine(cfg, params, clock=clock)
        r0 = eng.submit(prompts[0], 12, deadline=5.0)
        eng.step_segment()                           # 4 of 12 tokens decoded
        assert eng.active[0]
        clock.advance(10.0)
        eng.step_segment()                           # shed pre-pass fires
        assert not eng.active.any()
        assert eng.shed == {r0: "deadline"}
        assert len(eng.outputs[r0]) == 4             # partial output kept
        assert eng.slot_rid[0] == -1
        assert eng.slot_deadline[0] is None
        # the EWMA of one measured segment yields a positive hint
        assert eng.retry_after[r0] > 0.0

    def test_completed_but_late_counts_deadline_miss(self):
        cfg, params, prompts = _setup()
        # every clock read advances 0.3s: submit at 0.0, shed check at
        # 0.3 (< deadline 0.5), completion check at 0.6 (> deadline)
        eng = _engine(cfg, params, clock=ManualClock(dt=0.3))
        r0 = eng.submit(prompts[0], 4, deadline=0.5)
        eng.step_segment()
        assert len(eng.outputs[r0]) == 4             # delivered in full
        assert eng.stats["deadline_miss"] == 1
        assert r0 not in eng.shed                    # late, not shed


class TestBrownout:
    def test_lowest_priority_then_youngest_shed_first(self):
        cfg, params, prompts = _setup()
        clock = ManualClock()
        eng = _engine(cfg, params, clock=clock, brownout_depth=1)
        r0 = eng.submit(prompts[0], 8)
        eng.step_segment()                 # r0 takes the only slot
        clock.advance(1.0)
        r1 = eng.submit(prompts[1], 8, priority=1)
        clock.advance(1.0)
        r2 = eng.submit(prompts[2], 8, priority=0)
        clock.advance(1.0)
        r3 = eng.submit(prompts[3], 8, priority=1)
        out = eng.run()
        # depth 1: shed r2 (lowest priority), then r3 (youngest of the
        # priority-1 pair); the oldest high-priority request survives
        assert eng.shed == {r2: "brownout", r3: "brownout"}
        assert eng.stats["shed_brownout"] == 2
        assert len(out[r0]) == len(out[r1]) == 8
        assert out[r2] == [] and out[r3] == []

    def test_depth_zero_disables_brownout(self):
        cfg, params, prompts = _setup()
        eng = _engine(cfg, params, brownout_depth=0)
        rids = [eng.submit(p, 4) for p in prompts]
        out = eng.run()
        assert eng.shed == {}
        assert all(len(out[r]) == 4 for r in rids)


class TestSegmentRetry:
    def test_transient_segment_fault_retried_token_identical(self):
        cfg, params, prompts = _setup()
        want = _engine(cfg, params)
        want.submit(prompts[0], 8)
        out_want = want.run()

        eng = _engine(cfg, params,
                      fault_injector=ScriptedFaults(
                          [FaultSpec(call=0, job="segment")]),
                      retry_policy=RetryPolicy(attempts=3, base=0.0))
        eng.submit(prompts[0], 8)
        out = eng.run()
        assert out == out_want
        assert eng.stats["retries"] == 1
        assert eng.shed == {}

    def test_exhausted_segment_retry_propagates(self):
        from repro.launch.cluster import TransientFault
        cfg, params, prompts = _setup()
        eng = _engine(cfg, params,
                      fault_injector=ScriptedFaults(
                          [FaultSpec(call=0, job="segment", times=2)]),
                      retry_policy=RetryPolicy(attempts=2, base=0.0))
        eng.submit(prompts[0], 8)
        with pytest.raises(TransientFault):
            eng.run()
