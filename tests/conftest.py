"""Shared pytest configuration: hypothesis settings profiles.

Two profiles, selected via the ``HYPOTHESIS_PROFILE`` environment
variable (default ``dev``):

* ``ci`` — what the GitHub workflow runs: >= 200 examples per property,
  no per-example deadline (the differential fuzz harness replays five
  simulations per example), and **derandomized** — the example stream is
  derived from each test's source, so a CI failure reproduces exactly
  with ``HYPOTHESIS_PROFILE=ci pytest <nodeid>`` and shrunk
  counterexamples can be pasted into the regression corpus
  (``tests/test_engine_equivalence.py::REGRESSION_SPECS``).
* ``dev`` — fast local iteration: few examples, still no deadline.

Without the ``[test]`` extra installed this module is inert and the
property tests skip via ``tests/_hypothesis_compat.py``.
"""
import os

# Hermetic kernels: the committed artifacts/bench/autotune.json must not
# reroute kernel tests through the XLA reference (that would silently
# drop Pallas coverage) — tests that exercise tuned routing install a
# table explicitly via autotune.set_table().
os.environ.setdefault("REPRO_AUTOTUNE", "0")

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:   # pragma: no cover - no [test] extra
    settings = None

if settings is not None:
    settings.register_profile(
        "ci", max_examples=200, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=20, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))


def pytest_configure(config):
    # The fleet tests mark themselves with @pytest.mark.timeout so CI
    # (which installs pytest-timeout via the [test] extra) kills a hung
    # multi-process run instead of stalling the job. Locally, without
    # the plugin, register the marker so the mark is a harmless no-op —
    # the master's own phase_timeout is the in-process backstop.
    config.addinivalue_line(
        "markers",
        "timeout(seconds): per-test timeout (enforced only when "
        "pytest-timeout is installed)")
