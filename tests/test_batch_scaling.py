"""Tests for Algorithm 2 (batch size scaling with best sharing benefit)."""
import pytest

from repro.core.batch_scaling import (best_sharing_config,
                                      candidate_sub_batches)
from repro.core.interference import InterferenceModel
from repro.core.job import Job
from repro.core.perf_model import PerfParams

GB = 2 ** 30


def mk_job(jid, batch=32, iters=1000, mem_base=2 * GB, mem_per_sample=0.2 * GB,
           beta=5e-3):
    perf = PerfParams(alpha_comp=2e-3, beta_comp=beta, alpha_comm=1e-4,
                      beta_comm=8e-10, msg_bytes=4e8, mem_base=mem_base,
                      mem_per_sample=mem_per_sample)
    return Job(jid=jid, model="bert", arrival=0.0, gpus=4, iters=iters,
               batch=batch, perf=perf)


def test_candidate_sub_batches():
    assert candidate_sub_batches(32) == [32, 16, 8, 4, 2, 1]
    assert candidate_sub_batches(1) == [1]
    assert candidate_sub_batches(6) == [6, 3, 2, 1]


def test_non_divisor_sub_batch_preserves_effective_batch():
    """Regression: with B=3, b=2 the old code derived s=round(3/2)=2 and
    priced the iteration as if it ran s*b=4 samples — silently changing
    the effective batch. Now s=ceil(B/b) and the final micro-batch
    absorbs the remainder, so every candidate executes exactly B
    samples."""
    import math
    run = mk_job(0, batch=16)
    run.sub_batch = 16
    for B in (3, 5, 6, 7, 12, 100):
        new = mk_job(1, batch=B, mem_base=1 * GB, mem_per_sample=0.01 * GB)
        interf = InterferenceModel(global_xi=1.05)
        cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=64 * GB)
        s, b = cfg.accum_steps, cfg.sub_batch
        assert s == max(1, math.ceil(B / b))
        # executed samples: (s-1) full micro-batches + the remainder
        assert (s - 1) * b + (B - (s - 1) * b) == B
        assert B - (s - 1) * b >= 1   # final micro-batch is non-empty


def test_t_iter_sub_final_microbatch_aware():
    """t_iter_sub prices the remainder micro-batch at its true size and
    agrees exactly with Eq. 7 for exact divisors."""
    job = mk_job(0, batch=3)
    p = job.perf
    # B=3, b=2 -> steps of [2, 1]: one full compute step plus a tail that
    # overlaps comm with the 1-sample remainder step
    expect = p.t_comp(2) + (p.t_comp(1) ** p.delta
                            + p.t_comm() ** p.delta) ** (1.0 / p.delta)
    assert p.t_iter_sub(3, 2) == pytest.approx(expect, rel=1e-12)
    # divisors collapse to the even-split Eq. 7
    assert p.t_iter_sub(32, 8) == p.t_iter(32, 4)
    assert p.t_iter_sub(32, 32) == p.t_iter(32, 1)
    with pytest.raises(ValueError):
        p.t_iter_sub(32, 0)


def test_memory_forces_accumulation():
    # 11 GB GPU: running job uses 2GB + 16*0.2=5.2GB; new job (base 2GB)
    # can only fit a few samples -> Algorithm 2 must pick b < B.
    run = mk_job(0, batch=16)
    run.sub_batch = 16
    new = mk_job(1, batch=32)
    interf = InterferenceModel(global_xi=1.2)
    cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=11 * GB)
    assert cfg.share
    assert cfg.sub_batch < 32
    assert cfg.accum_steps == new.batch // cfg.sub_batch
    # chosen sub-batch must actually fit beside the running job
    run_mem = run.perf.mem_bytes(run.sub_batch)
    assert new.perf.fits(cfg.sub_batch, 11 * GB, other_mem=run_mem)


def test_no_fit_means_no_share():
    run = mk_job(0, batch=32, mem_base=8 * GB)
    run.sub_batch = 32
    new = mk_job(1, batch=32, mem_base=8 * GB)
    interf = InterferenceModel(global_xi=1.1)
    cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=11 * GB)
    assert not cfg.share
    assert cfg.decision is None


def test_high_interference_rejects_sharing():
    run = mk_job(0, iters=1000)
    run.sub_batch = run.batch
    new = mk_job(1, iters=1000)
    interf = InterferenceModel(global_xi=4.0)
    cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=64 * GB)
    assert not cfg.share  # Theorem 1 says sequential


def test_low_interference_accepts_sharing():
    run = mk_job(0, iters=1000)
    run.sub_batch = run.batch
    new = mk_job(1, iters=1000)
    interf = InterferenceModel(global_xi=1.05)
    cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=64 * GB)
    assert cfg.share
    assert cfg.avg_jct < run.solo_t_iter * 1000 + 1e-9 + 0.5 * new.solo_t_iter * 1000


def test_picks_best_of_feasible_sub_batches():
    # ample memory: b=B should win because accumulation only adds
    # per-step overhead (alpha_comp) here
    run = mk_job(0)
    run.sub_batch = run.batch
    new = mk_job(1, mem_base=1 * GB, mem_per_sample=0.01 * GB)
    interf = InterferenceModel(global_xi=1.1)
    cfg = best_sharing_config(run, new, interf, gpu_capacity_bytes=64 * GB)
    assert cfg.sub_batch == new.batch
    assert cfg.accum_steps == 1
