"""Executor fault recovery (DESIGN.md §16): scripted step faults,
bounded-backoff retry of transients, fatal member drop with group
re-fusion, async checkpointing, and the acceptance invariant —
restart-from-checkpoint replays the remaining steps bit-exactly."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.cluster import (FatalFault, FaultSpec, JobSpec, PlanOp,
                                  PlanPhase, ScheduleExecutor,
                                  ScriptedFaults, TransientFault)
from repro.util.retry import RetryPolicy


def _spec(name="minicpm-2b", batch=2, seq=32, **kw):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    return JobSpec(cfg, batch=batch, seq=seq, **kw)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not (np.asarray(x) == np.asarray(y)).all():
            return False
    return True


def _ex(**kw):
    kw.setdefault("donate", True)
    kw.setdefault("sleep", lambda d: None)   # no wall-clock in tests
    return ScheduleExecutor(**kw)


# ===================================================================== #
# Scripted fault injector
# ===================================================================== #
class TestScriptedFaults:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec(call=0, job="a", kind="weird")

    def test_fires_only_on_matching_call_and_member(self):
        inj = ScriptedFaults([FaultSpec(call=2, job="a")])
        inj.check(0, ("a",))
        inj.check(2, ("b",))        # wrong member: silent
        with pytest.raises(TransientFault):
            inj.check(2, ("a", "b"))
        inj.check(2, ("a",))        # times=1 budget consumed

    def test_times_budget_and_fatal_kind(self):
        inj = ScriptedFaults([FaultSpec(call=1, job="a", times=2),
                              FaultSpec(call=5, job="b", kind="fatal")])
        for _ in range(2):
            with pytest.raises(TransientFault):
                inj.check(1, ("a",))
        inj.check(1, ("a",))
        with pytest.raises(FatalFault) as ei:
            inj.check(5, ("b",))
        assert ei.value.job == "b"


# ===================================================================== #
# Retry / degrade inside step_group
# ===================================================================== #
class TestStepGroupFaults:
    def test_transient_absorbed_by_retry(self):
        ex = _ex(fault_injector=ScriptedFaults(
            [FaultSpec(call=1, job="a", times=2)]),
            retry_policy=RetryPolicy(attempts=3, base=0.0))
        ex.submit("a", _spec(), 3)
        ex.start("a")
        for _ in range(3):
            res = ex.step_group(["a"])
            assert "dropped" not in res
        assert ex.runs["a"].steps_done == 3
        assert ex.runs["a"].retries == 2
        assert ex.retries_total == 2
        assert ex.drops_total == 0

    def test_exhausted_transient_escalates_to_drop(self):
        ex = _ex(fault_injector=ScriptedFaults(
            [FaultSpec(call=1, job="a", times=3)]),
            retry_policy=RetryPolicy(attempts=3, base=0.0))
        ex.submit("a", _spec(), 3)
        ex.start("a")
        ex.step_group(["a"])
        res = ex.step_group(["a"])
        assert res["dropped"] == "a"
        assert ex.runs["a"].failed
        assert ex.runs["a"].steps_done == 1
        assert ex.drops_total == 1
        with pytest.raises(RuntimeError, match="not running"):
            ex.step_group(["a"])    # failed members cannot step

    def test_fatal_fault_in_group_drops_only_the_victim(self):
        """Bit-exactness of the degrade path: the survivor's state after
        the drop equals a solo run of the same step count."""
        specs = {"a": _spec(), "b": _spec(seed=3)}
        ex = _ex(fault_injector=ScriptedFaults(
            [FaultSpec(call=2, job="b", kind="fatal")]))
        for n, s in specs.items():
            ex.submit(n, s, 4)
            ex.start(n)
        for _ in range(2):
            assert "dropped" not in ex.step_group(["a", "b"])
        res = ex.step_group(["a", "b"])
        assert res["dropped"] == "b"
        # survivors keep stepping: the re-fused solo program compiles
        compiles_before = ex.compiles
        for _ in range(2):
            assert "dropped" not in ex.step_group(["a"])
        assert ex.compiles == compiles_before + 1
        assert ex.runs["a"].steps_done == 4
        assert ex.runs["b"].steps_done == 2 and ex.runs["b"].failed

        # degraded mode costs the survivor nothing numerically: its
        # state equals an uninterrupted solo run of the same length
        solo = _ex()
        solo.submit("a", specs["a"], 4)
        solo.start("a")
        for _ in range(4):
            solo.step_group(["a"])
        assert _leaves_equal(ex.runs["a"].params, solo.runs["a"].params)


# ===================================================================== #
# Checkpoint / restart
# ===================================================================== #
class TestCheckpointRestart:
    def test_restart_from_checkpoint_bit_exact(self, tmp_path):
        """The acceptance invariant: fail at step 4 (checkpoint at 4),
        restart, run to 6 — params and opt state must be bit-identical
        to an uninterrupted 6-step run."""
        spec = _spec()
        base = _ex()
        base.submit("a", spec, 6)
        base.start("a")
        for _ in range(6):
            base.step_group(["a"])

        ex = _ex(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                 fault_injector=ScriptedFaults(
                     [FaultSpec(call=4, job="a", kind="fatal")]))
        ex.submit("a", spec, 6)
        ex.start("a")
        for _ in range(4):
            assert "dropped" not in ex.step_group(["a"])
        assert ex.step_group(["a"])["dropped"] == "a"
        assert ex.runs["a"].failed
        assert ex.runs["a"].last_ckpt_step == 4

        run = ex.restart("a")
        assert not run.failed and run.restarts == 1
        assert run.steps_done == 4          # resumed at the checkpoint
        assert ex.checkpoints_written == 2  # steps 2 and 4 landed
        while run.steps_done < 6:
            assert "dropped" not in ex.step_group(["a"])
        assert _leaves_equal(run.params, base.runs["a"].params)
        assert _leaves_equal(run.opt, base.runs["a"].opt)
        assert run.last_metrics["loss"] == base.runs["a"].last_metrics["loss"]

    def test_restart_without_checkpoint_starts_from_scratch(self, tmp_path):
        ex = _ex(checkpoint_dir=str(tmp_path), checkpoint_every=0,
                 fault_injector=ScriptedFaults(
                     [FaultSpec(call=1, job="a", kind="fatal")]))
        ex.submit("a", _spec(), 4)
        ex.start("a")
        ex.step_group(["a"])
        assert ex.step_group(["a"])["dropped"] == "a"
        run = ex.restart("a")
        assert run.steps_done == 0 and run.restarts == 1

    def test_checkpoint_requires_dir_and_started_run(self, tmp_path):
        ex = _ex()
        ex.submit("a", _spec(), 2)
        ex.start("a")
        with pytest.raises(RuntimeError, match="no checkpoint_dir"):
            ex.checkpoint("a")
        ex2 = _ex(checkpoint_dir=str(tmp_path))
        ex2.submit("a", _spec(), 2)
        with pytest.raises(RuntimeError, match="not started"):
            ex2.checkpoint("a")
        with pytest.raises(RuntimeError, match="not started"):
            ex2.restart("a")

    def test_background_write_error_surfaces_at_flush(self, tmp_path):
        blocker = tmp_path / "blocker"
        blocker.write_text("not a directory")
        ex = _ex(checkpoint_dir=str(blocker))
        ex.submit("a", _spec(), 2)
        ex.start("a")
        ex.checkpoint("a")          # enqueue succeeds; the write fails
        with pytest.raises(OSError):
            ex.flush_checkpoints()

    def test_close_drains_and_joins_writer_thread(self, tmp_path):
        """Agent-teardown ordering: close() must land every queued write
        AND terminate the worker thread (flush alone leaves it parked on
        the queue). Idempotent, and usable as a context manager."""
        ex = _ex(checkpoint_dir=str(tmp_path), checkpoint_every=1)
        ex.submit("a", _spec(), 2)
        ex.start("a")
        ex.step_group(["a"])
        ex.step_group(["a"])
        thread = ex._ckpt_thread
        assert thread is not None and thread.is_alive()
        ex.close()
        assert not thread.is_alive()
        assert ex.checkpoints_written == 2
        assert (tmp_path / "a.npz").exists()
        ex.close()                       # idempotent
        with _ex(checkpoint_dir=str(tmp_path)) as ex2:
            ex2.submit("a", _spec(), 1)
            ex2.start("a")
            ex2.checkpoint("a")
            t2 = ex2._ckpt_thread
        assert not t2.is_alive()         # __exit__ closed it

    def test_close_surfaces_background_write_error(self, tmp_path):
        blocker = tmp_path / "blocker"
        blocker.write_text("not a directory")
        ex = _ex(checkpoint_dir=str(blocker))
        ex.submit("a", _spec(), 2)
        ex.start("a")
        ex.checkpoint("a")
        with pytest.raises(OSError):
            ex.close()

    def test_checkpoint_tag_names_epoch_files(self, tmp_path):
        ex = _ex(checkpoint_dir=str(tmp_path), checkpoint_tag=".e0003")
        ex.submit("a", _spec(), 1)
        ex.start("a")
        ex.step_group(["a"])
        ex.checkpoint("a")
        ex.close()
        assert (tmp_path / "a.e0003.npz").exists()

    def test_restore_run_from_explicit_path(self, tmp_path):
        """restore_run loads a named epoch file bit-exactly: resume from
        it and match an uninterrupted run."""
        spec = _spec()
        base = _ex()
        base.submit("a", spec, 4)
        base.start("a")
        for _ in range(4):
            base.step_group(["a"])

        ex = _ex(checkpoint_dir=str(tmp_path), checkpoint_tag=".e0001")
        ex.submit("a", spec, 4)
        ex.start("a")
        ex.step_group(["a"])
        ex.step_group(["a"])
        ex.checkpoint("a")
        ex.close()

        ex2 = _ex()
        ex2.submit("a", spec, 4)
        ex2.start("a")
        run = ex2.restore_run("a", str(tmp_path / "a.e0001.npz"))
        assert run.steps_done == 2
        ex2.step_group(["a"])
        ex2.step_group(["a"])
        assert _leaves_equal(run.params, base.runs["a"].params)
        assert _leaves_equal(run.opt, base.runs["a"].opt)

    def test_shared_program_cache_across_executors(self):
        cache = {}
        ex1 = _ex(program_cache=cache)
        ex1.submit("a", _spec(), 1)
        ex1.start("a")
        ex1.step_group(["a"])
        assert ex1.compiles == 1 and len(cache) == 1
        ex2 = _ex(program_cache=cache)
        ex2.submit("a", _spec(seed=5), 1)
        ex2.start("a")
        ex2.step_group(["a"])
        assert ex2.compiles == 0, "second executor reuses the cache"


# ===================================================================== #
# Degraded-mode plan execution
# ===================================================================== #
class TestExecuteDegraded:
    def test_failed_member_drops_and_survivors_finish(self):
        ex = _ex(fault_injector=ScriptedFaults(
            [FaultSpec(call=2, job="b", kind="fatal")]))
        ex.submit("a", _spec(), 4)
        ex.submit("b", _spec(seed=3), 4)
        plan = [PlanPhase(
            ops=(PlanOp("start", "a"), PlanOp("start", "b")),
            quotas=(("a", 4), ("b", 4)),
            groups=(("a", "b"),))]
        report = ex.execute(plan)
        assert report["a"]["steps"] == 4 and not report["a"]["failed"]
        assert report["b"]["steps"] == 2 and report["b"]["failed"]
        assert report["b"]["restarts"] == 0
        assert ex.drops_total == 1
        # walltime is attributed to survivors only
        assert report["a"]["walltime"] > 0.0
        assert report["b"]["walltime"] == 0.0
