"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned architecture runs one forward + one train step on CPU; output
shapes and finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data import make_batch
from repro.models import forward, init_params, param_count
from repro.train import TrainConfig, adamw_init, make_train_step

B, S = 2, 64


def reduced(name):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


@pytest.fixture(scope="module")
def setups():
    return {}


def _setup(name):
    cfg = reduced(name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_constraints(name):
    cfg = reduced(name)
    assert cfg.n_layers <= max(2, cfg.pattern_unit())
    assert cfg.d_model <= 512
    assert cfg.moe_experts <= 4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg, params, batch = _setup(name)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_no_nans(name):
    cfg, params, batch = _setup(name)
    step = jax.jit(make_train_step(cfg, TrainConfig(accum_steps=2)))
    opt = adamw_init(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert new_opt.step == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(moved)), f"{name}: params did not update"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_positive(name):
    cfg = get_config(name)
    n = cfg.param_count()
    assert n > 0
    assert cfg.active_param_count() <= n
