"""Bounded-backoff retry primitive (repro.util.retry): policy
validation, the full-jitter delay envelope, exhaustion semantics, and
seeded determinism — the property the executor's bit-exact fault
replays rest on."""
import random

import pytest

from repro.util.retry import (RetryBudgetExceeded, RetryPolicy,
                              retry_call)


class TestRetryPolicy:
    def test_validation(self):
        with pytest.raises(ValueError, match="attempts"):
            RetryPolicy(attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(base=-1.0)
        with pytest.raises(ValueError):
            RetryPolicy(cap=-0.1)
        with pytest.raises(ValueError, match="deadline"):
            RetryPolicy(deadline=-1.0)

    def test_jitterless_delay_is_capped_exponential(self):
        p = RetryPolicy(attempts=8, base=0.1, cap=1.0, jitter=False)
        rng = random.Random(0)
        delays = [p.delay(k, rng) for k in range(6)]
        assert delays[:4] == pytest.approx([0.1, 0.2, 0.4, 0.8])
        assert delays[4:] == [1.0, 1.0]   # capped

    def test_jittered_delay_within_envelope(self):
        p = RetryPolicy(attempts=8, base=0.1, cap=1.0, jitter=True)
        rng = random.Random(7)
        for k in range(6):
            bound = min(1.0, 0.1 * 2 ** k)
            for _ in range(20):
                assert 0.0 <= p.delay(k, rng) <= bound


class _Flaky:
    def __init__(self, fail_times, exc=ValueError):
        self.calls = 0
        self.fail_times = fail_times
        self.exc = exc

    def __call__(self):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise self.exc(f"boom {self.calls}")
        return "ok"


class TestRetryCall:
    def test_first_try_success_never_sleeps(self):
        slept = []
        assert retry_call(_Flaky(0), sleep=slept.append) == "ok"
        assert slept == []

    def test_transient_failures_absorbed(self):
        fn = _Flaky(2)
        slept, seen = [], []
        out = retry_call(fn, policy=RetryPolicy(attempts=3),
                         sleep=slept.append,
                         on_retry=lambda k, exc, d: seen.append((k, d)))
        assert out == "ok" and fn.calls == 3
        assert len(slept) == len(seen) == 2
        assert [k for k, _ in seen] == [0, 1]
        assert all(d == s for (_, d), s in zip(seen, slept))

    def test_exhaustion_raises_last_exception(self):
        fn = _Flaky(5)
        with pytest.raises(ValueError, match="boom 3"):
            retry_call(fn, policy=RetryPolicy(attempts=3),
                       sleep=lambda d: None)
        assert fn.calls == 3   # bounded: no fourth attempt

    def test_non_matching_exception_propagates_immediately(self):
        fn = _Flaky(1, exc=KeyError)
        with pytest.raises(KeyError):
            retry_call(fn, retry_on=(ValueError,), sleep=lambda d: None)
        assert fn.calls == 1

    def test_seeded_rng_makes_schedule_deterministic(self):
        def run(seed):
            slept = []
            retry_call(_Flaky(3), policy=RetryPolicy(attempts=4),
                       seed=seed, sleep=slept.append)
            return slept

        assert run(0) == run(0)
        assert run(0) != run(1)

    def test_deadline_raises_typed_budget_error(self):
        """A retry whose backoff sleep would overrun the wall-clock
        deadline is not attempted: RetryBudgetExceeded, chained to the
        underlying failure, instead of an exhausted-attempts raise."""
        now = [0.0]

        def fake_sleep(d):
            now[0] += d

        fn = _Flaky(10)
        with pytest.raises(RetryBudgetExceeded) as ei:
            retry_call(fn, policy=RetryPolicy(attempts=10, base=1.0,
                                              cap=1.0, jitter=False,
                                              deadline=2.5),
                       sleep=fake_sleep, clock=lambda: now[0])
        # attempts 1 and 2 slept 1s each; the third retry's 1s sleep
        # would land at t=3 > 2.5 — budget error after 3 calls
        assert fn.calls == 3
        assert ei.value.attempts == 3
        assert ei.value.deadline == 2.5
        assert isinstance(ei.value.__cause__, ValueError)

    def test_deadline_does_not_fire_when_attempts_exhaust_first(self):
        fn = _Flaky(5)
        with pytest.raises(ValueError, match="boom 2"):
            retry_call(fn, policy=RetryPolicy(attempts=2, base=0.0,
                                              deadline=100.0),
                       sleep=lambda d: None)
        assert fn.calls == 2

    def test_zero_deadline_allows_single_attempt(self):
        """deadline=0 still permits the first call (no sleep needed) but
        never a retry with a positive backoff."""
        assert retry_call(_Flaky(0),
                          policy=RetryPolicy(deadline=0.0)) == "ok"
        now = [0.0]
        with pytest.raises(RetryBudgetExceeded):
            retry_call(_Flaky(1),
                       policy=RetryPolicy(attempts=3, base=1.0,
                                          jitter=False, deadline=0.0),
                       sleep=lambda d: None, clock=lambda: now[0])

    def test_caller_owned_rng_is_consumed_in_sequence(self):
        rng = random.Random(42)
        slept = []
        retry_call(_Flaky(1), rng=rng, sleep=slept.append)
        retry_call(_Flaky(1), rng=rng, sleep=slept.append)
        want_rng = random.Random(42)
        want = [RetryPolicy().delay(0, want_rng) for _ in range(2)]
        assert slept == pytest.approx(want)
