"""Master/agent fleet runtime (DESIGN.md §17): wire protocol units,
heartbeat suspect/dead state machine + lease-epoch fencing against fake
agents (no subprocess, no jax on the master path), and real 2-agent
subprocess runs of the 4-job replay-validation schedule — bit-exact vs
the single-host executor, including with a SIGKILLed agent mid-plan."""
import dataclasses
import json
import socket
import time

import pytest

from repro.checkpoint import checkpoint_crc
from repro.configs import get_config
from repro.core import (ClusterState, InterferenceModel, Job, PerfParams,
                        Simulator)
from repro.core.schedulers import SJF_BSBF
from repro.launch.cluster import (JobSpec, ScheduleExecutor, plan_from_sim)
from repro.launch.fleet import (ChaosKiller, FleetConfig, FleetError,
                                FleetMaster, KillSpec)
from repro.launch.wire import (MessageReader, WireError, send_msg,
                               spec_from_wire, spec_to_wire)
from repro.util.retry import RetryPolicy

pytestmark = pytest.mark.timeout(900)


def _spec(name="minicpm-2b", batch=2, seq=32, **kw):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    return JobSpec(cfg, batch=batch, seq=seq, **kw)


# ===================================================================== #
# Wire protocol
# ===================================================================== #
class TestWire:
    def test_spec_roundtrip_through_json(self):
        spec = _spec("qwen2-vl-2b", batch=4, seed=7, accum_steps=2)
        wire = json.loads(json.dumps(spec_to_wire(spec)))
        back = spec_from_wire(wire)
        assert back == spec          # tuple fields survive the list form
        assert isinstance(back.cfg.mrope_sections, tuple)

    def test_framing_eof_and_bad_frame(self):
        a, b = socket.socketpair()
        try:
            send_msg(a, {"x": 1})
            send_msg(a, {"y": [1, 2]})
            reader = MessageReader(b)
            assert reader.read() == {"x": 1}
            assert reader.read() == {"y": [1, 2]}
            a.sendall(b"not json\n")
            with pytest.raises(WireError, match="bad frame"):
                reader.read()
            a.close()
            assert reader.read() is None    # EOF, never a hang
        finally:
            b.close()

    def test_send_to_closed_socket_raises_wire_error(self):
        a, b = socket.socketpair()
        b.close()
        a.close()
        with pytest.raises(WireError):
            send_msg(a, {"x": 1})


# ===================================================================== #
# Fake-agent harness: state machine + fencing without subprocesses
# ===================================================================== #
class FakeAgent:
    """A hand-driven agent connection: the tests decide exactly when it
    heartbeats, replies, or goes silent."""

    def __init__(self, port, agent_id):
        self.sock = socket.create_connection(("127.0.0.1", port))
        self.sock.settimeout(5.0)
        self.reader = MessageReader(self.sock)
        self.id = agent_id
        send_msg(self.sock, {"type": "hello", "role": "agent",
                             "id": agent_id, "pid": None})

    def heartbeat(self, watermark=None, epoch=None):
        send_msg(self.sock, {"type": "heartbeat", "agent": self.id,
                             "watermark": watermark or {}, "epoch": epoch})

    def send(self, msg):
        send_msg(self.sock, msg)

    def recv(self):
        return self.reader.read()

    def close(self):
        self.sock.close()


def _wait(predicate, timeout=5.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while not predicate():
        if time.monotonic() > deadline:
            raise AssertionError(f"timed out waiting for {msg}")
        time.sleep(0.01)


def _fast_cfg(**kw):
    kw.setdefault("heartbeat_interval", 0.05)
    kw.setdefault("suspect_after", 0.15)
    kw.setdefault("dead_after", 0.4)
    kw.setdefault("retry_policy",
                  RetryPolicy(attempts=3, base=0.01, deadline=5.0))
    return FleetConfig(**kw)


class TestStateMachine:
    def test_missed_heartbeats_suspect_then_dead(self, tmp_path):
        with FleetMaster(str(tmp_path), config=_fast_cfg()) as m:
            m.start(0)
            fa = FakeAgent(m.port, "f0")
            _wait(lambda: m.agents.get("f0", None) is not None
                  and m.agents["f0"].state == "alive", msg="agent up")
            # silence (no close: the socket stays open, like a hung host)
            _wait(lambda: m.agents["f0"].state == "dead", timeout=5.0,
                  msg="dead declaration")
            kinds = [e["kind"] for e in m.events]
            assert "agent_suspect" in kinds and "agent_dead" in kinds
            dead = next(e for e in m.events if e["kind"] == "agent_dead")
            assert dead["reason"] == "heartbeat"
            assert 0.0 <= dead["detection_latency"] < 5.0
            fa.close()

    def test_heartbeat_recovers_suspect_agent(self, tmp_path):
        cfg = _fast_cfg(suspect_after=0.1, dead_after=10.0)
        with FleetMaster(str(tmp_path), config=cfg) as m:
            m.start(0)
            fa = FakeAgent(m.port, "f0")
            _wait(lambda: "f0" in m.agents
                  and m.agents["f0"].state == "alive", msg="agent up")
            _wait(lambda: m.agents["f0"].state == "suspect",
                  msg="suspect")
            fa.heartbeat()
            _wait(lambda: m.agents["f0"].state == "alive",
                  msg="recovery")
            assert any(e["kind"] == "agent_recovered" for e in m.events)
            fa.close()

    def test_watermark_regression_is_counted(self, tmp_path):
        with FleetMaster(str(tmp_path),
                         config=_fast_cfg(dead_after=10.0)) as m:
            m.start(0)
            fa = FakeAgent(m.port, "f0")
            _wait(lambda: "f0" in m.agents
                  and m.agents["f0"].state == "alive", msg="agent up")
            fa.heartbeat({"j": 3})
            _wait(lambda: m.agents["f0"].watermark.get("j") == 3,
                  msg="watermark")
            fa.heartbeat({"j": 1})      # progress must be monotone
            _wait(lambda: m.stats["watermark_regressions"] == 1,
                  msg="regression count")
            fa.close()


class TestFencing:
    def test_zombie_lease_is_fenced_and_job_requeued(self, tmp_path):
        """The acceptance scenario for fencing: an agent takes a lease,
        goes silent past the timeout (unconfirmed death -> its epoch is
        fenced), then wakes up and reports completion — the stale result
        is discarded, and the job re-runs on a second agent whose lease
        excludes the fenced epoch from restore_epochs."""
        with FleetMaster(str(tmp_path), config=_fast_cfg()) as m:
            m.start(0)
            fa = FakeAgent(m.port, "f0")
            _wait(lambda: "f0" in m.agents
                  and m.agents["f0"].state == "alive", msg="agent up")
            m.submit_job({"stub": True}, steps=5, name="j")
            lease = fa.recv()
            assert lease["type"] == "lease"
            assert lease["members"][0]["name"] == "j"
            epoch = lease["epoch"]
            fa.heartbeat({"j": 2}, epoch=epoch)
            # now go silent until declared dead
            _wait(lambda: m.agents["f0"].state == "dead", timeout=5.0,
                  msg="dead declaration")
            assert epoch in m._fenced_epochs
            # zombie resumes and reports a full run: must be discarded
            fenced_before = m.stats["fenced"]
            fa.send({"type": "lease_done", "lease_id": lease["lease_id"],
                     "epoch": epoch, "walltime": 1.0,
                     "report": {"j": {"steps": 5, "resumed_from": 0}}})
            _wait(lambda: m.stats["fenced"] > fenced_before,
                  msg="fenced result")
            assert not m.jobs["j"].finished
            # a fresh agent picks up the requeued job
            fb = FakeAgent(m.port, "f1")
            lease2 = fb.recv()
            assert lease2["type"] == "lease"
            assert lease2["epoch"] != epoch
            assert epoch not in lease2["members"][0]["restore_epochs"]
            fb.heartbeat({"j": 5}, epoch=lease2["epoch"])
            fb.send({"type": "lease_done",
                     "lease_id": lease2["lease_id"],
                     "epoch": lease2["epoch"], "walltime": 2.0,
                     "report": {"j": {"steps": 5, "resumed_from": 0,
                                      "loss": 1.5}}})
            rep = m.wait_for_job("j", timeout=5.0)
            assert rep["finished"] and rep["steps"] == 5
            assert m.jobs["j"].redispatches == 1
            fa.close()
            fb.close()

    def test_cancel_requeued_before_dispatch(self, tmp_path):
        with FleetMaster(str(tmp_path), config=_fast_cfg()) as m:
            m.start(0)
            m.submit_job({"stub": True}, steps=5, name="j")
            assert m.cancel_job("j")
            assert not m.cancel_job("j")        # idempotent
            status = m.status()
            assert status["jobs"]["j"]["cancelled"]
            assert status["queue"] == []

    def test_dispatch_with_no_agents_exhausts_retry_budget(self, tmp_path):
        cfg = _fast_cfg(retry_policy=RetryPolicy(
            attempts=10, base=0.01, cap=0.02, deadline=0.2))
        from repro.util.retry import RetryBudgetExceeded
        with FleetMaster(str(tmp_path), config=cfg) as m:
            m.start(0)
            m.jobs["j"] = __import__(
                "repro.launch.fleet", fromlist=["MasterJob"]).MasterJob(
                name="j", wire_spec={}, total_steps=1, started=True)
            with pytest.raises((RetryBudgetExceeded, FleetError)):
                m._dispatch(("j",), {"j": 1}, ("j",))


# ===================================================================== #
# CLI client path against an in-process master + fake agent
# ===================================================================== #
class TestFleetCLI:
    def test_submit_status_cancel_roundtrip(self, tmp_path, capsys):
        from repro.launch import fleet_cli
        with FleetMaster(str(tmp_path),
                         config=_fast_cfg(dead_after=10.0)) as m:
            m.start(0)
            fa = FakeAgent(m.port, "f0")
            _wait(lambda: "f0" in m.agents
                  and m.agents["f0"].state == "alive", msg="agent up")
            port = str(m.port)
            rc = fleet_cli.main([
                "submit", "--port", port, "--arch", "minicpm-2b",
                "--reduced", "--steps", "2", "--name", "cli-job"])
            assert rc == 0
            assert "submitted cli-job" in capsys.readouterr().out
            lease = fa.recv()
            assert lease["members"][0]["name"] == "cli-job"
            # the wire spec the CLI built reconstructs into a JobSpec
            spec = spec_from_wire(lease["members"][0]["spec"])
            assert spec.cfg.name == "minicpm-2b-reduced"
            fa.send({"type": "lease_done", "lease_id": lease["lease_id"],
                     "epoch": lease["epoch"], "walltime": 0.5,
                     "report": {"cli-job": {"steps": 2,
                                            "resumed_from": 0}}})
            m.wait_for_job("cli-job", timeout=5.0)
            assert fleet_cli.main(["status", "--port", port]) == 0
            out = capsys.readouterr().out
            assert "cli-job: 2/2 finished" in out
            assert fleet_cli.main(
                ["cancel", "--port", port, "cli-job"]) == 1
            assert fleet_cli.main(["queue", "--port", port]) == 0
            fa.close()

    def test_unreachable_master_exits_2(self, capsys):
        from repro.launch import fleet_cli
        sock = socket.create_server(("127.0.0.1", 0))
        port = sock.getsockname()[1]
        sock.close()                      # nothing listens here now
        assert fleet_cli.main(["status", "--port", str(port)]) == 2


# ===================================================================== #
# Real 2-agent subprocess fleet: the 4-job replay-validation schedule
# ===================================================================== #
GB = 2 ** 30


def _perf(alpha=0.01, beta=0.01):
    return PerfParams(alpha_comp=alpha, beta_comp=beta, alpha_comm=0.0,
                      beta_comm=0.0, msg_bytes=0.0, delta=2.0,
                      mem_base=4.0 * GB, mem_per_sample=0.25 * GB,
                      param_bytes=1e8, n_workers=1)


def _replay_plan():
    """The replay-validation schedule (test_schedule_executor._scenario)
    at iters_a=6: donor A spans both GPUs, B/C form the 3-way sharing
    group with donor reconfigs, D queues — 8 phases, 16 total steps."""
    pa, pb = _perf(), _perf(beta=0.008)
    t_a = pa.t_iter(4)
    jobs = [Job(jid=0, model="m0", arrival=0.0, gpus=2, iters=6.0,
                batch=4, perf=pa),
            Job(jid=1, model="m1", arrival=2 * t_a, gpus=1, iters=3.0,
                batch=4, perf=pb),
            Job(jid=2, model="m1", arrival=4 * t_a, gpus=1, iters=4.0,
                batch=4, perf=pb),
            Job(jid=3, model="m0", arrival=6 * t_a, gpus=1, iters=3.0,
                batch=4, perf=pa)]
    cap = pa.mem_bytes(2) + pb.mem_bytes(2) + 0.25 * 0.25 * GB
    interf = InterferenceModel()
    for a in ("m0", "m1"):
        for b in ("m0", "m1"):
            interf.set_pair(a, b, 1.3, 1.3)
    cluster = ClusterState(n_servers=1, gpus_per_server=2,
                           gpu_capacity_bytes=cap)
    sim = Simulator(cluster, jobs, SJF_BSBF(donor_reconfig=True),
                    interference=interf, reconfig_on_release=True)
    sim.run()
    plan = plan_from_sim(sim.log, sim.jobs, sim.interference, cap,
                         names={0: "A", 1: "B", 2: "C", 3: "D"})
    assert max(len(g) for p in plan.phases for g in p.groups
               if p.groups) == 3
    specs = {"A": _spec(batch=4), "B": _spec(batch=4, seed=1),
             "C": _spec(batch=4, seed=2), "D": _spec(batch=4, seed=3)}
    return plan, specs


@pytest.fixture(scope="module")
def replay_reference(tmp_path_factory):
    """Single-host ScheduleExecutor run of the replay plan: the ground
    truth the fleet must match bit-for-bit (per-job final checkpoint
    CRCs, steps, losses)."""
    plan, specs = _replay_plan()
    ref_dir = tmp_path_factory.mktemp("ref")
    totals = {}
    for phase in plan.phases:
        for name, q in phase.quotas:
            totals[name] = totals.get(name, 0) + q
    with ScheduleExecutor(donate=True,
                          checkpoint_dir=str(ref_dir)) as ex:
        for name, spec in specs.items():
            ex.submit(name, spec, totals[name])
        report = ex.execute(plan)
        paths = {name: ex.checkpoint(name) for name in specs}
    crcs = {name: checkpoint_crc(paths[name]) for name in specs}
    assert all(c is not None for c in crcs.values())
    return {"plan": plan, "specs": specs, "report": report,
            "crcs": crcs}


class TestTwoAgentFleet:
    def test_fleet_matches_single_host_bit_exactly(self, tmp_path,
                                                   replay_reference):
        """Satellite 4, failure-free half: a 2-agent fleet run of the
        replay-validation schedule produces the same per-job step counts,
        final losses, and checkpoint content CRCs as the single-host
        executor."""
        ref = replay_reference
        with FleetMaster(str(tmp_path),
                         config=FleetConfig(checkpoint_every=1)) as m:
            m.start(n_agents=2)
            report = m.run_plan(ref["plan"], ref["specs"])
        for name in ref["specs"]:
            assert report[name]["finished"], name
            assert report[name]["steps"] == ref["report"][name]["steps"]
            assert report[name]["crc"] == ref["crcs"][name], \
                f"job {name}: fleet checkpoint diverged from single-host"
            assert report[name]["loss"] == pytest.approx(
                ref["report"][name]["loss"], abs=0)
        assert m.stats["redispatches"] == 0
        assert m.stats["fenced"] == 0

    def test_fleet_survives_sigkill_bit_exactly(self, tmp_path,
                                                replay_reference):
        """Satellite 4, failure half (the PR's acceptance scenario): one
        agent is SIGKILLed mid-step; the master detects it within the
        configured timeout, re-dispatches its group from the last
        checkpoint, and the final params still match the failure-free
        single-host run bit-for-bit."""
        ref = replay_reference
        cfg = FleetConfig(checkpoint_every=1, step_sleep=0.3,
                          heartbeat_interval=0.1, suspect_after=0.5,
                          dead_after=1.0)
        chaos = ChaosKiller([KillSpec(agent="a0", after_steps=2)])
        with FleetMaster(str(tmp_path), config=cfg, chaos=chaos) as m:
            m.start(n_agents=2)
            report = m.run_plan(ref["plan"], ref["specs"])
            assert len(chaos.kills) == 1, "the scripted kill must fire"
            dead = [e for e in m.events if e["kind"] == "agent_dead"]
            assert dead and dead[0]["agent"] == "a0"
            assert dead[0]["killed"]
            # detection within the configured timeout (+ scheduling slack)
            assert dead[0]["detection_latency"] < cfg.dead_after + 1.0
            assert m.stats["redispatches"] >= 1
        for name in ref["specs"]:
            assert report[name]["finished"], name
            assert report[name]["steps"] == ref["report"][name]["steps"]
            assert report[name]["crc"] == ref["crcs"][name], \
                f"job {name}: recovery broke bit-exactness"
            assert report[name]["loss"] == pytest.approx(
                ref["report"][name]["loss"], abs=0)
