"""Autotune table: artifact roundtrip, version gating, graceful absence,
and the tuned-or-fallback routing contract in ``kernels.ops``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.kernels import ref as kref


def _table(entries, backend=None, version=autotune.AUTOTUNE_VERSION):
    return {"version": version, "created": 0.0,
            "meta": {"backend": backend or jax.default_backend(),
                     "interpret": True, "smoke": True, "iters": 1},
            "entries": entries}


@pytest.fixture(autouse=True)
def _isolate_table():
    """Every test starts with no table and leaves none behind (conftest
    pins REPRO_AUTOTUNE=0, so reset re-reads that and disables)."""
    autotune.set_table(None)
    yield
    autotune.reset_table()


class TestArtifact:
    def test_roundtrip(self, tmp_path):
        key = autotune.shape_key("flash_decode", 100, 32, jnp.float32)
        payload = _table({key: {"backend": "kernel", "block_k": 64}})
        path = str(tmp_path / "autotune.json")
        autotune.save_artifact(payload, path)
        assert autotune.load_artifact(path) == payload

    def test_save_refuses_wrong_version(self, tmp_path):
        with pytest.raises(ValueError, match="version"):
            autotune.save_artifact(_table({}, version=99),
                                   str(tmp_path / "t.json"))

    def test_load_rejects_version_mismatch(self, tmp_path):
        path = str(tmp_path / "t.json")
        autotune.save_artifact(_table({}), path)
        import json
        payload = json.load(open(path))
        payload["version"] = autotune.AUTOTUNE_VERSION + 1
        json.dump(payload, open(path, "w"))
        with pytest.raises(ValueError, match="version"):
            autotune.load_artifact(path)

    def test_table_rejects_version_mismatch(self):
        with pytest.raises(ValueError, match="version"):
            autotune.AutotuneTable(_table({}, version=0))

    def test_absent_artifact_falls_back_gracefully(self, tmp_path,
                                                   monkeypatch):
        # a missing/unreadable artifact must leave routing on defaults,
        # never raise at kernel-call time
        monkeypatch.setenv("REPRO_AUTOTUNE",
                           str(tmp_path / "does_not_exist.json"))
        autotune.reset_table()
        assert autotune.get_table() is None
        assert autotune.lookup("flash_decode", 64, 32, jnp.float32) is None

    def test_stale_artifact_falls_back_gracefully(self, tmp_path,
                                                  monkeypatch):
        path = str(tmp_path / "stale.json")
        import json
        json.dump(_table({}, version=autotune.AUTOTUNE_VERSION + 1),
                  open(path, "w"))
        monkeypatch.setenv("REPRO_AUTOTUNE", path)
        autotune.reset_table()
        assert autotune.get_table() is None

    def test_env_disable(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "0")
        autotune.reset_table()
        assert autotune.get_table() is None


class TestShapeKey:
    def test_seq_bucket_pow2(self):
        assert autotune.seq_bucket(1) == 64
        assert autotune.seq_bucket(64) == 64
        assert autotune.seq_bucket(65) == 128
        assert autotune.seq_bucket(100) == 128
        assert autotune.seq_bucket(1024) == 1024

    def test_key_normalizes_dtype(self):
        a = autotune.shape_key("ssd", 100, 16, jnp.float32)
        b = autotune.shape_key("ssd", 128, 16, np.float32)
        c = autotune.shape_key("ssd", 128, 16,
                               jnp.zeros((), jnp.float32).dtype)
        assert a == b == c == "ssd|s128|d16|float32"


class TestRouting:
    def _decode_args(self):
        rng = np.random.default_rng(0)
        b, s, h, d = 2, 64, 2, 32
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        lengths = jnp.asarray([5, 64], jnp.int32)
        return q, k, v, lengths

    def test_ref_entry_routes_to_reference_bitwise(self):
        q, k, v, lengths = self._decode_args()
        key = autotune.shape_key("flash_decode", k.shape[1], q.shape[3],
                                 q.dtype)
        autotune.set_table(autotune.AutotuneTable(
            _table({key: {"backend": "ref"}})))
        out = ops.flash_decode(q, k, v, lengths)
        ref = kref.flash_decode_ref(q, k, v, lengths)
        assert (np.asarray(out) == np.asarray(ref)).all()

    def test_kernel_entry_supplies_blocks(self):
        q, k, v, lengths = self._decode_args()
        key = autotune.shape_key("flash_decode", k.shape[1], q.shape[3],
                                 q.dtype)
        autotune.set_table(autotune.AutotuneTable(
            _table({key: {"backend": "kernel", "block_k": 32}})))
        out = ops.flash_decode(q, k, v, lengths)
        ref = kref.flash_decode_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_explicit_blocks_beat_ref_entry(self):
        """A caller-pinned block size must run the kernel even when the
        table says the reference wins at this shape."""
        q, k, v, lengths = self._decode_args()
        key = autotune.shape_key("flash_decode", k.shape[1], q.shape[3],
                                 q.dtype)
        autotune.set_table(autotune.AutotuneTable(
            _table({key: {"backend": "ref"}})))
        pinned = ops.flash_decode(q, k, v, lengths, block_k=64)
        autotune.set_table(None)
        bare = ops.flash_decode(q, k, v, lengths, block_k=64)
        assert (np.asarray(pinned) == np.asarray(bare)).all()

    def test_other_backend_table_is_ignored(self):
        q, k, v, lengths = self._decode_args()
        key = autotune.shape_key("flash_decode", k.shape[1], q.shape[3],
                                 q.dtype)
        other = "tpu" if jax.default_backend() != "tpu" else "cpu"
        table = autotune.AutotuneTable(
            _table({key: {"backend": "ref"}}, backend=other))
        assert table.lookup("flash_decode", k.shape[1], q.shape[3],
                            q.dtype) is None

    def test_ssd_ref_entry_matches_model_path(self):
        from repro.models.ssm import ssd_chunked
        rng = np.random.default_rng(1)
        b, s, h, p, n = 1, 64, 2, 16, 16
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        dt = jax.nn.softplus(
            jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32) - 1.0)
        A = -jnp.exp(jnp.asarray(rng.standard_normal((h,)), jnp.float32)
                     * 0.5)
        Bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        key = autotune.shape_key("ssd", s, p, x.dtype)
        autotune.set_table(autotune.AutotuneTable(
            _table({key: {"backend": "ref"}})))
        out = ops.ssd(x, dt, A, Bm, Cm)
        ref = ssd_chunked(x, dt, A, Bm, Cm)
        assert (np.asarray(out) == np.asarray(ref)).all()

    def test_attention_ref_entry_matches_model_path(self):
        from repro.models.attention import full_attention
        rng = np.random.default_rng(2)
        b, s, h, d = 1, 64, 2, 16
        q, k, v = (jnp.asarray(rng.standard_normal((b, s, h, d)),
                               jnp.float32) for _ in range(3))
        key = autotune.shape_key("flash_attention", s, d, q.dtype)
        autotune.set_table(autotune.AutotuneTable(
            _table({key: {"backend": "ref"}})))
        out = ops.flash_attention(q, k, v)
        ref = full_attention(q, k, v, causal=True)
        assert (np.asarray(out) == np.asarray(ref)).all()


class TestSweep:
    def test_tiny_sweep_end_to_end(self, monkeypatch, tmp_path):
        """A minimal sweep produces a loadable table whose chosen config
        is never slower than the hard-coded default (the acceptance
        property), and ops picks it up through the env path."""
        monkeypatch.setattr(autotune, "SMOKE_ATTN_CLASSES", [(64, 8)])
        monkeypatch.setattr(autotune, "SMOKE_DECODE_CLASSES", [(64, 8)])
        monkeypatch.setattr(autotune, "SMOKE_PAGED_DECODE_CLASSES",
                            [(8, 8)])
        monkeypatch.setattr(autotune, "SMOKE_SSD_CLASSES", [(64, 8)])
        monkeypatch.setattr(autotune, "SMOKE_CANDIDATES", {
            "flash_attention": [(64, 64), (128, 128)],
            "flash_decode": [64, 128],
            "flash_decode_paged": [None],
            "ssd": [64, 256],
        })
        table, bench = autotune.run_autotune(smoke=True, iters=1)
        assert set(table["entries"]) == set(bench["entries"])
        assert any(k.startswith("flash_decode_paged|s8|")
                   for k in table["entries"])
        for key, e in table["entries"].items():
            assert e["speedup_vs_default"] >= 1.0, (key, e)
            assert e["t_best"] <= e["t_ref"]
            assert e["t_best"] <= e["t_default"]
            if e["backend"] == "ref":
                assert e["t_best"] == e["t_ref"]
        path = str(tmp_path / "autotune.json")
        autotune.save_artifact(table, path)
        monkeypatch.setenv("REPRO_AUTOTUNE", path)
        autotune.reset_table()
        loaded = autotune.get_table()
        assert loaded is not None
        assert loaded.lookup("flash_decode", 64, 8,
                             jnp.float32) is not None


class TestFlashDecodeNoClamp:
    def test_short_cache_pads_to_block(self):
        """s < block_k no longer silently clamps the block size: the
        cache pads up to one full block and the result is exact."""
        rng = np.random.default_rng(3)
        b, s, h, d = 2, 24, 2, 16
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        lengths = jnp.asarray([3, 24], jnp.int32)
        out = ops.flash_decode(q, k, v, lengths, block_k=128)
        ref = kref.flash_decode_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
