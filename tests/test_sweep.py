"""Parallel sweep determinism and plumbing: per-scenario seeding is
derived from the spec alone, so the canonical aggregate output must be
byte-identical across runs and worker counts (DESIGN.md §9)."""
import json

import pytest

from repro.core.sweep import (ScenarioSpec, grid, normalize_policy,
                              run_scenario, run_sweep, rows_by_policy,
                              to_canonical_json)


def _specs():
    return grid(("sjf", "sjf-bsbf"), seeds=(0, 1), n_jobs=24,
                n_servers=8, gpus_per_server=4)


def test_policy_normalization():
    assert normalize_policy("sjf_bsbf") == "sjf-bsbf"
    assert normalize_policy("SJF-FFS") == "sjf-ffs"
    with pytest.raises(ValueError, match="unknown policy"):
        normalize_policy("edf")


def test_grid_shape():
    specs = _specs()
    assert len(specs) == 4
    assert {s.policy for s in specs} == {"sjf", "sjf-bsbf"}
    assert {s.seed for s in specs} == {0, 1}


def test_parallel_matches_serial_and_is_byte_identical():
    specs = _specs()
    serial = run_sweep(specs, workers=1)
    parallel_a = run_sweep(specs, workers=2)
    parallel_b = run_sweep(specs, workers=4)
    assert (to_canonical_json(serial) == to_canonical_json(parallel_a)
            == to_canonical_json(parallel_b))


def test_row_contents():
    row = run_scenario(ScenarioSpec(policy="sjf", n_jobs=16, seed=3,
                                    n_servers=8, gpus_per_server=4,
                                    collect=("jct_deciles",)))
    assert row["policy"] == "sjf"
    assert row["events"] > 0
    assert len(row["jct_deciles"]) == 10
    assert row["jct_deciles"] == sorted(row["jct_deciles"])
    assert set(row["summary"]) >= {"makespan", "avg_jct", "avg_queue"}
    assert row["wall_seconds"] >= 0.0
    # canonical serialization drops the timing field
    canon = json.loads(to_canonical_json([row]))[0]
    assert "wall_seconds" not in canon and canon["policy"] == "sjf"


def test_rows_by_policy():
    rows = run_sweep(grid(("fifo", "sjf"), n_jobs=12, n_servers=8,
                          gpus_per_server=4), workers=1)
    payload = rows_by_policy(rows)
    assert set(payload) == {"fifo", "sjf"}
    assert payload["sjf"]["avg_jct"] > 0


def test_global_xi_and_physical_trace():
    row = run_scenario(ScenarioSpec(policy="sjf-ffs", trace="physical",
                                    n_servers=4, global_xi=1.3))
    assert row["trace"] == "physical"
    assert row["summary"]["makespan"] > 0
    with pytest.raises(ValueError, match="unknown trace"):
        run_scenario(ScenarioSpec(policy="sjf", trace="nope"))
    with pytest.raises(ValueError, match="unknown collect"):
        run_scenario(ScenarioSpec(policy="sjf", n_jobs=4,
                                  collect=("nope",)))


def test_philly_trace_and_queue_percentiles():
    """The sweep runner's capacity-planning surface (DESIGN.md §14):
    trace="philly" regenerates deterministically in the worker, and
    the queue_percentiles collector reports a sorted p50<=p95<=p99."""
    spec = ScenarioSpec(policy="sjf", trace="philly", n_jobs=60, seed=2,
                        n_servers=4, gpus_per_server=4, load_scale=2.0,
                        collect=("queue_percentiles",))
    a, b = run_scenario(spec), run_scenario(spec)
    drop = lambda r: {k: v for k, v in r.items() if k != "wall_seconds"}
    assert drop(a) == drop(b)
    q = a["queue_percentiles"]
    assert set(q) == {"p50", "p90", "p95", "p99"}
    assert q["p50"] <= q["p90"] <= q["p95"] <= q["p99"]
