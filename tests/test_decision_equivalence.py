"""Full-trace pin for the vectorized decision core: every policy must
produce *identical* ``SimResults.summary()`` (and event counts) whether
SJF-BSBF runs the grid whole-pass path, the batched per-job NumPy path,
or the scalar per-pair reference — the vectorized cores mirror the
scalar arithmetic operation-for-operation, so the pin is exact
equality, tighter than the 1e-9 acceptance bound."""
import pytest

from repro.core import (ClusterState, InterferenceModel, Simulator,
                        datacenter_trace, make_scheduler,
                        paper_interference_model, simulation_trace)
from repro.core.schedulers import ALL_POLICIES

GB = 2 ** 30


def _run(policy, decision, interference=None, jobs=None):
    jobs = jobs if jobs is not None else simulation_trace(n_jobs=70, seed=5)
    cluster = ClusterState(n_servers=16, gpus_per_server=4,
                           gpu_capacity_bytes=11 * GB)
    sim = Simulator(cluster, jobs, make_scheduler(policy),
                    interference=interference or paper_interference_model(),
                    decision=decision)
    assert sim.decision_path == decision
    return sim.run()


def _assert_identical(a, b):
    assert a.events == b.events
    assert a.summary() == b.summary()
    for ja, jb in zip(sorted(a.jobs, key=lambda j: j.jid),
                      sorted(b.jobs, key=lambda j: j.jid)):
        assert ja.finish_time == jb.finish_time
        assert ja.sub_batch == jb.sub_batch
        assert ja.placement == jb.placement


@pytest.mark.parametrize("decision", ["batched", "grid"])
@pytest.mark.parametrize("policy", sorted(ALL_POLICIES))
def test_vectorized_matches_scalar_paper_model(policy, decision):
    _assert_identical(_run(policy, "scalar"), _run(policy, decision))


@pytest.mark.parametrize("decision", ["batched", "grid"])
@pytest.mark.parametrize("interference", [
    InterferenceModel(),                  # structural fallback
    InterferenceModel(global_xi=1.4),     # Fig. 6b style injection
], ids=["structural", "global-xi"])
def test_vectorized_matches_scalar_other_xi_regimes(interference, decision):
    _assert_identical(_run("sjf-bsbf", "scalar", interference=interference),
                      _run("sjf-bsbf", decision, interference=interference))


@pytest.mark.parametrize("decision", ["batched", "grid"])
def test_vectorized_matches_scalar_datacenter_trace(decision):
    def run(d):
        jobs = datacenter_trace(n_jobs=150, seed=3, n_gpus=64)
        cluster = ClusterState(n_servers=16, gpus_per_server=4,
                               gpu_capacity_bytes=11 * GB)
        sim = Simulator(cluster, jobs, make_scheduler("sjf-bsbf"),
                        interference=paper_interference_model(),
                        decision=d)
        return sim.run()

    _assert_identical(run("scalar"), run(decision))


def test_scan_heap_agree_on_non_divisor_sub_batch():
    """Both engines must price a co-runner's iteration time with the
    final-microbatch-aware Eq. 7 when memory pressure forces a
    non-divisor sub-batch (regression: ScanEngine used the even-split
    form, diverging from HeapEngine under the structural xi model)."""
    from repro.core import Job, PerfParams

    def mk(jid, model):
        # batch=100 at 0.5 GB/sample on an 11 GB device: candidate 25
        # overflows (13.5 GB) so the solo fit is 13 — a non-divisor of
        # 100 (ceil-halving candidates are 100, 50, 25, 13, 7, 4, 2, 1)
        perf = PerfParams(alpha_comp=2e-3, beta_comp=5e-3, alpha_comm=1e-4,
                          beta_comm=8e-10, msg_bytes=4e8,
                          mem_base=1.0 * GB, mem_per_sample=0.5 * GB)
        return Job(jid=jid, model=model, arrival=float(jid), gpus=2,
                   iters=400.0, batch=100, perf=perf)

    def run(engine):
        jobs = [mk(0, "a"), mk(1, "b"), mk(2, "a"), mk(3, "b")]
        cluster = ClusterState(n_servers=1, gpus_per_server=2,
                               gpu_capacity_bytes=11 * GB)
        sim = Simulator(cluster, jobs, make_scheduler("sjf-ffs"),
                        interference=InterferenceModel(),  # structural xi
                        engine=engine)
        res = sim.run()
        subs = {j.jid: j.sub_batch for j in res.jobs}
        assert any(j.batch % s for j, s in
                   ((j, j.sub_batch) for j in res.jobs)), \
            "scenario must actually exercise a non-divisor sub-batch"
        return res, subs

    res_scan, subs_scan = run("scan")
    res_heap, subs_heap = run("heap")
    assert subs_scan == subs_heap
    assert res_scan.events == res_heap.events
    for key, val in res_scan.summary().items():
        assert res_heap.summary()[key] == pytest.approx(val, rel=1e-9), key


def test_default_decision_is_grid(monkeypatch):
    monkeypatch.delenv("REPRO_SIM_DECISION", raising=False)
    jobs = simulation_trace(n_jobs=8, seed=0)
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    sim = Simulator(cluster, jobs, make_scheduler("sjf-bsbf"))
    assert sim.decision_path == "grid"


def test_decision_env_and_validation(monkeypatch):
    jobs = simulation_trace(n_jobs=8, seed=0)
    cluster = ClusterState(n_servers=4, gpus_per_server=4)
    monkeypatch.setenv("REPRO_SIM_DECISION", "scalar")
    sim = Simulator(cluster, jobs, make_scheduler("sjf-bsbf"))
    assert sim.decision_path == "scalar"
    with pytest.raises(ValueError, match="unknown decision path"):
        Simulator(ClusterState(n_servers=4, gpus_per_server=4),
                  simulation_trace(n_jobs=8, seed=0),
                  make_scheduler("sjf-bsbf"), decision="simd")
