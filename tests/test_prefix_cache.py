"""Prefix-shared paged KV cache (DESIGN.md §18): radix-trie index,
suffix-extend prefill bitwise identity, copy-on-write forking, credit
accounting, LRU retention, brown-out eviction, and property-based
refcount invariants over admit/decode/fork/release sequences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import autotune, ops
from repro.kernels import ref as kref
from repro.launch import serve
from repro.launch.engine import DecodeEngine
from repro.launch.prefix import PrefixTrie
from repro.models import init_cache, init_params, prefill, prefill_extend

from _hypothesis_compat import HealthCheck, given, settings, st

# families whose suffix-extend prefill is bitwise-stable (the gate for
# prefix_share); moe qualifies only under the per-token dense dispatch
SHARE_ARCHS = [
    ("minicpm-2b", {}),                                    # dense
    ("qwen2-vl-2b", {}),                                   # vlm
    ("granite-moe-3b-a800m", {"moe_capacity_factor": 8.0,
                              "moe_dispatch": "dense"}),   # moe
]


def _cfg(name, **kw):
    return dataclasses.replace(get_config(name).reduced(),
                               dtype="float32", **kw)


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS[cfg.name]


# ====================================================================== #
# radix trie
# ====================================================================== #
class TestPrefixTrie:
    def test_miss_on_empty(self):
        t = PrefixTrie(4)
        pages, n = t.match([1, 2, 3])
        assert pages == [] and n == 0

    def test_insert_then_match_full_and_partial(self):
        t = PrefixTrie(4)
        new = t.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11])
        assert new == [10, 11]
        assert t.page_count() == 2
        pages, n = t.match([1, 2, 3, 4, 5, 6, 7, 8, 9])
        assert pages == [10, 11] and n == 8
        # mid-node divergence: matched rows counted, chain ends there
        pages, n = t.match([1, 2, 3, 4, 5, 6, 99, 0])
        assert pages == [10, 11] and n == 6

    def test_reinsert_reuses_nodes(self):
        t = PrefixTrie(4)
        t.insert([1, 2, 3, 4], [7])
        assert t.insert([1, 2, 3, 4], [9]) == []   # node 7 authoritative
        assert t.page_count() == 1
        assert t.match([1, 2, 3, 4])[0] == [7]

    def test_partial_tail_covered_by_longer_sibling_is_skipped(self):
        t = PrefixTrie(4)
        t.insert([1, 2, 3, 4], [7])
        assert t.insert([1, 2], [8]) == []         # rows served by 7
        assert t.page_count() == 1

    def test_divergent_tail_becomes_sibling(self):
        t = PrefixTrie(4)
        t.insert([1, 2, 3, 4, 5, 5], [7, 8])
        new = t.insert([1, 2, 3, 4, 6, 6], [7, 9])
        assert new == [9]
        assert t.match([1, 2, 3, 4, 6, 6]) == ([7, 9], 6)
        assert t.match([1, 2, 3, 4, 5, 5]) == ([7, 8], 6)

    def test_lru_eviction_leaves_first_oldest_first(self):
        t = PrefixTrie(4)
        refs = np.ones(16, np.int32)
        t.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11])
        t.insert([1, 2, 3, 4, 9, 9, 9, 9], [10, 12])
        t.match([1, 2, 3, 4, 9, 9, 9, 9])          # 12 most recent
        assert t.evict_lru(refs) == 11             # LRU leaf first
        assert t.evict_lru(refs) == 12
        assert t.evict_lru(refs) == 10             # interior drained
        assert t.evict_lru(refs) is None

    def test_pinned_page_blocks_eviction_but_not_siblings(self):
        t = PrefixTrie(4)
        refs = np.ones(16, np.int32)
        t.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11])
        t.insert([1, 2, 3, 4, 9, 9, 9, 9], [10, 12])
        refs[11] = 2                               # a slot still maps 11
        assert t.evictable_pages(refs) == 1        # only 12 (10 blocked)
        assert t.evict_lru(refs) == 12
        refs[11] = 1
        assert t.evictable_pages(refs) == 2


# ====================================================================== #
# suffix-extend prefill: bitwise vs the full one-shot prefill
# ====================================================================== #
class TestPrefillExtend:
    @pytest.mark.parametrize("name,kw", SHARE_ARCHS,
                             ids=[a for a, _ in SHARE_ARCHS])
    def test_bitwise_identity_suffix_ge_2(self, name, kw):
        cfg = _cfg(name, **kw)
        params = _params(cfg)
        rng = np.random.default_rng(0)
        max_len = 32
        for plen, start in [(12, 7), (9, 2), (16, 8), (13, 11)]:
            toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, plen)),
                               jnp.int32)
            lg_full, c_full = prefill(cfg, params,
                                      init_cache(cfg, 1, max_len), toks)
            c_pre = init_cache(cfg, 1, max_len)

            def take(dst, src):
                if dst.ndim >= 3 and dst.shape[2] == max_len:
                    return dst.at[:, :, :start].set(src[:, :, :start])
                return dst
            c_pre["units"] = jax.tree.map(take, c_pre["units"],
                                          c_full["units"])
            lg_ext, c_ext = prefill_extend(cfg, params, c_pre,
                                           toks[:, start:], start=start)
            assert (np.asarray(lg_full[:, start:])
                    == np.asarray(lg_ext)).all(), (name, plen, start)

            def rows_equal(a, b):
                if a.ndim >= 3 and a.shape[2] == max_len:
                    assert (np.asarray(a[:, :, :plen])
                            == np.asarray(b[:, :, :plen])).all()
            jax.tree.map(rows_equal, c_full["units"], c_ext["units"])

    def test_rejects_unsupported_family(self):
        cfg = _cfg("zamba2-7b")
        with pytest.raises(AssertionError):
            prefill_extend(cfg, _params(cfg), init_cache(cfg, 1, 32),
                           jnp.zeros((1, 4), jnp.int32), start=4)


# ====================================================================== #
# engine: gating, identity, COW, capacity, reclaim
# ====================================================================== #
def _share_engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("segment", 8)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("n_pages", 32)
    kw.setdefault("debug", True)
    return DecodeEngine(cfg, params, **kw)


def _drain(eng, prompts, tokens=8):
    rids = [eng.submit(p, tokens) for p in prompts]
    eng.run()
    return {r: eng.outputs[r] for r in rids}


class TestPrefixEngineGating:
    def test_requires_paged(self):
        cfg = _cfg("minicpm-2b")
        with pytest.raises(ValueError, match="paged"):
            DecodeEngine(cfg, _params(cfg), prefix_share=True)

    @pytest.mark.parametrize("name,kw,msg", [
        ("zamba2-7b", {}, "bitwise-stable"),               # hybrid
        ("granite-moe-3b-a800m",
         {"moe_capacity_factor": 8.0}, "bitwise-stable"),  # moe einsum
    ])
    def test_rejects_unstable_families(self, name, kw, msg):
        cfg = _cfg(name, **kw)
        with pytest.raises(ValueError, match=msg):
            DecodeEngine(cfg, _params(cfg), paged=True, page_size=8,
                         n_pages=32, max_len=64, prefix_share=True)

    def test_accepts_moe_dense_dispatch(self):
        cfg = _cfg("granite-moe-3b-a800m", moe_capacity_factor=8.0,
                   moe_dispatch="dense")
        eng = _share_engine(cfg, _params(cfg), prefix_share=True)
        assert eng.prefix_share


class TestPrefixEngine:
    @pytest.mark.parametrize("name,kw", SHARE_ARCHS,
                             ids=[a for a, _ in SHARE_ARCHS])
    def test_identity_vs_private_and_solo(self, name, kw):
        """Shared-prefix tokens == private-pages tokens == solo
        generation, across every family supporting the paged layout
        with a bitwise-stable extend path."""
        cfg = _cfg(name, **kw)
        params = _params(cfg)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg.vocab, 20)    # 2.5 pages: COW too
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, 6)])
                   for _ in range(5)]
        base = _drain(_share_engine(cfg, params, prefix_share=False),
                      prompts)
        eng = _share_engine(cfg, params, prefix_share=True)
        out = _drain(eng, prompts)
        assert out == base
        assert eng.stats["prefix_hits"] >= 4
        assert eng.stats["prefill_tokens_saved"] > 0
        solo = serve.generate(cfg, params, jnp.asarray(prompts[1])[None, :],
                              max_new_tokens=8, max_len=64)
        assert list(np.asarray(solo)[0]) == out[1]

    def test_cow_fork_on_boundary_page(self):
        """An unaligned prompt publishes its tail page; the first decode
        write forks it (shared-then-diverge == fully-private)."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(1)
        shared = rng.integers(0, cfg.vocab, 20)
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, 6)])
                   for _ in range(4)]               # plen 26 = 3.25 pages
        base = _drain(_share_engine(cfg, params, prefix_share=False),
                      prompts)
        eng = _share_engine(cfg, params, prefix_share=True)
        assert _drain(eng, prompts) == base
        assert eng.stats["cow_forks"] >= 1

    def test_capacity_at_equal_memory(self):
        """Sharing admits >= 2x the concurrent requests of the private
        baseline at the same page pool."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(2)
        shared = rng.integers(0, cfg.vocab, 24)     # 3 full pages
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, 8)])
                   for _ in range(12)]              # plen 32, +1 decode pg
        kw = dict(n_slots=12, max_len=64, n_pages=20)
        private = _share_engine(cfg, params, prefix_share=False, **kw)
        base = _drain(private, prompts)
        eng = _share_engine(cfg, params, prefix_share=True, **kw)
        assert _drain(eng, prompts) == base
        assert private.stats["peak_active_slots"] == 4   # 20 // 5
        assert eng.stats["peak_active_slots"] >= 8

    def test_drain_returns_all_pages_below_watermark(self):
        """After a full drain with retain_pages=0 every page is back on
        the free list, the trie is empty, and credit is zero."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab, 20)
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, 6)])
                   for _ in range(5)]
        eng = _share_engine(cfg, params, prefix_share=True, retain_pages=0)
        _drain(eng, prompts)
        assert sorted(eng._free_pages) == list(range(eng.n_pages))
        assert eng._trie.page_count() == 0
        assert (eng._page_refs == 0).all()
        assert eng._committed == 0
        assert (eng._pages_np == -1).all()
        assert eng.stats["prefix_evictions"] > 0
        eng._check_invariants()

    def test_retention_watermark_bounds_trie(self):
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(4)
        prompts = [rng.integers(0, cfg.vocab, 16) for _ in range(6)]
        eng = _share_engine(cfg, params, prefix_share=True, retain_pages=4)
        _drain(eng, prompts)
        assert eng._trie.evictable_pages(eng._page_refs) <= 4
        assert eng.stats["prefix_evictions"] > 0

    def test_default_watermark_retains_prefixes(self):
        """With the default watermark (the whole pool) cached prefixes
        persist across drains — a later identical prompt still hits."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(5)
        shared = rng.integers(0, cfg.vocab, 16)
        eng = _share_engine(cfg, params, prefix_share=True)
        _drain(eng, [np.concatenate([shared,
                                     rng.integers(0, cfg.vocab, 8)])])
        assert eng._trie.page_count() > 0
        _drain(eng, [np.concatenate([shared,
                                     rng.integers(0, cfg.vocab, 8)])])
        assert eng.stats["prefix_hits"] == 1

    def test_brownout_evicts_prefixes_before_shedding(self):
        """Satellite 6: under brown-out the engine reclaims zero-ref
        cached prefixes first (counted separately from shed requests),
        and sheds only what freed memory cannot admit."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        rng = np.random.default_rng(6)
        shared = rng.integers(0, cfg.vocab, 24)
        mk = lambda: np.concatenate(  # noqa: E731
            [shared, rng.integers(0, cfg.vocab, 8)])
        eng = _share_engine(cfg, params, prefix_share=True, n_slots=8,
                            n_pages=20, max_len=64, brownout_depth=1)
        _drain(eng, [mk()])                        # cold cache: seed trie
        assert eng._trie.page_count() > 0
        rids = [eng.submit(mk(), 8) for _ in range(8)]
        eng.run()
        assert eng.stats["brownout_prefix_evictions"] > 0
        served = [r for r in rids if r not in eng.shed]
        for r in served:
            assert len(eng.outputs[r]) == 8
        # evictions are counted separately from shed requests, and the
        # freed pages admit more of the burst than the plain brown-out
        # formula (queue - depth = 7 shed) would have served
        assert eng.stats["shed_brownout"] == len(eng.shed)
        assert len(eng.shed) < len(rids) - eng.brownout_depth
        assert len(served) == len(rids) - len(eng.shed) >= 4

    def test_debug_asserts_on_sentinel_corruption(self):
        """Satellite 2: a -1 sentinel inside the mapped range (or a
        mapped entry past it) trips the debug audit."""
        cfg = _cfg("minicpm-2b")
        params = _params(cfg)
        eng = _share_engine(cfg, params, prefix_share=True)
        eng.submit(np.arange(8, dtype=np.int64) % cfg.vocab, 16)
        eng.step_segment()                 # debug mode audited this step
        assert eng.active.any()            # 8 tokens left: slot still live
        slot = int(np.argmax(eng.active))
        keep = eng._pages_np[slot, 0]
        eng._pages_np[slot, 0] = -1
        with pytest.raises(AssertionError, match="sentinel"):
            eng._check_invariants()
        eng._pages_np[slot, 0] = keep
        eng._pages_np[slot, 7] = 0                 # past npages
        with pytest.raises(AssertionError, match="past npages"):
            eng._check_invariants()


# ====================================================================== #
# tuned routing for the paged decode kernel (satellite 1)
# ====================================================================== #
class TestPagedDecodeRouting:
    def _args(self):
        rng = np.random.default_rng(7)
        b, h, hkv, d, ps, n_pg, p_tab = 2, 4, 2, 32, 8, 6, 2
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((n_pg, ps, hkv, d)),
                         jnp.float32)
        vp = jnp.asarray(rng.standard_normal((n_pg, ps, hkv, d)),
                         jnp.float32)
        pages = jnp.asarray([[0, 1], [2, -1]], jnp.int32)
        lengths = jnp.asarray([16, 5], jnp.int32)
        return q, kp, vp, pages, lengths

    def _table(self, entries):
        return autotune.AutotuneTable(
            {"version": autotune.AUTOTUNE_VERSION, "created": 0.0,
             "meta": {"backend": jax.default_backend(), "interpret": True,
                      "smoke": True, "iters": 1},
             "entries": entries})

    def test_ref_entry_routes_to_gather_oracle_bitwise(self):
        q, kp, vp, pages, lengths = self._args()
        key = autotune.shape_key("flash_decode_paged", kp.shape[1],
                                 q.shape[3], q.dtype)
        try:
            autotune.set_table(self._table({key: {"backend": "ref"}}))
            out = ops.flash_decode_paged(q, kp, vp, pages, lengths)
        finally:
            autotune.reset_table()
        ref = kref.flash_decode_paged_ref(q, kp, vp, pages, lengths)
        assert (np.asarray(out) == np.asarray(ref)).all()

    def test_kernel_entry_keeps_kernel_path(self):
        q, kp, vp, pages, lengths = self._args()
        key = autotune.shape_key("flash_decode_paged", kp.shape[1],
                                 q.shape[3], q.dtype)
        try:
            autotune.set_table(self._table({key: {"backend": "kernel"}}))
            out = ops.flash_decode_paged(q, kp, vp, pages, lengths)
        finally:
            autotune.reset_table()
        ref = kref.flash_decode_paged_ref(q, kp, vp, pages, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_page_size_classes_do_not_collide(self):
        keys = {autotune.shape_key("flash_decode_paged", ps, 64,
                                   jnp.float32) for ps in (8, 16, 32)}
        assert len(keys) == 3


# ====================================================================== #
# property-based refcount invariants (satellite 3)
# ====================================================================== #
_ENGINES = {}


def _prop_engine(key, **kw):
    """One long-lived engine per property (jits compile once; state
    persisting across hypothesis examples is the point — the invariants
    must hold from ANY starting trie/refcount state)."""
    if key not in _ENGINES:
        cfg = _cfg("minicpm-2b")
        _ENGINES[key] = _share_engine(cfg, _params(cfg), n_slots=3,
                                      n_pages=24, **kw)
    return _ENGINES[key]


@st.composite
def _workloads(draw):
    """A sequence of prompts over a tiny shared-prefix family: tenant
    choice, prefix reuse length, and decode length all vary, covering
    admit/extend/COW-fork/release interleavings."""
    n = draw(st.integers(2, 5))
    reqs = [(draw(st.integers(0, 2)),              # tenant
             draw(st.sampled_from([8, 14, 20, 26])),   # plen (bounded:
             draw(st.sampled_from([8, 16])))       # one jit per plen)
            for _ in range(n)]
    retain = draw(st.sampled_from([0, None]))
    return reqs, retain


class TestRefcountProperties:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(_workloads())
    def test_invariants_hold_through_any_sequence(self, workload):
        """sum(refcounts) == mapped block-table entries + trie nodes at
        every segment (debug mode audits each step); no page is both
        free and referenced; a full drain returns every reservation."""
        reqs, retain = workload
        eng = _prop_engine(("inv", retain), prefix_share=True,
                           retain_pages=retain)
        rng = np.random.default_rng(8)
        tenants = [rng.integers(0, eng.cfg.vocab, 32) for _ in range(3)]
        rids = []
        for tenant, plen, tokens in reqs:
            rids.append((eng.submit(tenants[tenant][:plen], tokens),
                         tokens))
        eng.run()                      # debug=True audits every segment
        for rid, tokens in rids:
            assert len(eng.outputs[rid]) == tokens
        # full drain: every page accounted for
        refs = eng._page_refs
        assert len(eng._free_pages) + int((refs > 0).sum()) == eng.n_pages
        assert int(refs.sum()) == eng._trie.page_count()
        assert eng._committed == 0
        if retain == 0:
            assert eng._trie.page_count() == 0
            assert sorted(eng._free_pages) == list(range(eng.n_pages))
        eng._check_invariants()

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5)),
                    min_size=2, max_size=4))
    def test_shared_tokens_bitwise_match_private(self, spec):
        """Bit-identical tokens, shared-prefix vs private-pages, over
        arbitrary tenant/suffix combinations (the shared engine's trie
        carries over between examples, so later examples mix warm hits
        with cold misses)."""
        rng = np.random.default_rng(9)
        cfg = _cfg("minicpm-2b")
        tenants = [rng.integers(0, cfg.vocab, 12) for _ in range(3)]
        sufs = [rng.integers(0, cfg.vocab, 6) for _ in range(6)]
        prompts = [np.concatenate([tenants[t], sufs[s]])
                   for t, s in spec]
        base = _drain(_prop_engine("bit-private", prefix_share=False),
                      prompts)
        out = _drain(_prop_engine("bit-shared", prefix_share=True),
                     prompts)
        assert list(base.values()) == list(out.values())
