"""Co-scheduled execution: the fused pair program advances both jobs, the
structural xi model is sane, and the measured ratios obey the
time-multiplexing bounds."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.coschedule import (JobSpec, _make_state, make_pair_step,
                                   measure_pair, structural_xi)


def _spec(name, **kw):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    return JobSpec(cfg, batch=2, seq=32, **kw)


def test_pair_step_advances_both_jobs():
    sa, sb = _spec("minicpm-2b"), _spec("qwen2-vl-2b", accum_steps=2)
    pa, oa, ba = _make_state(sa)
    pb, ob, bb = _make_state(sb)
    pair = make_pair_step(sa, sb)
    pa2, oa2, ma, pb2, ob2, mb = pair(pa, oa, ba, pb, ob, bb)
    assert int(oa2.step) == 1 and int(ob2.step) == 1
    assert np.isfinite(float(ma["loss"])) and np.isfinite(float(mb["loss"]))
    moved_a = any(bool((x != y).any()) for x, y in
                  zip(jax.tree.leaves(pa), jax.tree.leaves(pa2)))
    moved_b = any(bool((x != y).any()) for x, y in
                  zip(jax.tree.leaves(pb), jax.tree.leaves(pb2)))
    assert moved_a and moved_b


def test_structural_xi_bounds():
    # strict time multiplexing: xi = (t_me + t_other) / t_me
    assert structural_xi(1.0, 1.0) == 2.0
    assert structural_xi(2.0, 1.0) == 1.5
    # overlap credits reduce xi toward 1
    assert 1.0 < structural_xi(1.0, 1.0, overlap=0.5) < 2.0
    # HBM pressure adds a penalty
    assert structural_xi(1.0, 1.0, mem_frac=1.0) > 2.0


def test_measured_xi_exceeds_one():
    sa, sb = _spec("minicpm-2b"), _spec("minicpm-2b", seed=3)
    r = measure_pair(sa, sb, iters=1)
    assert r["xi_a"] > 1.0 and r["xi_b"] > 1.0
    # fused program can't be faster than the slower solo job
    assert r["t_pair"] >= 0.9 * max(r["t_a_solo"], r["t_b_solo"])
