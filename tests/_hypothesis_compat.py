"""Import hypothesis when available; otherwise provide inert stand-ins
so test modules stay importable and ONLY the property-based tests skip —
the plain tests in the same files (scheduler invariants, Theorem-1
endpoints, perf-model algebra) must keep running in environments without
the [test] extra."""
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="property tests need the [test] extra "
               "(pip install -e .[test])")

    def given(*_args, **_kwargs):
        return lambda fn: _SKIP(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Anything:
        """Stands in for `st` / `HealthCheck`: any attribute access or
        call yields another inert object, enough to evaluate strategy
        expressions at decoration time."""

        def __getattr__(self, _name):
            return _Anything()

        def __call__(self, *_args, **_kwargs):
            return _Anything()

    st = _Anything()
    HealthCheck = _Anything()

__all__ = ["HAS_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
