"""Workload generator tests."""
import pytest

from repro.core.tasks import PAPER_TASK_PROFILES
from repro.core.trace import (DATACENTER_GPU_DEMAND, TraceConfig,
                              datacenter_trace, generate_trace,
                              physical_trace, simulation_trace)


def test_physical_trace_shape():
    jobs = physical_trace(seed=0)
    assert len(jobs) == 30
    small = [j for j in jobs if j.gpus <= 8]
    large = [j for j in jobs if j.gpus in (12, 16)]
    assert len(small) == 20
    assert len(large) == 10
    for j in jobs:
        assert 100 <= j.iters <= 5000
        assert j.model in PAPER_TASK_PROFILES
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)


def test_trace_determinism():
    a = simulation_trace(n_jobs=50, seed=42)
    b = simulation_trace(n_jobs=50, seed=42)
    assert [(j.model, j.arrival, j.gpus, j.iters) for j in a] == \
           [(j.model, j.arrival, j.gpus, j.iters) for j in b]
    c = simulation_trace(n_jobs=50, seed=43)
    assert [(j.arrival) for j in a] != [(j.arrival) for j in c]


def test_gpu_demand_support():
    cfg = TraceConfig(n_jobs=300, seed=1,
                      gpu_demand=((1, 0.5), (4, 0.3), (8, 0.2)))
    jobs = generate_trace(cfg)
    assert {j.gpus for j in jobs} <= {1, 4, 8}
    # rough distribution sanity
    ones = sum(1 for j in jobs if j.gpus == 1)
    assert 0.3 < ones / 300 < 0.7


def test_iter_bounds():
    cfg = TraceConfig(n_jobs=200, seed=2, min_iters=100, max_iters=5000)
    for j in generate_trace(cfg):
        assert 100 <= j.iters <= 5000 * 1.01


def test_datacenter_trace_shape_and_determinism():
    a = datacenter_trace(n_jobs=400, seed=9, n_gpus=256)
    b = datacenter_trace(n_jobs=400, seed=9, n_gpus=256)
    assert [(j.model, j.arrival, j.gpus, j.iters) for j in a] == \
           [(j.model, j.arrival, j.gpus, j.iters) for j in b]
    demands = {g for g, _ in DATACENTER_GPU_DEMAND}
    for j in a:
        assert j.gpus in demands and j.gpus <= 256
        assert 200 <= j.iters <= 50000 * 1.01
    arr = [j.arrival for j in a]
    assert arr == sorted(arr)
    # the heavy tail is present at this sample size
    assert any(j.gpus >= 32 for j in a)


def test_datacenter_trace_demand_capped_at_cluster():
    jobs = datacenter_trace(n_jobs=300, seed=1, n_gpus=16)
    assert all(j.gpus <= 16 for j in jobs)


def test_datacenter_trace_load_scales_arrival_rate():
    """Same work, higher target utilization -> compressed arrivals."""
    relaxed = datacenter_trace(n_jobs=200, seed=4, n_gpus=128,
                               utilization=0.5)
    loaded = datacenter_trace(n_jobs=200, seed=4, n_gpus=128,
                              utilization=1.0)
    assert loaded[-1].arrival < relaxed[-1].arrival


def test_perf_params_scale_with_gpus():
    """More workers -> larger all-reduce message per worker (ring)."""
    cfg1 = TraceConfig(n_jobs=1, seed=3, gpu_demand=((2, 1.0),))
    cfg2 = TraceConfig(n_jobs=1, seed=3, gpu_demand=((16, 1.0),))
    j2 = generate_trace(cfg1)[0]
    j16 = generate_trace(cfg2)[0]
    assert j16.perf.msg_bytes > j2.perf.msg_bytes
