"""Workload generator tests, including the distributional property
tests for the datacenter-scale generators (``datacenter_trace``,
``philly_trace``): determinism per seed, non-decreasing arrivals,
demand/duration tails inside KS-style sanity bounds of the configured
distributions, and every sampled job schedulable on the cluster the
trace was generated for."""
import math

import pytest

from _hypothesis_compat import given, st
from repro.core.tasks import PAPER_TASK_PROFILES
from repro.core.trace import (DATACENTER_GPU_DEMAND, PHILLY_GPU_DEMAND,
                              TraceConfig, datacenter_trace,
                              generate_trace, philly_trace,
                              physical_trace, simulation_trace)


def test_physical_trace_shape():
    jobs = physical_trace(seed=0)
    assert len(jobs) == 30
    small = [j for j in jobs if j.gpus <= 8]
    large = [j for j in jobs if j.gpus in (12, 16)]
    assert len(small) == 20
    assert len(large) == 10
    for j in jobs:
        assert 100 <= j.iters <= 5000
        assert j.model in PAPER_TASK_PROFILES
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)


def test_trace_determinism():
    a = simulation_trace(n_jobs=50, seed=42)
    b = simulation_trace(n_jobs=50, seed=42)
    assert [(j.model, j.arrival, j.gpus, j.iters) for j in a] == \
           [(j.model, j.arrival, j.gpus, j.iters) for j in b]
    c = simulation_trace(n_jobs=50, seed=43)
    assert [(j.arrival) for j in a] != [(j.arrival) for j in c]


def test_gpu_demand_support():
    cfg = TraceConfig(n_jobs=300, seed=1,
                      gpu_demand=((1, 0.5), (4, 0.3), (8, 0.2)))
    jobs = generate_trace(cfg)
    assert {j.gpus for j in jobs} <= {1, 4, 8}
    # rough distribution sanity
    ones = sum(1 for j in jobs if j.gpus == 1)
    assert 0.3 < ones / 300 < 0.7


def test_iter_bounds():
    cfg = TraceConfig(n_jobs=200, seed=2, min_iters=100, max_iters=5000)
    for j in generate_trace(cfg):
        assert 100 <= j.iters <= 5000 * 1.01


def test_datacenter_trace_shape_and_determinism():
    a = datacenter_trace(n_jobs=400, seed=9, n_gpus=256)
    b = datacenter_trace(n_jobs=400, seed=9, n_gpus=256)
    assert [(j.model, j.arrival, j.gpus, j.iters) for j in a] == \
           [(j.model, j.arrival, j.gpus, j.iters) for j in b]
    demands = {g for g, _ in DATACENTER_GPU_DEMAND}
    for j in a:
        assert j.gpus in demands and j.gpus <= 256
        assert 200 <= j.iters <= 50000 * 1.01
    arr = [j.arrival for j in a]
    assert arr == sorted(arr)
    # the heavy tail is present at this sample size
    assert any(j.gpus >= 32 for j in a)


def test_datacenter_trace_demand_capped_at_cluster():
    jobs = datacenter_trace(n_jobs=300, seed=1, n_gpus=16)
    assert all(j.gpus <= 16 for j in jobs)


def test_datacenter_trace_load_scales_arrival_rate():
    """Same work, higher target utilization -> compressed arrivals."""
    relaxed = datacenter_trace(n_jobs=200, seed=4, n_gpus=128,
                               utilization=0.5)
    loaded = datacenter_trace(n_jobs=200, seed=4, n_gpus=128,
                              utilization=1.0)
    assert loaded[-1].arrival < relaxed[-1].arrival


def test_perf_params_scale_with_gpus():
    """More workers -> larger all-reduce message per worker (ring)."""
    cfg1 = TraceConfig(n_jobs=1, seed=3, gpu_demand=((2, 1.0),))
    cfg2 = TraceConfig(n_jobs=1, seed=3, gpu_demand=((16, 1.0),))
    j2 = generate_trace(cfg1)[0]
    j16 = generate_trace(cfg2)[0]
    assert j16.perf.msg_bytes > j2.perf.msg_bytes


# ===================================================================== #
# Philly-shaped trace (DESIGN.md §14; benchmarks/sim_scale.py)
# ===================================================================== #

GB = 2 ** 30


def _key(jobs):
    return [(j.model, j.arrival, j.gpus, j.iters, j.batch) for j in jobs]


def test_philly_trace_determinism():
    a = philly_trace(n_jobs=300, seed=21, n_gpus=128)
    b = philly_trace(n_jobs=300, seed=21, n_gpus=128)
    assert _key(a) == _key(b)
    c = philly_trace(n_jobs=300, seed=22, n_gpus=128)
    assert _key(a) != _key(c)


def test_philly_trace_arrivals_sorted_and_demand_support():
    jobs = philly_trace(n_jobs=500, seed=5, n_gpus=256)
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)
    demands = {g for g, _ in PHILLY_GPU_DEMAND}
    assert all(j.gpus in demands and j.gpus <= 256 for j in jobs)
    assert [j.jid for j in jobs] == list(range(500))


def test_philly_gpu_demand_matches_configured_cdf():
    """KS-style bound: the empirical demand CDF stays within 0.05 of
    the configured one at n=2000 (the 1% KS critical distance is
    ~0.036; the slack covers the seeded draw)."""
    jobs = philly_trace(n_jobs=2000, seed=11, n_gpus=1024)
    n = len(jobs)
    acc = 0.0
    for g, p in PHILLY_GPU_DEMAND:
        acc += p
        empirical = sum(1 for j in jobs if j.gpus <= g) / n
        assert abs(empirical - acc) < 0.05, f"CDF at {g} GPUs"
    # the thin 32+ tail is present at this sample size (p ~ 3%)
    assert any(j.gpus >= 32 for j in jobs)


def test_philly_duration_tail_matches_lognormal():
    """Solo durations (iters * solo t_iter) must look like the
    configured log-normal: sample median near ``median_seconds``, the
    heavy tail realized (p90/p50 well above 1), and every duration
    inside the clip bounds (modulo iteration rounding)."""
    jobs = philly_trace(n_jobs=2000, seed=13, n_gpus=1024,
                        median_seconds=600.0, sigma=1.8)
    durs = sorted(j.iters * j.solo_t_iter for j in jobs)
    n = len(durs)
    median = durs[n // 2]
    # stderr of the log-median is sigma * 1.25 / sqrt(n) ~ 5%; allow 4x
    assert 600.0 * 0.8 < median < 600.0 * 1.25
    assert durs[int(0.9 * n)] / median > math.exp(1.28 * 1.8) * 0.5
    t_iter_max = max(j.solo_t_iter for j in jobs)
    assert durs[0] >= 30.0 * 0.9 - t_iter_max
    assert durs[-1] <= 30.0 * 86400.0 * 1.01 + t_iter_max


def test_philly_arrivals_are_diurnal():
    """Arrivals must oscillate with the configured day cycle: the mean
    of sin(2*pi*(t - 6h)/24h) over arrival times estimates amp/2 (0.25
    at the default amplitude); a homogeneous process estimates ~0."""
    jobs = philly_trace(n_jobs=2000, seed=17, n_gpus=64)
    assert jobs[-1].arrival > 2 * 86400.0   # spans multiple days
    stat = sum(math.sin(2.0 * math.pi * (j.arrival - 21600.0) / 86400.0)
               for j in jobs) / len(jobs)
    assert stat > 0.1
    flat = philly_trace(n_jobs=2000, seed=17, n_gpus=64,
                        diurnal_amplitude=0.0)
    stat0 = sum(math.sin(2.0 * math.pi * (j.arrival - 21600.0) / 86400.0)
                for j in flat) / len(flat)
    assert abs(stat0) < 0.1


def test_philly_utilization_scales_arrival_rate():
    relaxed = philly_trace(n_jobs=300, seed=4, n_gpus=128, utilization=0.5)
    loaded = philly_trace(n_jobs=300, seed=4, n_gpus=128, utilization=1.0)
    assert loaded[-1].arrival < relaxed[-1].arrival


@pytest.mark.parametrize("mk,kw", [
    (philly_trace, {}),
    (datacenter_trace, {}),
])
def test_trace_jobs_schedulable_on_configured_cluster(mk, kw):
    """Every sampled job must be placeable on the cluster the trace
    was generated for: demand capped at the cluster size and the solo
    memory footprint inside the 11 GB bench GPU at the default
    sub-batch."""
    jobs = mk(n_jobs=400, seed=3, n_gpus=64, **kw)
    for j in jobs:
        assert 1 <= j.gpus <= 64
        assert j.iters >= 10
        assert j.perf.mem_bytes(j.sub_batch) <= 11 * GB


@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_philly_trace_properties_hold_for_any_seed(seed):
    """Per-seed invariants (hypothesis): determinism, sorted arrivals,
    configured demand support, clip-bounded durations, schedulability."""
    a = philly_trace(n_jobs=40, seed=seed, n_gpus=32)
    b = philly_trace(n_jobs=40, seed=seed, n_gpus=32)
    assert _key(a) == _key(b)
    arr = [j.arrival for j in a]
    assert arr == sorted(arr)
    demands = {g for g, _ in PHILLY_GPU_DEMAND}
    for j in a:
        assert j.gpus in demands and j.gpus <= 32
        assert j.iters >= 10
        assert j.perf.mem_bytes(j.sub_batch) <= 11 * GB
        assert j.iters * j.solo_t_iter <= 30.0 * 86400.0 * 1.01 + 1.0


@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_datacenter_trace_properties_hold_for_any_seed(seed):
    a = datacenter_trace(n_jobs=40, seed=seed, n_gpus=32)
    b = datacenter_trace(n_jobs=40, seed=seed, n_gpus=32)
    assert _key(a) == _key(b)
    arr = [j.arrival for j in a]
    assert arr == sorted(arr)
    for j in a:
        assert 1 <= j.gpus <= 32
        assert 200 <= j.iters <= 50000 * 1.01
        assert j.perf.mem_bytes(j.sub_batch) <= 11 * GB
