"""Input-shape layer coverage: every (arch x shape) combination produces
well-formed ShapeDtypeStruct stand-ins WITHOUT allocating; skip rules and
long-context variants match DESIGN.md §5; and this test process sees ONE
device (the 512-device XLA flag must stay inside dryrun.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import (INPUT_SHAPES, LONG_CTX_WINDOW,
                                  input_specs, shape_applicable,
                                  variant_for_shape)


def test_tests_see_one_device():
    # smoke tests/benches must NOT inherit the dry-run's 512 fake devices
    assert len(jax.devices()) == 1


def test_shape_catalogue():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape_name", sorted(INPUT_SHAPES))
def test_input_specs_all_combos(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        assert arch == "whisper-tiny" and shape_name == "long_500k"
        return
    specs = input_specs(cfg, shape)
    # everything is a ShapeDtypeStruct — no device allocation happened
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    if shape.kind in ("train", "prefill"):
        assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        assert specs["tokens"].dtype == jnp.int32
        if shape.kind == "train":
            assert specs["labels"].shape == specs["tokens"].shape
        if cfg.family == "vlm":
            assert specs["vision_embeds"].shape == (
                shape.global_batch, cfg.vision_tokens, cfg.d_model)
        if cfg.family == "audio":
            assert specs["frames"].shape == (
                shape.global_batch, cfg.encoder_seq, cfg.d_model)
    else:
        assert specs["tokens"].shape == (shape.global_batch, 1)
        cache = specs["cache"]
        assert "units" in cache and "index" in cache
        vcfg = variant_for_shape(cfg, shape)
        # KV caches sized seq_len, or the sliding window for long-context
        # dense variants; SSM caches are O(1) in seq_len
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            keys = [str(getattr(p, "key", "")) for p in path]
            if keys[-1] in ("k", "v") and "cross" not in keys:
                expect = (min(vcfg.sliding_window, shape.seq_len)
                          if vcfg.sliding_window else shape.seq_len)
                assert leaf.shape[2] == expect, (arch, shape_name, keys)


def test_long_ctx_variant_rules():
    long = INPUT_SHAPES["long_500k"]
    # dense/vlm/moe get the sliding window; ssm/hybrid run natively
    assert variant_for_shape(get_config("glm4-9b"), long).sliding_window \
        == LONG_CTX_WINDOW
    assert variant_for_shape(get_config("llama4-maverick-400b-a17b"),
                             long).sliding_window == LONG_CTX_WINDOW
    assert variant_for_shape(get_config("xlstm-1.3b"), long).sliding_window \
        == 0
    assert variant_for_shape(get_config("zamba2-7b"), long).sliding_window \
        == 0
    # other shapes never mutate the config
    assert variant_for_shape(get_config("glm4-9b"),
                             INPUT_SHAPES["decode_32k"]).sliding_window == 0


def test_ssm_cache_is_constant_in_seq():
    cfg = get_config("xlstm-1.3b")
    s32 = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    s500 = input_specs(cfg, INPUT_SHAPES["long_500k"])
    n32 = sum(l.size for l in jax.tree.leaves(s32["cache"]))
    n500 = sum(l.size for l in jax.tree.leaves(s500["cache"]))
    # batch 128 -> 1 shrinks it; per-sequence state is seq-independent
    assert n500 < n32
