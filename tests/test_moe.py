"""MoE routing correctness: the capacity-based einsum dispatch must match
the dense every-expert oracle when capacity is sufficient; padded experts
never receive tokens; aux loss behaves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.moe import (_top_k_positions, moe_forward, moe_init)


def _setup(e=4, d=32, f=64, top_k=2, pad_to=0, key=0):
    p = moe_init(jax.random.PRNGKey(key), d, e, f, pad_to=pad_to)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (2, 16, d)) * 0.5
    return p, x


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_einsum_matches_dense_oracle(top_k):
    p, x = _setup(top_k=top_k)
    y_ein, aux1 = moe_forward(p, x, n_experts=4, top_k=top_k,
                              capacity_factor=8.0)  # no drops
    y_dense, aux2 = moe_forward(p, x, n_experts=4, top_k=top_k,
                                dispatch="dense")
    np.testing.assert_allclose(np.asarray(y_ein), np.asarray(y_dense),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


def test_padded_experts_get_no_tokens():
    p, x = _setup(e=3, pad_to=8)
    assert p["router"]["w"].shape[-1] == 8
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(
        jnp.where(jnp.arange(8) >= 3, -1e30, logits), axis=-1)
    assert float(probs[..., 3:].max()) == 0.0
    y, aux = moe_forward(p, x, n_experts=3, top_k=2, capacity_factor=8.0)
    assert bool(jnp.isfinite(y).all())


def test_capacity_drops_reduce_output():
    """With capacity 1 slot per expert most tokens are dropped -> output
    differs from the no-drop case (sanity that capacity is enforced)."""
    p, x = _setup()
    y_full, _ = moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    y_tight, _ = moe_forward(p, x, n_experts=4, top_k=2,
                             capacity_factor=0.05)
    assert float(jnp.abs(y_full - y_tight).max()) > 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_positions_respect_capacity_property(seed, top_k):
    """Property: assigned slot positions are always < capacity when kept,
    and no (expert, slot) pair is used twice within a group."""
    rng = np.random.default_rng(seed)
    G, g, E, cap = 2, 8, 4, 3
    idx = jnp.asarray(rng.integers(0, E, (G, g, top_k)), jnp.int32)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)
    pos, keep = _top_k_positions(onehot, idx, E, cap)
    pos = np.asarray(pos)
    keep = np.asarray(keep)
    assert (pos[keep] < cap).all()
    for G_i in range(G):
        used = set()
        for g_i in range(g):
            for k_i in range(top_k):
                if keep[G_i, g_i, k_i]:
                    key = (int(idx[G_i, g_i, k_i]), int(pos[G_i, g_i, k_i]))
                    assert key not in used, "slot collision"
                    used.add(key)
