"""The paper's central convergence claim: gradient accumulation at
sub-batch B/s is EXACTLY one step at batch B (Section IV-A.4). We prove it
numerically: accumulated grads == full-batch grads, and s-step training
trajectories match the full-batch trajectory."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.data import make_batch
from repro.models import init_params
from repro.train import (TrainConfig, accumulate_gradients, adamw_init,
                         loss_fn, make_train_step)


def _setup(name="minicpm-2b", batch=8, seq=32):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch_data = make_batch(cfg, batch, seq)
    return cfg, params, batch_data


def _lg(cfg):
    def lg(params, mb):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
        return loss, grads
    return lg


@pytest.mark.parametrize("accum_steps", [2, 4, 8])
def test_grads_match_full_batch(accum_steps):
    cfg, params, batch = _setup()
    lg = _lg(cfg)
    loss_full, g_full = lg(params, batch)
    loss_acc, g_acc = accumulate_gradients(lg, params, batch, accum_steps)
    np.testing.assert_allclose(float(loss_acc), float(loss_full),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-6)


def test_training_trajectory_matches():
    """3 optimizer steps with s=4 == 3 steps with s=1 (same batches)."""
    cfg, params, _ = _setup()
    opt = adamw_init(params)
    step1 = jax.jit(make_train_step(cfg, TrainConfig(accum_steps=1)))
    step4 = jax.jit(make_train_step(cfg, TrainConfig(accum_steps=4)))
    pa, oa = params, opt
    pb, ob = params, opt
    for i in range(3):
        batch = make_batch(cfg, 8, 32, step=i)
        pa, oa, _ = step1(pa, oa, batch)
        pb, ob, _ = step4(pb, ob, batch)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_moe_grads_match():
    """Grad-accum equivalence holds for the MoE *data* loss (routing is
    per-token, so micro-batch splits do not change expert assignment).

    Caveat found here and documented in DESIGN.md §8: the load-balance
    aux loss is a BATCH STATISTIC (mean routed fraction x mean prob), so
    it is not linear in the batch split — equivalence is exact only with
    aux_loss_weight=0 (or per-micro-batch aux, which is what most
    frameworks actually optimize)."""
    cfg, params, batch = _setup("granite-moe-3b-a800m")
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops

    def lg(params, mb):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb, aux_loss_weight=0.0),
            has_aux=True)(params)
        return loss, grads

    _, g_full = lg(params, batch)
    _, g_acc = accumulate_gradients(lg, params, batch, 4)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("batch_size,accum_steps", [
    (7, 4),    # b = 2, micro sizes (2, 2, 2, 1): masked final micro-batch
    (6, 4),    # b = 2, s_eff = 3 < requested s (ceil semantics)
    (5, 3),    # b = 2, micro sizes (2, 2, 1)
])
def test_ragged_accum_matches_full_batch(batch_size, accum_steps):
    """Non-divisor batches: s = ceil(B/b) with a masked final micro-batch
    must still reproduce the exact full-batch loss and gradients — the
    same semantics ``candidate_sub_batches`` / ``PerfParams.t_iter_sub``
    price in the simulator, so the physical executor and the scheduler
    agree on what a non-divisor sub-batch costs AND computes."""
    cfg, params, _ = _setup(batch=batch_size)
    batch = make_batch(cfg, batch_size, 32)
    lg = _lg(cfg)
    loss_full, g_full = lg(params, batch)
    loss_acc, g_acc = accumulate_gradients(lg, params, batch, accum_steps)
    np.testing.assert_allclose(float(loss_acc), float(loss_full),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-6)


def test_ragged_accum_under_jit_train_step():
    """The masked final micro-batch must survive jit + scan inside the
    donated train step (sample_mask is injected under trace)."""
    cfg, params, _ = _setup(batch=7)
    from repro.train import make_jit_train_step
    opt = adamw_init(params)
    step = make_jit_train_step(cfg, TrainConfig(accum_steps=4))
    batch = make_batch(cfg, 7, 32)
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(opt.step) == 1


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.integers(0, 2 ** 31 - 1))
def test_accum_loss_invariant_property(s, seed):
    """Property: the accumulated loss equals the full-batch loss for any
    power-of-two s and any batch content."""
    cfg, params, _ = _setup(batch=4, seq=16)
    batch = make_batch(cfg, 4, 16, seed=seed)
    lg = _lg(cfg)
    loss_full, _ = lg(params, batch)
    loss_acc, _ = accumulate_gradients(lg, params, batch, s)
    np.testing.assert_allclose(float(loss_acc), float(loss_full),
                               rtol=1e-5, atol=1e-6)
