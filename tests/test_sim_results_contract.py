"""Regression pin for the :class:`repro.core.engine.SimResults`
degenerate-input contract (documented on the class): ``avg_jct`` /
``avg_queueing`` return **0.0 silently when the selection is empty** —
an empty job list, or a large/small split with no members. Downstream
consumers (sweep collectors, bench acceptance checks) average these
averages and must be able to rely on 0.0-with-empty-selection staying
0.0 rather than becoming an exception or NaN."""
import pytest

from repro.core import (ClusterState, Simulator, make_scheduler,
                        paper_interference_model)
from repro.core.engine import SimResults
from repro.core.job import Job
from repro.core.perf_model import GPU_2080TI
from repro.core.tasks import PAPER_TASK_PROFILES


def _mk_job(jid, gpus, iters=100.0, arrival=0.0):
    name = sorted(PAPER_TASK_PROFILES)[jid % len(PAPER_TASK_PROFILES)]
    prof = PAPER_TASK_PROFILES[name]
    return Job(jid=jid, model=name, arrival=arrival, gpus=gpus,
               iters=iters, batch=prof.default_batch,
               perf=prof.perf_params(gpus, GPU_2080TI))


def _run(jobs):
    cluster = ClusterState(n_servers=4, gpus_per_server=4,
                           gpu_capacity_bytes=11 * 2 ** 30)
    sim = Simulator(cluster, jobs, make_scheduler("sjf"),
                    interference=paper_interference_model())
    return sim.run()


def test_empty_job_list():
    res = _run([])
    assert res.makespan == 0.0
    assert res.events == 0
    assert res.avg_jct() == 0.0
    assert res.avg_jct(True) == 0.0
    assert res.avg_jct(False) == 0.0
    assert res.avg_queueing() == 0.0
    assert res.jct_list() == []
    assert all(v == 0.0 for v in res.summary().values())


def test_empty_results_container_directly():
    res = SimResults(jobs=[], makespan=0.0, events=0, name="x")
    assert res.avg_jct() == 0.0
    assert res.avg_queueing() == 0.0
    assert res.summary()["avg_jct_large"] == 0.0


def test_single_job():
    res = _run([_mk_job(0, gpus=2)])
    assert len(res.jobs) == 1
    job = res.jobs[0]
    assert job.finish_time is not None
    assert res.avg_jct() == pytest.approx(job.jct())
    assert res.makespan == pytest.approx(job.finish_time)
    # a lone job on an empty cluster never queues
    assert res.avg_queueing() == 0.0
    # the 2-GPU job is "small" (paper split: large means > 4 GPUs)
    assert res.avg_jct(False) == pytest.approx(job.jct())
    assert res.avg_jct(True) == 0.0


def test_all_small_selection():
    """A trace with only <=4-GPU jobs: the large-side aggregates are
    silently 0.0, never an error — and vice versa."""
    res = _run([_mk_job(i, gpus=g, arrival=float(i))
                for i, g in enumerate((1, 2, 4, 4))])
    assert res.avg_jct(False) > 0.0
    assert res.avg_jct(True) == 0.0
    assert res.avg_queueing(True) == 0.0
    assert res.summary()["avg_jct_large"] == 0.0


def test_all_large_selection():
    res = _run([_mk_job(i, gpus=8, arrival=float(i)) for i in range(3)])
    assert res.avg_jct(True) > 0.0
    assert res.avg_jct(False) == 0.0
    assert res.avg_queueing(False) == 0.0
    assert res.summary()["avg_jct_small"] == 0.0


def test_selection_is_strictly_greater_than_4_gpus():
    """Pin the split boundary itself: 4 GPUs is small, 8 is large."""
    res = _run([_mk_job(0, gpus=4), _mk_job(1, gpus=8, arrival=1.0)])
    four, eight = sorted(res.jobs, key=lambda j: j.gpus)
    assert res.avg_jct(False) == pytest.approx(four.jct())
    assert res.avg_jct(True) == pytest.approx(eight.jct())
