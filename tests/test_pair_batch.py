"""Batched-vs-scalar equivalence for the vectorized sharing-decision
core: ``repro.core.pair_batch`` must reproduce the scalar Algorithm-2
reference (``best_sharing_config``) decision-for-decision — share flag,
chosen sub-batch, accumulation count, and pair-average JCT — across xi
regimes (global override, two-way table, one-way table, structural
fallback), non-power-of-two batches, and infeasible pairs."""
import math
import random

import pytest

from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core.batch_scaling import best_sharing_config
from repro.core.interference import InterferenceModel
from repro.core.job import Job
from repro.core.pair_batch import (DonorBatch, best_sharing_config_batched,
                                   best_sharing_configs,
                                   job_candidate_table)
from repro.core.perf_model import PerfParams

GB = 2 ** 30
TOL = 1e-9


def mk_job(jid, model="a", batch=32, iters=1000.0, mem_base=2 * GB,
           mem_per_sample=0.2 * GB, alpha=2e-3, beta=5e-3):
    perf = PerfParams(alpha_comp=alpha, beta_comp=beta, alpha_comm=1e-4,
                      beta_comm=8e-10, msg_bytes=4e8, mem_base=mem_base,
                      mem_per_sample=mem_per_sample)
    return Job(jid=jid, model=model, arrival=0.0, gpus=4, iters=iters,
               batch=batch, perf=perf)


def _rand_job(rng, jid, model):
    job = mk_job(
        jid, model=model,
        batch=rng.choice([1, 3, 5, 6, 7, 16, 32, 48, 100]),
        iters=rng.uniform(10.0, 5000.0),
        mem_base=rng.uniform(0.5, 8.0) * GB,
        mem_per_sample=rng.uniform(0.01, 0.4) * GB,
        alpha=rng.uniform(1e-4, 5e-3), beta=rng.uniform(1e-4, 1e-2))
    return job


def _rand_interference(rng, regime, run_model, new_model):
    m = InterferenceModel()
    if regime == "global":
        m.global_xi = rng.uniform(1.0, 5.0)
    elif regime == "two-way":
        m.set_pair(run_model, new_model,
                   rng.uniform(1.0, 4.0), rng.uniform(1.0, 4.0))
    elif regime == "one-way":
        m.table[(run_model, new_model)] = (rng.uniform(1.0, 4.0),
                                           rng.uniform(1.0, 4.0))
    return m   # "structural": empty table


def _assert_config_equal(a, b):
    assert a.share == b.share
    assert a.sub_batch == b.sub_batch
    assert a.accum_steps == b.accum_steps
    if math.isinf(a.avg_jct):
        assert math.isinf(b.avg_jct)
        assert a.decision is None and b.decision is None
        return
    assert b.avg_jct == pytest.approx(a.avg_jct, rel=TOL, abs=TOL)
    assert b.xi_run == pytest.approx(a.xi_run, rel=TOL)
    assert b.xi_new == pytest.approx(a.xi_new, rel=TOL)
    assert b.decision.kappa == pytest.approx(a.decision.kappa,
                                             rel=TOL, abs=TOL)
    assert b.decision.jct_a == pytest.approx(a.decision.jct_a, rel=TOL)
    assert b.decision.jct_b == pytest.approx(a.decision.jct_b, rel=TOL)


@pytest.mark.parametrize("regime",
                         ["global", "two-way", "one-way", "structural"])
def test_single_donor_matches_scalar_randomized(regime):
    rng = random.Random(hash(regime) & 0xFFFF)
    for _ in range(150):
        run = _rand_job(rng, 0, rng.choice("ab"))
        run.sub_batch = rng.choice([run.batch, max(1, run.batch // 2)])
        run.iters_done = rng.uniform(0.0, run.iters)
        new = _rand_job(rng, 1, rng.choice("ab"))
        interf = _rand_interference(rng, regime, run.model, new.model)
        cap = rng.uniform(6.0, 24.0) * GB
        scalar = best_sharing_config(run, new, interf, cap)
        batched = best_sharing_config_batched(run, new, interf, cap)
        _assert_config_equal(scalar, batched)


def test_multi_donor_mixed_regimes_match_scalar():
    """One DonorBatch mixing fixed-xi donors (which take the scalar
    first-feasible shortcut) with structural donors (full grid argmin)."""
    rng = random.Random(42)
    new = _rand_job(rng, 99, "x")
    interf = InterferenceModel()
    interf.set_pair("fixed", "x", 1.3, 1.2)          # two-way: fixed donor
    interf.table[("oneway", "x")] = (1.8, 1.8)       # one-way hit
    donors = []
    for i, model in enumerate(["fixed", "oneway", "structural", "fixed",
                               "structural", "oneway"]):
        d = _rand_job(rng, i, model)
        d.sub_batch = d.batch
        d.iters_done = rng.uniform(0.0, d.iters)
        donors.append(d)
    cap = 16 * GB
    res = best_sharing_configs(new, DonorBatch(donors), interf, cap)
    assert len(res.donors) == len(donors)
    for i, donor in enumerate(donors):
        _assert_config_equal(
            best_sharing_config(donor, new, interf, cap), res.config(i))


def test_infeasible_pair_matches_scalar_sentinel():
    run = mk_job(0, mem_base=8 * GB)
    run.sub_batch = run.batch
    new = mk_job(1, mem_base=8 * GB)
    interf = InterferenceModel(global_xi=1.1)
    cfg = best_sharing_config_batched(run, new, interf, 11 * GB)
    assert not cfg.share
    assert cfg.decision is None
    assert math.isinf(cfg.avg_jct)
    assert cfg.sub_batch == new.batch and cfg.accum_steps == 1


def test_empty_donor_batch():
    new = mk_job(1)
    res = best_sharing_configs(new, [], InterferenceModel(), 11 * GB)
    assert len(res.donors) == 0
    assert res.share.shape == (0,)


def test_candidate_table_cached_on_job():
    job = mk_job(0, batch=48)
    bs, ss, t, mem = job_candidate_table(job)
    assert job_candidate_table(job) is job._pair_table
    assert list(bs) == [48, 24, 12, 6, 3, 2, 1]
    # s = ceil(B / b), never round — the effective batch is preserved
    assert all(s == math.ceil(48 / b) for b, s in zip(bs, ss))
    assert all(tv > 0 for tv in t)
    assert mem[0] > mem[-1]   # memory shrinks with the sub-batch


pos_t = st.floats(1e-4, 1e-2)
iters = st.floats(1.0, 5000.0)
xi = st.floats(1.0, 6.0)
batches = st.sampled_from([1, 3, 6, 7, 16, 32, 100])
mem_gb = st.floats(0.5, 9.0)


@given(batches, batches, iters, iters, xi, xi, mem_gb, mem_gb)
@settings(max_examples=200, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_batched_equals_scalar(batch_r, batch_n, iters_r, iters_n,
                                        xi_r, xi_n, mem_r, mem_n):
    run = mk_job(0, batch=batch_r, iters=iters_r, mem_base=mem_r * GB)
    run.sub_batch = batch_r
    new = mk_job(1, batch=batch_n, iters=iters_n, mem_base=mem_n * GB)
    interf = InterferenceModel()
    interf.set_pair("a", "a", xi_r, xi_n)
    scalar = best_sharing_config(run, new, interf, 11 * GB)
    batched = best_sharing_config_batched(run, new, interf, 11 * GB)
    _assert_config_equal(scalar, batched)
