"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp oracles in ``repro.kernels.ref``, plus consistency
of the model's jnp paths (chunked attention / ssd_chunked) with the same
oracles — kernel, model path and oracle must all agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba2_scan import ssd_fwd
from repro.kernels.ref import attention_ref, ssd_ref
from repro.models.attention import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 128, 64), (2, 3, 256, 64), (1, 2, 512, 128), (2, 1, 384, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, s, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = [jax.random.normal(kk, (b, h, s, d), dtype) for kk in ks]
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=128,
                              block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = [jax.random.normal(kk, (2, 2, 256, 64)) for kk in ks]
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = [jax.random.normal(kk, (1, 2, 256, 64)) for kk in ks]
    out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_model_chunked_attention_matches_ref():
    """The model's jnp flash path (used for long sequences under jit)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, d = 2, 512, 2, 64
    q, k, v = [jax.random.normal(kk, (b, s, h, d)) for kk in ks]
    out = chunked_attention(q, k, v, causal=True, q_chunk=128, kv_chunk=128)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), atol=2e-4, rtol=2e-4)
    full = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------- #
# mamba2 ssd
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 1, 16, 8, 64), (2, 512, 3, 32, 16, 128),
    (1, 256, 2, 64, 64, 256), (2, 384, 2, 32, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n), dtype)
    Cm = jax.random.normal(ks[4], (b, s, n), dtype)
    out = ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-3, rtol=2e-2)


def test_model_ssd_chunked_matches_ref():
    """The model's jnp chunked path vs the sequential oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, s, h, p, n = 2, 256, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    out = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_kernel_state_continuity():
    """Chunk boundaries must be seamless: one long kernel call == the
    oracle on a sequence spanning many chunks."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, h, p, n = 1, 1024, 1, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    out = ssd_fwd(x, dt, A, Bm, Cm, chunk=128, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
