"""Sharding rules + launch plumbing tests: spec sanitization properties
(hypothesis), param-spec path rules, HLO stat parsers on synthetic HLO,
and an in-process single-device lowering of the full dry-run path."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_flops import hlo_flops_bytes
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_smoke_mesh
from repro.launch.specs import sanitize_spec
from repro.sharding.rules import make_rules, param_specs


# ---------------------------------------------------------------------- #
# sanitize_spec
# ---------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096))
def test_sanitize_always_divisible(d0, d1):
    mesh = make_smoke_mesh(1)  # (1,1) mesh — everything divisible
    spec = sanitize_spec(mesh, (d0, d1), P("data", "model"))
    for dim, axes in zip((d0, d1), spec):
        if axes is not None:
            tup = axes if isinstance(axes, tuple) else (axes,)
            prod = 1
            for a in tup:
                prod *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
            assert dim % prod == 0


def test_sanitize_drops_odd_vocab():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    spec = sanitize_spec(mesh, (51865, 384), P("model", "data"))
    assert spec[0] is None          # 51865 % 4 != 0 -> replicated
    assert spec[1] == "data"        # 384 % 4 == 0 -> kept


# ---------------------------------------------------------------------- #
# param path rules
# ---------------------------------------------------------------------- #
def test_param_spec_rules():
    """ndim arguments are the REAL stacked ranks: +1 for units, +1 more
    for the inner per-unit stack (hybrid/ssm)."""
    mesh = make_smoke_mesh(1)
    rules = make_rules(mesh)
    assert rules.param_spec("embed/table", 2) == P("model", "data")
    assert rules.param_spec("units/sub0/attn/wq/w", 3) == \
        P(None, "data", "model")            # (U, d, H*hd)
    assert rules.param_spec("units/sub0/attn/wo/w", 3) == \
        P(None, "model", "data")            # (U, H*hd, d)
    assert rules.param_spec("units/sub0/ffn/experts/gate", 4) == \
        P(None, "model", "data", None)      # (U, E, d, f)
    assert rules.param_spec("ln_f/scale", 1) == P(None)
    # double-stacked mamba params: (U, u_inner, ...) -> two leading Nones
    assert rules.param_spec("units/mamba/mamba/in_proj/w", 4) == \
        P(None, None, "data", "model")
    assert rules.param_spec("units/mamba/mamba/A_log", 3) == \
        P(None, None, "model")


# ---------------------------------------------------------------------- #
# HLO parsers
# ---------------------------------------------------------------------- #
SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ag = f32[128,512]{1,0} all-gather(%x), replica_groups={}, dimensions={1}
  %w = f32[512,256]{1,0} constant({...})
  %y = f32[128,256]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(%zero, %a)
  %loop = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
  %out = f32[128,256]{1,0} get-tuple-element(%loop), index=1
  %ar = f32[128,256]{1,0} all-reduce(%out), to_apply=%add
  ROOT %r = f32[128,256]{1,0} copy(%ar)
}
"""


def test_collective_stats_trip_counts():
    stats = collective_stats(SYNTH_HLO)
    # all-gather inside the 10-trip loop: 128*512*4 bytes * 10
    assert stats["all-gather"] == 128 * 512 * 4 * 10
    assert stats["all-reduce"] == 128 * 256 * 4
    assert stats["total"] == stats["all-gather"] + stats["all-reduce"]


def test_hlo_flops_trip_counts():
    r = hlo_flops_bytes(SYNTH_HLO)
    # dot: 2 * (128*256) * 512 per trip, 10 trips
    assert r["flops"] == 2 * 128 * 256 * 512 * 10


# ---------------------------------------------------------------------- #
# end-to-end lowering on this process's devices (1 CPU device)
# ---------------------------------------------------------------------- #
def test_dryrun_path_single_device():
    """The full build->lower->compile pipeline on a (1,1) mesh with a
    reduced config exercises specs/rules/hooks without the 512-device
    subprocess."""
    import dataclasses
    from repro.configs import get_config
    from repro.configs.shapes import InputShape, input_specs
    from repro.launch import specs as S
    from repro.sharding.hooks import activation_rules
    from repro.train import TrainConfig, make_train_step

    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced())
    shape = InputShape("tiny_train", seq_len=64, global_batch=4,
                       kind="train")
    mesh = make_smoke_mesh(1)
    rules = make_rules(mesh)
    sds = input_specs(cfg, shape)
    p_shape = S.params_shape(cfg)
    o_shape = S.opt_shape(cfg, p_shape)
    step = make_train_step(cfg, TrainConfig(accum_steps=2))
    with activation_rules(rules.activation_table(), mesh):
        lowered = jax.jit(
            step,
            in_shardings=(S.param_shardings(rules, p_shape),
                          S.opt_shardings(rules, o_shape, p_shape),
                          S.batch_shardings(rules, sds)),
        ).lower(p_shape, o_shape, sds)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    r = hlo_flops_bytes(compiled.as_text())
    assert r["flops"] > 0
