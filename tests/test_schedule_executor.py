"""Schedule-driven executor (DESIGN.md §13): N-way fused group steps are
bit-identical to solo training, mid-run (τ, sub-batch) reconfiguration
carries state bit-exactly and preserves the effective batch, plan
execution attributes group walltime to every running member, the
simulator-log replay reproduces the schedule structure, and the
calibration artifact round-trips into the simulator."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ClusterState, InterferenceModel, Job, PerfParams,
                        Simulator)
from repro.core.calibration import (CALIBRATION_VERSION, load_artifact,
                                    perf_params_from_artifact,
                                    profiles_from_artifact, run_calibration,
                                    save_artifact)
from repro.core.schedulers import SJF_BSBF
from repro.launch.cluster import (JobSpec, PlanOp, PlanPhase, SchedulePlan,
                                  ScheduleExecutor, _make_state,
                                  accum_for_sub_batch, plan_from_sim)
from repro.train import TrainConfig, make_jit_train_step


def _spec(name, batch=2, seq=32, **kw):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    return JobSpec(cfg, batch=batch, seq=seq, **kw)


# ====================================================================== #
# N-way fused group program
# ====================================================================== #
class TestGroupStep:
    def test_three_way_group_bit_identical_to_solo(self):
        specs = [_spec("minicpm-2b"), _spec("minicpm-2b", seed=3),
                 _spec("qwen2-vl-2b", accum_steps=2)]
        ex = ScheduleExecutor(donate=True)
        for i, s in enumerate(specs):
            ex.submit(f"j{i}", s, 2)
            ex.start(f"j{i}")
        for _ in range(2):
            r = ex.step_group(["j0", "j1", "j2"])
            assert all(np.isfinite(v) for v in r["losses"].values())
        for i, s in enumerate(specs):
            solo = ScheduleExecutor(donate=True)
            solo.submit("x", s, 2)
            solo.start("x")
            solo.step_group(["x"])
            solo.step_group(["x"])
            got = jax.tree.leaves(ex.runs[f"j{i}"].params)
            want = jax.tree.leaves(solo.runs["x"].params)
            for a, b in zip(got, want):
                assert (np.asarray(a) == np.asarray(b)).all(), f"job {i}"
            assert (ex.runs[f"j{i}"].last_metrics["loss"]
                    == solo.runs["x"].last_metrics["loss"])

    def test_program_cache_reuse(self):
        ex = ScheduleExecutor(donate=True)
        for i in range(2):
            ex.submit(f"j{i}", _spec("minicpm-2b", seed=i), 4)
            ex.start(f"j{i}")
        for _ in range(3):
            ex.step_group(["j0", "j1"])
        assert ex.compiles == 1 and ex.calls == 3
        ex.step_group(["j0"])     # new composition -> one more program
        ex.step_group(["j0"])
        assert ex.compiles == 2 and ex.calls == 5


# ====================================================================== #
# Mid-run (τ, sub-batch) reconfiguration
# ====================================================================== #
class TestReconfigure:
    def test_reconfig_carries_state_bit_exactly(self):
        """Executor run with a mid-run accumulation change equals the
        manual composition of jitted train steps at those configs."""
        spec = _spec("minicpm-2b", batch=4)
        ex = ScheduleExecutor(donate=True)
        ex.submit("j", spec, 4)
        ex.start("j")
        ex.step_group(["j"])
        ex.step_group(["j"])
        ex.reconfigure("j", 2)           # b: 4 -> 2, s: 1 -> 2, at τ
        assert ex.runs["j"].accum_steps == 2
        ex.step_group(["j"])
        ex.step_group(["j"])

        cfg = spec.cfg
        p, o, b = _make_state(spec)
        s1 = make_jit_train_step(cfg, TrainConfig(accum_steps=1))
        s2 = make_jit_train_step(cfg, TrainConfig(accum_steps=2))
        for _ in range(2):
            p, o, _ = s1(p, o, b)
        for _ in range(2):
            p, o, _ = s2(p, o, b)
        for a, w in zip(jax.tree.leaves(ex.runs["j"].params),
                        jax.tree.leaves(p)):
            assert (np.asarray(a) == np.asarray(w)).all()

    def test_reconfig_preserves_effective_batch(self):
        """Training THROUGH a reconfiguration matches an uninterrupted
        full-batch run within the grad-accum equivalence tolerance (the
        effective batch never changes)."""
        spec = _spec("minicpm-2b", batch=4)
        ex = ScheduleExecutor(donate=True)
        ex.submit("j", spec, 3)
        ex.start("j")
        ex.step_group(["j"])
        ex.reconfigure("j", 2)
        ex.step_group(["j"])
        ex.step_group(["j"])

        full = ScheduleExecutor(donate=True)
        full.submit("j", spec, 3)
        full.start("j")
        for _ in range(3):
            full.step_group(["j"])
        for a, w in zip(jax.tree.leaves(ex.runs["j"].params),
                        jax.tree.leaves(full.runs["j"].params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       rtol=2e-3, atol=2e-5)

    def test_ragged_sub_batch_reconfig(self):
        """A non-divisor sub-batch reconfigures onto the masked ragged
        path (PR 3) and still matches the manual jitted composition."""
        spec = _spec("minicpm-2b", batch=3)
        ex = ScheduleExecutor(donate=True)
        ex.submit("j", spec, 2)
        ex.start("j")
        ex.step_group(["j"])
        ex.reconfigure("j", 2)           # s = ceil(3/2) = 2, micros (2, 1)
        assert ex.runs["j"].accum_steps == 2
        ex.step_group(["j"])

        cfg = spec.cfg
        p, o, b = _make_state(spec)
        s1 = make_jit_train_step(cfg, TrainConfig(accum_steps=1))
        s2 = make_jit_train_step(cfg, TrainConfig(accum_steps=2))
        p, o, _ = s1(p, o, b)
        p, o, _ = s2(p, o, b)
        for a, w in zip(jax.tree.leaves(ex.runs["j"].params),
                        jax.tree.leaves(p)):
            assert (np.asarray(a) == np.asarray(w)).all()

    def test_accum_for_sub_batch(self):
        assert accum_for_sub_batch(8, 8) == 1
        assert accum_for_sub_batch(8, 4) == 2
        assert accum_for_sub_batch(5, 3) == 2
        assert accum_for_sub_batch(4, 99) == 1   # clamped to the batch
        with pytest.raises(ValueError):
            accum_for_sub_batch(4, 0)


# ====================================================================== #
# Plan execution
# ====================================================================== #
class TestExecutePlan:
    def test_walltime_attributed_to_idle_group_members(self):
        """A running group member with a zero step quota still pays the
        phase's walltime — its GPU is busy with the co-tenant."""
        ex = ScheduleExecutor(donate=True)
        ex.submit("a", _spec("minicpm-2b"), 2)
        ex.submit("b", _spec("minicpm-2b", seed=1), 1)
        phases = [
            PlanPhase(ops=(PlanOp("start", "a"), PlanOp("start", "b")),
                      quotas=(("a", 2), ("b", 0)),
                      groups=(("a", "b"),)),
            PlanPhase(ops=(PlanOp("finish", "a"),),
                      quotas=(("b", 1),),
                      groups=(("b",),)),
            PlanPhase(ops=(PlanOp("finish", "b"),), quotas=(), groups=()),
        ]
        report = ex.execute(phases)
        assert report["a"]["steps"] == 2 and report["b"]["steps"] == 1
        # b idled through phase 0 (a's 2 steps) and then ran phase 1
        assert report["b"]["walltime"] > report["a"]["walltime"] > 0

    def test_finish_rejects_incomplete_job(self):
        ex = ScheduleExecutor(donate=True)
        ex.submit("a", _spec("minicpm-2b"), 3)
        phases = [
            PlanPhase(ops=(PlanOp("start", "a"),), quotas=(("a", 1),),
                      groups=(("a",),)),
            PlanPhase(ops=(PlanOp("finish", "a"),), quotas=(), groups=()),
        ]
        with pytest.raises(RuntimeError, match="finished at 1/3"):
            ex.execute(phases)

    def test_predictions_joined_into_report(self):
        ex = ScheduleExecutor(donate=True)
        ex.submit("a", _spec("minicpm-2b"), 1)
        plan = SchedulePlan(
            phases=[
                PlanPhase(ops=(PlanOp("start", "a"),),
                          quotas=(("a", 1),), groups=(("a",),)),
                PlanPhase(ops=(PlanOp("finish", "a"),), quotas=(),
                          groups=()),
            ],
            predicted={"a": {"exec_seconds": 1000.0, "jct": 1000.0}})
        report = ex.execute(plan)
        assert report["a"]["predicted_exec"] == 1000.0
        assert report["a"]["measured_exec"] == report["a"]["walltime"]
        assert report["a"]["error"] == pytest.approx(
            (report["a"]["walltime"] - 1000.0) / 1000.0)


# ====================================================================== #
# Simulator-log replay (no jax on this path: synthetic PerfParams)
# ====================================================================== #
GB = 2 ** 30


def _perf(alpha=0.01, beta=0.01):
    return PerfParams(alpha_comp=alpha, beta_comp=beta, alpha_comm=0.0,
                      beta_comm=0.0, msg_bytes=0.0, delta=2.0,
                      mem_base=4.0 * GB, mem_per_sample=0.25 * GB,
                      param_bytes=1e8, n_workers=1)


def _scenario(iters_a=30):
    """The replay-harness shape: donor A on both GPUs, short sharers B/C
    (3-way group; B's admission needs the donor-rescaling extension),
    late D queues behind the doubly-tenanted GPUs."""
    pa, pb = _perf(), _perf(beta=0.008)
    t_a = pa.t_iter(4)
    jobs = [
        Job(jid=0, model="m0", arrival=0.0, gpus=2, iters=float(iters_a),
            batch=4, perf=pa),
        Job(jid=1, model="m1", arrival=2 * t_a, gpus=1, iters=3.0,
            batch=4, perf=pb),
        Job(jid=2, model="m1", arrival=4 * t_a, gpus=1, iters=4.0,
            batch=4, perf=pb),
        Job(jid=3, model="m0", arrival=6 * t_a, gpus=1, iters=3.0,
            batch=4, perf=pa),
    ]
    # A@2 + sharer@2 fits; A@4 + sharer@1 does not
    cap = pa.mem_bytes(2) + pb.mem_bytes(2) + 0.25 * 0.25 * GB
    interf = InterferenceModel()
    for a in ("m0", "m1"):
        for b in ("m0", "m1"):
            interf.set_pair(a, b, 1.3, 1.3)
    return jobs, cap, interf


def _run_scenario(engine="heap"):
    jobs, cap, interf = _scenario()
    cluster = ClusterState(n_servers=1, gpus_per_server=2,
                           gpu_capacity_bytes=cap)
    sim = Simulator(cluster, jobs, SJF_BSBF(donor_reconfig=True),
                    interference=interf, reconfig_on_release=True,
                    engine=engine)
    res = sim.run()
    return sim, res


class TestPlanFromSim:
    def test_schedule_structure(self):
        sim, res = _run_scenario()
        log = sim.log
        # B's admission reconfigured the donor mid-run; the restore fired
        # when A's last sharer departed
        reconfigs = [e for e in log if e[1] == "reconfig"]
        assert len(reconfigs) >= 2
        assert any(e[2] == 0 and e[3] == 2 for e in reconfigs), \
            "donor A must shrink to sub-batch 2 at the sharing point"
        assert any(e[2] == 0 and e[3] == 4 for e in reconfigs), \
            "donor A must restore to its full sub-batch"
        # every start carries a config entry
        starts = [e for e in log if e[1] == "start"]
        configs = [e for e in log if e[1] == "config"]
        assert len(starts) == len(configs) == 4

    def test_plan_quotas_and_groups(self):
        sim, res = _run_scenario()
        plan = plan_from_sim(sim.log, sim.jobs, sim.interference,
                             sim.cluster.gpu_capacity_bytes,
                             names={0: "A", 1: "B", 2: "C", 3: "D"})
        totals = {}
        for phase in plan.phases:
            for name, q in phase.quotas:
                assert q >= 0
                totals[name] = totals.get(name, 0) + q
        assert totals == {"A": 30, "B": 3, "C": 4, "D": 3}
        assert max(len(g) for p in plan.phases for g in p.groups
                   if p.groups) == 3, "expected a 3-way sharing group"
        kinds = [(op.kind, op.job) for p in plan.phases for op in p.ops]
        assert kinds.count(("finish", "A")) == 1
        assert ("reconfig", "A") in kinds
        assert ("start", "B") in kinds
        # predicted execution times come from the simulated timeline
        for name, jid in (("A", 0), ("B", 1), ("C", 2), ("D", 3)):
            job = sim.jobs[jid]
            assert plan.predicted[name]["exec_seconds"] == pytest.approx(
                job.finish_time - job.start_time)

    def test_engines_agree_on_reconfig_schedule(self):
        """The scan and heap engines produce the same schedule under the
        donor-rescaling + restore-on-release extensions."""
        sim_h, res_h = _run_scenario("heap")
        sim_s, res_s = _run_scenario("scan")
        for jh, js in zip(sorted(res_h.jobs, key=lambda j: j.jid),
                          sorted(res_s.jobs, key=lambda j: j.jid)):
            assert jh.finish_time == pytest.approx(js.finish_time, rel=1e-6)
            assert jh.sub_batch == js.sub_batch
        assert ([e for e in sim_h.log if e[1] == "reconfig"]
                == pytest.approx([e for e in sim_s.log
                                  if e[1] == "reconfig"]))

    def test_default_flags_emit_no_reconfig(self):
        """Without the opt-in flags the schedule carries no reconfig
        events (seed semantics)."""
        jobs, cap, interf = _scenario()
        cluster = ClusterState(n_servers=1, gpus_per_server=2,
                               gpu_capacity_bytes=cap)
        sim = Simulator(cluster, jobs, SJF_BSBF(), interference=interf)
        sim.run()
        assert not [e for e in sim.log if e[1] == "reconfig"]


# ====================================================================== #
# Calibration artifact
# ====================================================================== #
def _fake_payload():
    return {
        "version": CALIBRATION_VERSION,
        "host": {"backend": "cpu", "device_count": 1},
        "iters": 2,
        "archs": {
            "m0": {"arch": "minicpm-2b", "batch": 4, "seq": 32,
                   "accum_steps": 1,
                   "sweep": {"sub_batches": [4, 2, 1],
                             "times": [0.05, 0.03, 0.02]},
                   "alpha_comp": 0.01, "beta_comp": 0.01,
                   "t_iter_solo": 0.05, "n_params": 1000,
                   "param_bytes": 4000.0, "mem_base": 1e9,
                   "mem_per_sample": 1e8},
        },
        "pairs": {
            "m0+m0": {"a": "m0", "b": "m0", "t_a_solo": 0.05,
                      "t_b_solo": 0.05, "t_pair": 0.09,
                      "xi_a": 1.8, "xi_b": 1.8,
                      "xi_a_structural": 2.0, "xi_b_structural": 2.0},
        },
    }


class TestCalibrationArtifact:
    def test_roundtrip_and_version_check(self, tmp_path):
        path = str(tmp_path / "calibration.json")
        save_artifact(_fake_payload(), path)
        payload = load_artifact(path)
        assert payload["archs"]["m0"]["alpha_comp"] == 0.01
        bad = _fake_payload()
        bad["version"] = 99
        with pytest.raises(ValueError, match="version"):
            save_artifact(bad, path)
        save_artifact(_fake_payload(), path)
        import json
        with open(path) as f:
            raw = json.load(f)
        raw["version"] = 99
        with open(path, "w") as f:
            json.dump(raw, f)
        with pytest.raises(ValueError, match="version"):
            load_artifact(path)

    def test_interference_from_artifact(self, tmp_path):
        model = InterferenceModel.from_artifact(_fake_payload())
        assert model.xi("m0", "m0") == 1.8
        path = str(tmp_path / "calibration.json")
        save_artifact(_fake_payload(), path)
        assert InterferenceModel.from_artifact(path).xi("m0", "m0") == 1.8
        with pytest.raises(FileNotFoundError):
            InterferenceModel.from_artifact(str(tmp_path / "nope.json"))

    def test_perf_params_and_profiles(self):
        payload = _fake_payload()
        p = perf_params_from_artifact(payload["archs"]["m0"])
        # single host: no explicit comm term; Eq. 7 reduces to s*t_comp
        assert p.t_comm() == 0.0
        assert p.t_iter(4) == pytest.approx(0.01 + 0.01 * 4)
        assert p.t_iter_sub(4, 2) == pytest.approx(2 * (0.01 + 0.01 * 2))
        profs = profiles_from_artifact(payload)
        assert profs["m0"].default_batch == 4
        # measured profiles ignore the requested GPU count/hardware
        assert profs["m0"].perf_params(8) is profs["m0"].perf_params(1)

    def test_run_calibration_measures_once_per_model(self, monkeypatch):
        """The pipeline initializes each model once and threads pristine
        state copies into the measurements (no O(n) extra re-inits)."""
        import repro.core.coschedule as cos
        import repro.launch.cluster as cluster_mod

        made = []
        real_make_state = cluster_mod._make_state

        def counting_make_state(spec):
            made.append(spec.cfg.name)
            return real_make_state(spec)

        monkeypatch.setattr(cluster_mod, "_make_state", counting_make_state)
        solo_calls, pair_calls = [], []
        monkeypatch.setattr(
            cos, "measure_solo",
            lambda spec, iters=3, state=None:
                solo_calls.append((spec.batch, state is not None)) or 0.05)

        def fake_pair(a, b, iters=3, *, t_a_solo=None, t_b_solo=None,
                      state_a=None, state_b=None):
            pair_calls.append((t_a_solo, t_b_solo,
                               state_a is not None, state_b is not None))
            return {"t_a_solo": t_a_solo, "t_b_solo": t_b_solo,
                    "t_pair": 0.09, "xi_a": 1.8, "xi_b": 1.8, "iters": iters}

        monkeypatch.setattr(cos, "measure_pair", fake_pair)
        specs = {"m": _spec("minicpm-2b", batch=4)}
        payload = run_calibration(specs, iters=1)
        assert made == ["minicpm-2b-reduced"], "one init per model"
        # every measurement consumes prebuilt state (master copies; the
        # sweep points only rebuild the data tensor at batch b), and the
        # spec's own solo timing reuses the sweep's full-batch point
        assert [c[0] for c in solo_calls] == [4, 2, 1]
        assert all(prebuilt for _, prebuilt in solo_calls)
        assert pair_calls == [(0.05, 0.05, True, True)]
        assert payload["version"] == CALIBRATION_VERSION
        assert payload["archs"]["m"]["alpha_comp"] == pytest.approx(0.05)
        assert payload["pairs"]["m+m"]["xi_a"] == 1.8


# ====================================================================== #
# Pair-shaped facade keeps its state-reuse contract
# ====================================================================== #
class TestMeasureStateReuse:
    def test_measure_solo_skips_init_with_prebuilt_state(self, monkeypatch):
        import repro.core.coschedule as cos
        import repro.launch.cluster as cluster_mod

        spec = _spec("minicpm-2b")
        state = _make_state(spec)

        def boom(_):
            raise AssertionError("_make_state must not run")

        monkeypatch.setattr(cluster_mod, "_make_state", boom)
        t = cos.measure_solo(spec, iters=1, state=state)
        assert t > 0

    def test_measure_pair_accepts_prebuilt_states(self, monkeypatch):
        import repro.core.coschedule as cos
        import repro.launch.cluster as cluster_mod

        spec = _spec("minicpm-2b")
        sa, sb = _make_state(spec), _make_state(spec)

        def boom(_):
            raise AssertionError("_make_state must not run")

        monkeypatch.setattr(cluster_mod, "_make_state", boom)
        r = cos.measure_pair(spec, spec, iters=1, t_a_solo=0.5,
                             t_b_solo=0.5, state_a=sa, state_b=sb)
        assert r["t_pair"] > 0 and r["xi_a"] == pytest.approx(
            r["t_pair"] / 0.5)
