"""The paper's experiment in one script: schedule a multi-tenant DL job
trace with SJF-BSBF and compare it against FIFO/SJF/Tiresias/Pollux-like/
SJF-FFS on average JCT and queueing delay.

    PYTHONPATH=src python examples/cluster_scheduling.py [--jobs 120]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (ClusterState, Simulator, make_scheduler,
                        paper_interference_model, simulation_trace)

POLICIES = ("fifo", "sjf", "tiresias", "pollux", "sjf-ffs", "sjf-bsbf")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=120)
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--gpus-per-server", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"{args.jobs} jobs on {args.servers}x{args.gpus_per_server} GPUs")
    print(f"{'policy':<10} {'avg JCT':>10} {'avg queue':>10} "
          f"{'makespan':>10} {'preempt':>8}")
    base = None
    for policy in POLICIES:
        jobs = simulation_trace(n_jobs=args.jobs, seed=args.seed)
        cluster = ClusterState(n_servers=args.servers,
                               gpus_per_server=args.gpus_per_server,
                               gpu_capacity_bytes=11 * 2 ** 30)
        sim = Simulator(cluster, jobs, make_scheduler(policy),
                        interference=paper_interference_model())
        res = sim.run()
        s = res.summary()
        n_preempt = sum(j.preemptions for j in res.jobs)
        if policy == "fifo":
            base = s["avg_jct"]
        print(f"{policy:<10} {s['avg_jct']:>10.1f} {s['avg_queue']:>10.1f} "
              f"{s['makespan']:>10.1f} {n_preempt:>8d}"
              f"   ({(1 - s['avg_jct'] / base) * 100:+.1f}% vs FIFO)")


if __name__ == "__main__":
    main()
