"""Batched serving across cache families: generate tokens with a dense
(ring-buffer sliding window), an SSM (O(1) state) and an encoder-decoder
architecture through the fused scan engine (one-shot prefill + one
jitted dispatch per generation).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import init_params
import jax


def main():
    rng = np.random.default_rng(0)
    for name, kw in (("glm4-9b", dict(sliding_window=16)),
                     ("xlstm-1.3b", {}),
                     ("zamba2-7b", {}),
                     ("whisper-tiny", {})):
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32", **kw)
        params = init_params(cfg, jax.random.PRNGKey(0))
        b, plen, new = 4, 8, 12
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, plen)), jnp.int32)
        frames = None
        if cfg.is_encoder_decoder:
            frames = jnp.asarray(rng.standard_normal(
                (b, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32)
        t0 = time.perf_counter()
        toks = generate(cfg, params, prompt, max_new_tokens=new,
                        max_len=64, frames=frames)
        dt = time.perf_counter() - t0
        print(f"{name:<28} cache={cfg.family:<7} "
              f"generated {toks.shape[0]}x{toks.shape[1]} tokens "
              f"in {dt:5.1f}s ({b * new / dt:6.1f} tok/s)")


if __name__ == "__main__":
    main()
