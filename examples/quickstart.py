"""Quickstart (end-to-end driver): train a ~100M-param dense LM for a few
hundred steps on synthetic data with gradient accumulation — the paper's
convergence-preserving memory mechanism — and verify the loss goes down.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import time

import jax

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import init_params, param_count
from repro.train import (TrainConfig, adamw_init, make_jit_train_step,
                         wsd_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200,
                    help="a few hundred steps ~= 1-2 h on one CPU core; "
                         "use --steps 30 for a quick check")
    ap.add_argument("--accum-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: a shrunk MiniCPM (8 layers, d_model=768, 32k vocab)
    cfg = dataclasses.replace(
        get_config("minicpm-2b"), n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=2048, vocab=32768,
        dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name} shrunk to {param_count(params):,} params")

    sched = wsd_schedule(peak_lr=6e-4, warmup_steps=20,
                         stable_steps=int(args.steps * 0.7),
                         decay_steps=int(args.steps * 0.25))
    # donated params/opt-state (the loop below re-binds both each step)
    step = make_jit_train_step(
        cfg, TrainConfig(accum_steps=args.accum_steps, schedule=sched))
    opt = adamw_init(params)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq,
                       structured=True)

    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)

    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    print(f"loss: first-10 avg {first:.4f} -> last-10 avg {last:.4f}")
    assert last < first, "training did not reduce the loss"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
