"""GPU sharing, for real: co-schedule two training jobs on this host as
ONE fused JAX program (the TPU analogue of the paper's GPU sharing,
DESIGN.md §4), measure the structural interference ratios xi_A/xi_B, and
let Theorem 1 decide whether the pair should share or run sequentially.

The second job uses gradient accumulation (sub-batch b = B/s) — the
paper's mechanism for fitting two jobs into one device's memory without
changing convergence.

    PYTHONPATH=src python examples/shared_gpu_training.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

from repro.configs import get_config
from repro.core.coschedule import JobSpec, measure_pair
from repro.core.pair import PairJob, best_pair_schedule


def main():
    cfg_a = dataclasses.replace(get_config("minicpm-2b").reduced(),
                                dtype="float32")
    cfg_b = dataclasses.replace(get_config("qwen2-vl-2b").reduced(),
                                dtype="float32")
    # job B shrinks its per-step memory via gradient accumulation (s=4)
    spec_a = JobSpec(cfg_a, batch=8, seq=128, accum_steps=1, seed=0)
    spec_b = JobSpec(cfg_b, batch=8, seq=128, accum_steps=4, seed=1)

    print("measuring solo and interleaved step times (one fused program)…")
    r = measure_pair(spec_a, spec_b, iters=3)
    print(f"  t_A solo {r['t_a_solo']*1e3:7.1f} ms")
    print(f"  t_B solo {r['t_b_solo']*1e3:7.1f} ms (with grad accum s=4)")
    print(f"  t_pair   {r['t_pair']*1e3:7.1f} ms")
    print(f"  xi_A = {r['xi_a']:.2f}, xi_B = {r['xi_b']:.2f}")

    # Theorem 1: share or run sequentially? (A mid-flight, B arriving)
    a = PairJob(t_iter=r["t_a_solo"], iters=400, xi=r["xi_a"])
    b = PairJob(t_iter=r["t_b_solo"], iters=200, xi=r["xi_b"])
    dec = best_pair_schedule(a, b)
    mode = "SHARE now (kappa=0)" if dec.share else \
        f"run SEQUENTIALLY (kappa={dec.kappa:.1f}s)"
    print(f"Theorem 1 decision: {mode}; pair avg JCT {dec.avg_jct:.1f}s")
    seq_avg = 0.5 * (a.solo_time + (a.solo_time + b.solo_time))
    print(f"(sequential avg JCT would be {seq_avg:.1f}s)")


if __name__ == "__main__":
    main()
