"""Kernel autotuner: per-shape-class block/chunk selection with an XLA
fallback, persisted as a versioned artifact (DESIGN.md §15).

The Pallas kernels' tile sizes (``block_q``/``block_k`` for flash
attention, ``block_k`` for flash decode, ``chunk`` for the SSD scan) were
hard-coded; ``BENCH_kernels.json`` shows the kernels losing to the
compiled XLA reference at small shapes under those defaults.  This module
sweeps a candidate grid per *shape class* — (sequence-length bucket ×
head/state dim × dtype) — times every candidate against the XLA
reference path, and records the winner.  When the best Pallas candidate
still trails the reference, the entry records ``backend: "ref"`` and the
wrappers in :mod:`ops` route that shape class to the reference
implementation instead — the tuned-or-fallback choice is never slower
than the hard-coded default, because the default candidate is always in
the measured set.

The winners persist in ``artifacts/bench/autotune.json`` (versioned, like
PR 5's ``calibration.json``).  :mod:`ops` consults the table lazily at
trace time whenever a call site does not pass explicit block sizes, so
every kernel call site (train step, coschedule, serve) picks up tuned
choices with zero API change; with no artifact present the hard-coded
defaults apply unchanged.  Entries are honored only when the table was
tuned on the current jax backend — a CPU-tuned table never disables
kernels on TPU.

Environment override: ``REPRO_AUTOTUNE=/path/to/table.json`` points the
lazy load elsewhere; ``REPRO_AUTOTUNE=0`` (or ``off``) disables the table
entirely (the test suite does this for hermeticity).

Artifact schema (version 2 — version 1 lacked the ``flash_decode_paged``
kind, whose shape classes key on the exact page size rather than a
sequence bucket, so stale tables are invalidated)::

    {"version": 2, "created": ...,
     "meta": {"backend": "cpu"|"tpu", "interpret": bool, "smoke": bool,
              "iters": n},
     "entries": {"<kind>|s<bucket>|d<dim>|<dtype>":
                 {"backend": "kernel"|"ref", <block fields>,
                  "t_best": s, "t_ref": s, "t_default": s,
                  "speedup_vs_default": x}}}
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

from ..util.errors import ArtifactVersionError

AUTOTUNE_VERSION = 2
DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "bench", "autotune.json")

# the hard-coded choices the table replaces (and falls back to).  The
# paged decode kernel has no block knobs — tuning it is a pure
# kernel-vs-reference routing decision per (page_size, head_dim, dtype).
DEFAULTS = {
    "flash_attention": {"block_q": 128, "block_k": 128},
    "flash_decode": {"block_k": 128},
    "flash_decode_paged": {},
    "ssd": {"chunk": 256},
}


# ---------------------------------------------------------------------- #
# shape classes
# ---------------------------------------------------------------------- #
def seq_bucket(s: int) -> int:
    """Next power of two >= s, floored at 64 (one class per octave)."""
    b = 64
    while b < s:
        b *= 2
    return b


def shape_key(kind: str, s: int, d: int, dtype) -> str:
    import numpy as np
    name = np.dtype(dtype).name
    # paged decode keys on the exact page size: page sizes (8/16/32...)
    # sit below the 64-floor sequence bucket and would all collide
    b = int(s) if kind == "flash_decode_paged" else seq_bucket(int(s))
    return f"{kind}|s{b}|d{int(d)}|{name}"


# ---------------------------------------------------------------------- #
# artifact I/O
# ---------------------------------------------------------------------- #
def save_artifact(payload: Dict, path: Optional[str] = None) -> str:
    if payload.get("version") != AUTOTUNE_VERSION:
        raise ValueError(f"refusing to save autotune artifact with version "
                         f"{payload.get('version')!r}")
    path = path or DEFAULT_PATH
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_artifact(path: Optional[str] = None) -> Dict:
    path = path or DEFAULT_PATH
    with open(path) as f:
        payload = json.load(f)
    version = payload.get("version")
    if version != AUTOTUNE_VERSION:
        raise ArtifactVersionError(path, version, AUTOTUNE_VERSION,
                                   kind="autotune artifact",
                                   detail="re-run benchmarks/autotune.py "
                                          "to regenerate")
    return payload


class AutotuneTable:
    """In-memory view of the artifact, consulted by :mod:`ops`."""

    def __init__(self, payload: Dict):
        version = payload.get("version")
        if version != AUTOTUNE_VERSION:
            raise ArtifactVersionError("<payload>", version,
                                       AUTOTUNE_VERSION,
                                       kind="autotune artifact")
        for field in ("entries", "meta"):
            if field not in payload:
                raise ArtifactVersionError(
                    "<payload>", version, AUTOTUNE_VERSION,
                    kind="autotune artifact",
                    detail=f"schema missing {field!r}")
        if "backend" not in payload["meta"]:
            raise ArtifactVersionError(
                "<payload>", version, AUTOTUNE_VERSION,
                kind="autotune artifact",
                detail="schema missing meta['backend']")
        self.payload = payload
        self.entries: Dict[str, Dict] = payload["entries"]
        self.backend: str = payload["meta"]["backend"]

    def lookup(self, kind: str, s: int, d: int, dtype) -> Optional[Dict]:
        """Tuned entry for this shape class, or None (caller uses the
        hard-coded defaults).  Entries tuned on a different jax backend
        are ignored: the timings do not transfer."""
        import jax
        if self.backend != jax.default_backend():
            return None
        return self.entries.get(shape_key(kind, s, d, dtype))


# module-level table: lazily loaded from DEFAULT_PATH (or REPRO_AUTOTUNE)
# on first lookup; absent/stale artifacts fall back to None gracefully —
# serving must never fail because a tuning artifact is missing.
_UNSET = object()
_TABLE = _UNSET


def set_table(table: Optional[AutotuneTable]) -> None:
    """Install a table explicitly (None disables all tuned routing)."""
    global _TABLE
    _TABLE = table


def reset_table() -> None:
    """Forget the cached table; next lookup lazily re-reads the env/disk."""
    global _TABLE
    _TABLE = _UNSET


def get_table() -> Optional[AutotuneTable]:
    global _TABLE
    if _TABLE is _UNSET:
        env = os.environ.get("REPRO_AUTOTUNE")
        if env is not None and env.strip().lower() in ("", "0", "off"):
            _TABLE = None
        else:
            path = env or DEFAULT_PATH
            try:
                _TABLE = AutotuneTable(load_artifact(path))
            except (FileNotFoundError, ValueError, KeyError):
                _TABLE = None
    return _TABLE


def lookup(kind: str, s: int, d: int, dtype) -> Optional[Dict]:
    table = get_table()
    return None if table is None else table.lookup(kind, s, d, dtype)


# ---------------------------------------------------------------------- #
# sweep machinery
# ---------------------------------------------------------------------- #
# candidate grids; the DEFAULTS entry is always included so the chosen
# config is >= 1.0x the default by construction (same measurement set)
CANDIDATES = {
    "flash_attention": [(64, 64), (64, 128), (128, 64), (128, 128),
                        (128, 256), (256, 128), (256, 256)],
    "flash_decode": [32, 64, 128, 256],
    "flash_decode_paged": [None],       # no knobs: kernel-vs-ref only
    "ssd": [64, 128, 256],
}
SMOKE_CANDIDATES = {
    "flash_attention": [(64, 64), (128, 128)],
    "flash_decode": [64, 128],
    "flash_decode_paged": [None],
    "ssd": [128, 256],
}

# (s, d) shape classes per kernel; smoke keeps CI fast (interpret mode).
# For paged decode the "s" is the PAGE SIZE (keyed exactly, no bucket).
ATTN_CLASSES = [(256, 32), (256, 64), (512, 64), (1024, 64)]
DECODE_CLASSES = [(128, 32), (256, 64), (512, 64), (1024, 64)]
PAGED_DECODE_CLASSES = [(8, 32), (16, 64), (32, 64), (16, 128)]
SSD_CLASSES = [(256, 16), (512, 32), (1024, 32)]
SMOKE_ATTN_CLASSES = [(128, 32), (256, 32)]
SMOKE_DECODE_CLASSES = [(128, 32)]
SMOKE_PAGED_DECODE_CLASSES = [(8, 32)]
SMOKE_SSD_CLASSES = [(256, 16)]


def _time(fn, args, iters: int, warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _vjp_fn(f):
    import jax

    def run(*args):
        out, pull = jax.vjp(f, *args[:-1])
        return pull(args[-1])
    return run


def _pick(rows: List[Dict], default_cfg: Dict, score_field: str) -> Dict:
    """Winner = argmin score over all measured rows (candidates + ref).
    The returned entry carries the winner's config plus the timing
    triple used by the acceptance check."""
    best = min(rows, key=lambda r: r[score_field])
    t_ref = next(r[score_field] for r in rows if r["backend"] == "ref")
    t_default = next(
        r[score_field] for r in rows
        if r["backend"] == "kernel"
        and all(r[k] == v for k, v in default_cfg.items()))
    entry = {k: v for k, v in best.items() if k not in ("t_fwd",)}
    entry["t_best"] = best[score_field]
    entry["t_ref"] = t_ref
    entry["t_default"] = t_default
    entry["speedup_vs_default"] = t_default / best[score_field]
    return entry


def _tune_flash_attention(classes, candidates, iters: int, interpret: bool):
    import jax
    import jax.numpy as jnp

    from repro.models.attention import full_attention

    from . import flash_attention as _flash

    entries, sweep = {}, {}
    for (s, d) in classes:
        b, h = 1, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q, k, v, do = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)

        def kern(bq, bk):
            def f(q, k, v):
                return _flash.flash_attention(
                    q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), causal=True, window=0,
                    block_q=bq, block_k=bk,
                    interpret=interpret).transpose(0, 2, 1, 3)
            return f

        rows = []
        for (bq, bk) in candidates:
            f = kern(bq, bk)
            rows.append({
                "backend": "kernel", "block_q": bq, "block_k": bk,
                "t_fwd": _time(jax.jit(f), (q, k, v), iters),
                "t_fwd_bwd": _time(jax.jit(_vjp_fn(f)), (q, k, v, do),
                                   iters),
            })
        ref = lambda q, k, v: full_attention(q, k, v, causal=True)  # noqa
        rows.append({
            "backend": "ref",
            "t_fwd": _time(jax.jit(ref), (q, k, v), iters),
            "t_fwd_bwd": _time(jax.jit(_vjp_fn(ref)), (q, k, v, do), iters),
        })
        key = shape_key("flash_attention", s, d, jnp.float32)
        # scored on fwd+bwd: training dominates; prefill rides the winner
        entries[key] = _pick(rows, DEFAULTS["flash_attention"], "t_fwd_bwd")
        sweep[key] = {"shape": {"b": b, "s": s, "h": h, "d": d},
                      "rows": rows}
    return entries, sweep


def _tune_flash_decode(classes, candidates, iters: int, interpret: bool):
    import jax
    import jax.numpy as jnp

    from . import flash_decode as _decode
    from . import ref as _ref

    entries, sweep = {}, {}
    for (s, d) in classes:
        b, h = 8, 4
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d))
        k = jax.random.normal(ks[1], (b, s, h, d))
        v = jax.random.normal(ks[2], (b, s, h, d))
        lengths = jnp.linspace(1, s, b).astype(jnp.int32)

        rows = []
        for bk in candidates:
            def f(q, k, v, lengths, bk=bk):
                return _decode.flash_decode(
                    q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), lengths, block_k=bk,
                    interpret=interpret).transpose(0, 2, 1, 3)
            rows.append({"backend": "kernel", "block_k": bk,
                         "t": _time(jax.jit(f), (q, k, v, lengths), iters)})
        rows.append({"backend": "ref",
                     "t": _time(jax.jit(_ref.flash_decode_ref),
                                (q, k, v, lengths), iters)})
        key = shape_key("flash_decode", s, d, jnp.float32)
        entries[key] = _pick(rows, DEFAULTS["flash_decode"], "t")
        sweep[key] = {"shape": {"b": b, "s": s, "h": h, "d": d},
                      "rows": rows}
    return entries, sweep


def _tune_flash_decode_paged(classes, candidates, iters: int,
                             interpret: bool):
    """No block knobs to sweep — the decision is purely whether the
    Pallas paged kernel beats the XLA gather+softmax reference at this
    (page_size, head_dim) class."""
    del candidates
    import jax
    import jax.numpy as jnp

    from . import flash_decode as _decode
    from . import ref as _ref

    entries, sweep = {}, {}
    for (ps, d) in classes:
        b, h, h_kv, p_tab = 8, 4, 2, 4
        n_pages = b * p_tab
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d))
        k_pool = jax.random.normal(ks[1], (n_pages, ps, h_kv, d))
        v_pool = jax.random.normal(ks[2], (n_pages, ps, h_kv, d))
        pages = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, p_tab)
        lengths = jnp.linspace(1, p_tab * ps, b).astype(jnp.int32)

        def kern(q, k_pool, v_pool, pages, lengths):
            return _decode.flash_decode_paged(
                q.transpose(0, 2, 1, 3), k_pool, v_pool, pages, lengths,
                interpret=interpret).transpose(0, 2, 1, 3)

        rows = [
            {"backend": "kernel",
             "t": _time(jax.jit(kern), (q, k_pool, v_pool, pages, lengths),
                        iters)},
            {"backend": "ref",
             "t": _time(jax.jit(_ref.flash_decode_paged_ref),
                        (q, k_pool, v_pool, pages, lengths), iters)},
        ]
        key = shape_key("flash_decode_paged", ps, d, jnp.float32)
        entries[key] = _pick(rows, DEFAULTS["flash_decode_paged"], "t")
        sweep[key] = {"shape": {"b": b, "page_size": ps, "h": h,
                                "h_kv": h_kv, "d": d, "n_pages": n_pages},
                      "rows": rows}
    return entries, sweep


def _tune_ssd(classes, candidates, iters: int, interpret: bool):
    import jax
    import jax.numpy as jnp

    from repro.models.ssm import ssd_chunked

    from . import mamba2_scan as _ssd

    entries, sweep = {}, {}
    for (s, p) in classes:
        b, h, n = 1, 2, p
        ks = jax.random.split(jax.random.PRNGKey(2), 6)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        Bm = jax.random.normal(ks[3], (b, s, n))
        Cm = jax.random.normal(ks[4], (b, s, n))
        dy = jax.random.normal(ks[5], (b, s, h, p))

        rows = []
        for chunk in candidates:
            def f(x, dt, A, Bm, Cm, chunk=chunk):
                return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk,
                                interpret=interpret)
            rows.append({
                "backend": "kernel", "chunk": chunk,
                "t_fwd": _time(jax.jit(f), (x, dt, A, Bm, Cm), iters),
                "t_fwd_bwd": _time(jax.jit(_vjp_fn(f)),
                                   (x, dt, A, Bm, Cm, dy), iters),
            })
        ref = lambda *a: ssd_chunked(*a)  # noqa: E731 — model default chunk
        rows.append({
            "backend": "ref",
            "t_fwd": _time(jax.jit(ref), (x, dt, A, Bm, Cm), iters),
            "t_fwd_bwd": _time(jax.jit(_vjp_fn(ref)),
                               (x, dt, A, Bm, Cm, dy), iters),
        })
        key = shape_key("ssd", s, p, jnp.float32)
        entries[key] = _pick(rows, DEFAULTS["ssd"], "t_fwd_bwd")
        sweep[key] = {"shape": {"b": b, "s": s, "h": h, "p": p, "n": n},
                      "rows": rows}
    return entries, sweep


def run_autotune(smoke: bool = False, iters: Optional[int] = None
                 ) -> Tuple[Dict, Dict]:
    """Sweep every kernel's candidate grid over its shape classes.

    Returns (table_payload, bench_payload): the first is the versioned
    artifact :mod:`ops` consults; the second is the full sweep record for
    ``BENCH_autotune.json`` (every candidate's walltime, the chosen
    config, and its speedup vs the hard-coded default)."""
    import jax

    interpret = jax.default_backend() != "tpu"
    iters = iters if iters is not None else (2 if smoke else 5)
    cands = SMOKE_CANDIDATES if smoke else CANDIDATES
    attn_classes = SMOKE_ATTN_CLASSES if smoke else ATTN_CLASSES
    dec_classes = SMOKE_DECODE_CLASSES if smoke else DECODE_CLASSES
    paged_classes = (SMOKE_PAGED_DECODE_CLASSES if smoke
                     else PAGED_DECODE_CLASSES)
    ssd_classes = SMOKE_SSD_CLASSES if smoke else SSD_CLASSES

    entries: Dict[str, Dict] = {}
    sweep: Dict[str, Dict] = {}
    for tune, classes, cand in (
            (_tune_flash_attention, attn_classes, cands["flash_attention"]),
            (_tune_flash_decode, dec_classes, cands["flash_decode"]),
            (_tune_flash_decode_paged, paged_classes,
             cands["flash_decode_paged"]),
            (_tune_ssd, ssd_classes, cands["ssd"])):
        e, s = tune(classes, cand, iters, interpret)
        entries.update(e)
        sweep.update(s)

    meta = {"backend": jax.default_backend(), "interpret": interpret,
            "smoke": smoke, "iters": iters}
    table_payload = {"version": AUTOTUNE_VERSION, "created": time.time(),
                     "meta": meta, "entries": entries}
    bench_payload = {"meta": meta, "defaults": DEFAULTS, "sweep": sweep,
                     "entries": entries}
    return table_payload, bench_payload
