"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid: (B, H, n_chunks) — chunks innermost, so the inter-chunk state
(P, N) persists in VMEM scratch across chunk steps (TPU grid order is
sequential over the last dimension). Per chunk the kernel computes the
intra-chunk attention-like term (an (L, L) masked matmul on the MXU), the
inter-chunk contribution from the carried state, and the state update —
exactly the structure of ``repro.models.ssm.ssd_chunked`` (the jnp
reference path used by the model on CPU).

VMEM working set per step at L=256, P=64, N=64:
  x/dt/dA/B/C blocks + (L,L) decay f32 + state (P,N) f32 ~= 0.6 MiB.
All matmul dims are multiples of 64/128 -> MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (L,)  (<= 0)
    Bm = b_ref[0].astype(jnp.float32)            # (L, N)
    Cm = c_ref[0].astype(jnp.float32)            # (L, N)

    cum = jnp.cumsum(dA)                         # (L,)
    total = cum[-1]
    # intra-chunk: masked decay * (C B^T)
    diff = cum[:, None] - cum[None, :]           # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(ii >= jj, diff, NEG_INF))
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    m = scores * decay                           # (L, L)
    xdt = x * dt[:, None]                        # (L, P)
    y_intra = jnp.dot(m, xdt, preferred_element_type=jnp.float32)
    # inter-chunk from carried state (P, N)
    state = state_ref[...]
    y_inter = jnp.dot(Cm, state.T,
                      preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]                  # (L, P)
    # state update
    w = jnp.exp(total - cum) * dt                # (L,)
    s_local = jnp.dot((x * w[:, None]).T, Bm,
                      preferred_element_type=jnp.float32)   # (P, N)
    state_ref[...] = jnp.exp(total) * state + s_local
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_fwd(x, dt, A, Bm, Cm, *, chunk=256, interpret=False):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N) -> y (B,S,H,P).

    Same contract as ``repro.models.ssm.ssd_chunked`` /
    ``repro.kernels.ref.ssd_ref``.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # layout: (B, H, S, *) with chunks innermost in the grid
    xr = x.transpose(0, 2, 1, 3)                     # (B,H,S,P)
    dtr = dt.transpose(0, 2, 1)                      # (B,H,S)
    dAr = (A[None, :, None] * dtr).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, dAr, Bm, Cm)
    return y.transpose(0, 2, 1, 3)                   # (B,S,H,P)
