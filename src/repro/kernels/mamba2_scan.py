"""Mamba2 SSD chunk-scan Pallas TPU kernels — forward AND backward.

Forward grid: (B, H, n_chunks) — chunks innermost, so the inter-chunk
state (P, N) persists in VMEM scratch across chunk steps (TPU grid order
is sequential over the last dimension). Per chunk the kernel computes
the intra-chunk attention-like term (an (L, L) masked matmul on the
MXU), the inter-chunk contribution from the carried state, and the state
update — exactly the structure of ``repro.models.ssm.ssd_chunked`` (the
jnp reference path used by the model on CPU). When taking gradients the
forward additionally spills each chunk's INPUT state to HBM
((B, H, nc, P, N), the only residual beyond the inputs themselves).

Backward (DESIGN.md §11): the same grid iterated in REVERSE chunk order
(via the index maps — the grid itself stays forward-ordered) carrying
``dstate`` (P, N) in VMEM scratch. Per chunk it recomputes the cheap
forward intermediates (cumsum, decay tile, scores) from the saved input
state and emits dx, ddt, d(dA), and per-head dB/dC partials (summed over
heads outside, since Bm/Cm are shared across heads and revisiting one
output block non-consecutively would break TPU accumulation). The
``dA = A * dt`` chain rule runs outside the kernel in jnp, keeping the
kernel oblivious to the A/dt factorization. Everything is wired through
``jax.custom_vjp`` in ``ssd`` below.

VMEM working set per backward step at L=256, P=64, N=64:
  x/dt/dA/B/C/state/dy blocks + (L, L) decay+score f32 tiles + the
  (P, N) dstate scratch ~= 1.3 MiB. All matmul dims are multiples of
64/128 -> MXU-aligned. Non-multiple sequence lengths are zero-padded by
``ssd`` (dt = 0 on the pad makes the extra positions exact no-ops:
dA = 0 so the decay is 1 and the state passes through unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_fwd_only_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref,
                         state_ref, *, chunk: int):
    _ssd_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, None,
                state_ref, chunk=chunk)


def _ssd_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, st_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    if st_ref is not None:
        st_ref[0, 0, 0] = state_ref[...]

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (L,)  (<= 0)
    Bm = b_ref[0].astype(jnp.float32)            # (L, N)
    Cm = c_ref[0].astype(jnp.float32)            # (L, N)

    cum = jnp.cumsum(dA)                         # (L,)
    total = cum[-1]
    # intra-chunk: masked decay * (C B^T)
    diff = cum[:, None] - cum[None, :]           # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(ii >= jj, diff, NEG_INF))
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    m = scores * decay                           # (L, L)
    xdt = x * dt[:, None]                        # (L, P)
    y_intra = jnp.dot(m, xdt, preferred_element_type=jnp.float32)
    # inter-chunk from carried state (P, N)
    state = state_ref[...]
    y_inter = jnp.dot(Cm, state.T,
                      preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]                  # (L, P)
    # state update
    w = jnp.exp(total - cum) * dt                # (L,)
    s_local = jnp.dot((x * w[:, None]).T, Bm,
                      preferred_element_type=jnp.float32)   # (P, N)
    state_ref[...] = jnp.exp(total) * state + s_local
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)


def _ssd_bwd_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, st_ref, dy_ref,
                    dx_ref, ddt_ref, ddA_ref, db_ref, dc_ref,
                    dstate_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():                                  # d(final state) == 0
        dstate_ref[...] = jnp.zeros_like(dstate_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (L,)
    Bm = b_ref[0].astype(jnp.float32)            # (L, N)
    Cm = c_ref[0].astype(jnp.float32)            # (L, N)
    s0 = st_ref[0, 0, 0]                         # (P, N) input state
    dy = dy_ref[0, 0].astype(jnp.float32)        # (L, P)
    ds1 = dstate_ref[...]                        # d(output state)

    # ---- recompute the cheap forward intermediates ------------------- #
    cum = jnp.cumsum(dA)
    total = cum[-1]
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(ii >= jj, diff, NEG_INF))
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    m = scores * decay
    xdt = x * dt[:, None]
    expcum = jnp.exp(cum)
    w = jnp.exp(total - cum) * dt
    et = jnp.exp(total)

    # ---- state update: state_out = exp(total) s0 + (x*w)^T B --------- #
    ds0 = et * ds1
    dtotal = et * jnp.sum(ds1 * s0)
    g = jnp.dot(x, ds1, preferred_element_type=jnp.float32)      # (L, N)
    db = w[:, None] * g
    dxw = jnp.dot(Bm, ds1.T, preferred_element_type=jnp.float32)  # (L, P)
    dx = w[:, None] * dxw
    dw = jnp.sum(x * dxw, axis=-1)                                # (L,)
    ddt = dw * jnp.exp(total - cum)
    dcum = -(dw * w)
    dtotal += jnp.sum(dw * w)

    # ---- inter-chunk: y_inter = (C s0^T) * exp(cum) ------------------ #
    dyec = dy * expcum[:, None]                                   # (L, P)
    y_inter = jnp.dot(Cm, s0.T,
                      preferred_element_type=jnp.float32) * expcum[:, None]
    dc = jnp.dot(dyec, s0, preferred_element_type=jnp.float32)    # (L, N)
    ds0 += jnp.dot(dyec.T, Cm, preferred_element_type=jnp.float32)
    dcum += jnp.sum(dy * y_inter, axis=-1)

    # ---- intra-chunk: y_intra = (scores * decay) @ (x * dt) ---------- #
    dm = jnp.dot(dy, xdt.T, preferred_element_type=jnp.float32)   # (L, L)
    dxdt = jnp.dot(m.T, dy, preferred_element_type=jnp.float32)   # (L, P)
    dscores = dm * decay
    ddecay = dm * scores
    dc += jnp.dot(dscores, Bm, preferred_element_type=jnp.float32)
    db += jnp.dot(dscores.T, Cm, preferred_element_type=jnp.float32)
    ddiff = ddecay * decay          # masked entries: decay == 0 -> 0
    dcum += ddiff.sum(axis=-1) - ddiff.sum(axis=0)
    dx += dxdt * dt[:, None]
    ddt += jnp.sum(dxdt * x, axis=-1)

    # total = cum[-1]; cum = cumsum(dA) -> ddA = inclusive suffix sum
    last = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
    dcum += jnp.where(last == chunk - 1, dtotal, 0.0)
    csum = jnp.cumsum(dcum)
    ddA = csum[-1] - csum + dcum

    dx_ref[0, 0] = dx.astype(dx_ref.dtype)
    ddt_ref[0, 0] = ddt
    ddA_ref[0, 0] = ddA
    db_ref[0, 0] = db
    dc_ref[0, 0] = dc
    dstate_ref[...] = ds0


def _ssd_layouts(x, dt, A):
    xr = x.transpose(0, 2, 1, 3)                     # (B,H,S,P)
    dtr = dt.transpose(0, 2, 1)                      # (B,H,S)
    dAr = (A[None, :, None] * dtr).astype(jnp.float32)
    return xr, dtr, dAr


def ssd_fwd(x, dt, A, Bm, Cm, *, chunk=256, interpret=False,
            return_states=False):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N) -> y (B,S,H,P)
    [, per-chunk input states (B,H,nc,P,N)].

    Raw divisible-shape primitive (same contract as
    ``repro.models.ssm.ssd_chunked`` / ``repro.kernels.ref.ssd_ref``);
    ``ssd`` below adds padding and the custom VJP."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # layout: (B, H, S, *) with chunks innermost in the grid
    xr, dtr, dAr = _ssd_layouts(x, dt, A)

    # the per-chunk-states residual output exists only when the caller
    # will differentiate — plain forwards don't pay for the buffer
    out_specs = [pl.BlockSpec((1, 1, chunk, p),
                              lambda bi, hi, ci: (bi, hi, ci, 0))]
    out_shape = [jax.ShapeDtypeStruct((b, h, s, p), x.dtype)]
    if return_states:
        kernel = functools.partial(_ssd_kernel, chunk=chunk)
        out_specs.append(pl.BlockSpec(
            (1, 1, 1, p, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b, h, nc, p, n), jnp.float32))
    else:
        kernel = functools.partial(_ssd_fwd_only_kernel, chunk=chunk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, dAr, Bm, Cm)
    y = out[0].transpose(0, 2, 1, 3)                 # (B,S,H,P)
    if return_states:
        return y, out[1]
    return y


def ssd_bwd(x, dt, A, Bm, Cm, states, dy, *, chunk=256, interpret=False):
    """Raw backward: inputs + saved chunk states + cotangent dy
    (B,S,H,P) -> (dx, ddt, dA, dBm, dCm) matching the input shapes."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xr, dtr, dAr = _ssd_layouts(x, dt, A)
    dyr = dy.transpose(0, 2, 1, 3)                   # (B,H,S,P)

    # all chunk-indexed dims run REVERSED so dstate flows backward
    rev = nc - 1
    kernel = functools.partial(_ssd_bwd_kernel, chunk=chunk)
    dx_r, ddt_r, ddA_r, dbh, dch = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, rev - ci)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, rev - ci)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, rev - ci, 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, rev - ci, 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, rev - ci)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, rev - ci)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi, rev - ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, dAr, Bm, Cm, states, dyr)

    # chain rule through dA = A * dt (done here, not in the kernel)
    dx = dx_r.transpose(0, 2, 1, 3).astype(x.dtype)
    ddt = (ddt_r + ddA_r * A[None, :, None]).transpose(0, 2, 1)
    dA_out = jnp.sum(ddA_r * dtr.astype(jnp.float32), axis=(0, 2))
    dBm = dbh.sum(axis=1)                            # heads share Bm/Cm
    dCm = dch.sum(axis=1)
    return (dx, ddt.astype(dt.dtype), dA_out.astype(A.dtype),
            dBm.astype(Bm.dtype), dCm.astype(Cm.dtype))


# ---------------------------------------------------------------------- #
# custom_vjp core (divisible shapes) + padded public entry
# ---------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_core(x, dt, A, Bm, Cm, chunk, interpret):
    return ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def _ssd_core_fwd(x, dt, A, Bm, Cm, chunk, interpret):
    y, states = ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret,
                        return_states=True)
    return y, (x, dt, A, Bm, Cm, states)


def _ssd_core_bwd(chunk, interpret, res, dy):
    x, dt, A, Bm, Cm, states = res
    return ssd_bwd(x, dt, A, Bm, Cm, states, dy, chunk=chunk,
                   interpret=interpret)


_ssd_core.defvjp(_ssd_core_fwd, _ssd_core_bwd)


def ssd(x, dt, A, Bm, Cm, *, chunk=256, interpret=False):
    """Trainable Mamba2 SSD, any sequence length.

    Non-multiple S is zero-padded to the next chunk multiple: dt = 0 on
    the pad makes dA = 0, so the padded positions leave the carried state
    untouched and contribute nothing to real outputs or gradients."""
    b, s, h, p = x.shape
    ck = min(chunk, s)
    if s % ck:
        sp = ck * pl.cdiv(s, ck)
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, sp - s), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, sp - s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, sp - s), (0, 0)))
    y = _ssd_core(x, dt, A, Bm, Cm, ck, interpret)
    return y[:, :s]
