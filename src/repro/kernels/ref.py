"""Pure-jnp oracles for the Pallas kernels.

These are deliberately the *naive* formulations (full softmax attention;
strictly sequential SSD recurrence) so kernel tests compare against an
implementation whose correctness is obvious.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q/k/v: (B, H, S, D). Full-softmax reference."""
    b, h, s, d = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_ref(q, k, v, lengths):
    """Single-query decode attention, XLA path — *model layout*.

    q: (B, 1, H, D); k/v: (B, S_cache, H, D) with kv heads already
    repeated; lengths: (B,) valid-prefix rows.  This mirrors the masked
    softmax in ``repro.models.attention.attention_decode`` operation for
    operation, so when the autotuner routes ``ops.flash_decode`` here the
    serving path stays BITWISE identical to the non-kernel engine (the
    token-identity tests rely on that).
    """
    b, one, h, d = q.shape
    s_cache = k.shape[1]
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kpos = jnp.arange(s_cache)[None, :]
    valid = kpos < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_paged_ref(q, k_pool, v_pool, pages, lengths):
    """Paged decode attention, XLA path — *model layout*.

    q: (B, 1, H, D); k_pool/v_pool: (N_pages, page_size, H_kv, D) shared
    pools; pages: (B, P) block tables (-1 = unassigned); lengths: (B,)
    valid rows.  Gathers each slot's pages into a linear cache (-1 rows
    are gathered from page 0 but masked by ``lengths`` — the engine only
    maps pages covering valid rows), repeats KV heads for GQA, and
    defers to ``flash_decode_ref`` — so when the autotuner routes
    ``ops.flash_decode_paged`` here the paged serving path stays BITWISE
    identical to the engine's jnp path."""
    b, p_tab = pages.shape
    n_pages, ps, h_kv, d = k_pool.shape
    h = q.shape[2]
    safe = jnp.maximum(pages, 0)
    k = k_pool[safe].reshape(b, p_tab * ps, h_kv, d)
    v = v_pool[safe].reshape(b, p_tab * ps, h_kv, d)
    groups = h // h_kv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    return flash_decode_ref(q, k, v, lengths)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential Mamba2/SSD recurrence (the obviously-correct oracle).

    x: (B,S,H,P), dt: (B,S,H), A: (H,) (<0), Bm/Cm: (B,S,N).
    h_t = exp(A*dt_t) h_{t-1} + dt_t * x_t (outer) B_t ;  y_t = C_t . h_t
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (b,h,p),(b,h),(b,n),(b,n)
        da = jnp.exp(A[None, :] * dtt)             # (b,h)
        state = da[..., None, None] * state + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)   # (B,S,H,P)
