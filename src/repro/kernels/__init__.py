"""Pallas TPU kernels for the compute hot spots (flash attention, Mamba2
SSD chunk scan), forward and backward (``jax.custom_vjp``), each with a
pure-jnp oracle in ``ref.py`` and a jit'd wrapper in ``ops.py``.
Validated — values and ``jax.grad`` — with ``interpret=True`` on CPU."""
from . import ops
from . import ref

# module aliases used by the model code
flash_attention_ops = ops
mamba2_ops = ops

__all__ = ["flash_attention_ops", "mamba2_ops", "ops", "ref"]
