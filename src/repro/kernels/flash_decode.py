"""Flash-decode Pallas TPU kernel — single-query attention over a KV
cache (the serving hot loop).

Decode attention is one query row per (batch, head) against ``S_cache``
cached keys/values, of which only a dynamic prefix ``lengths[b]`` is
valid (the linear, non-ring cache layout: slot ``t`` holds absolute
position ``t``).  The kernel blocks over the KV length with the kv
dimension innermost — grid ``(B*H, n_kv_blocks)`` — so the running
flash statistics (max ``m``, sum ``l``, weighted accumulator ``acc``)
live in VMEM scratch across kv steps, exactly like the full
flash-attention forward in ``flash_attention.py``; only q, the kv
blocks, and the (1, D) output ever cross the DMA boundary.

Masking: the cache length ``S_cache`` is static (zero-padded to a block
multiple outside the kernel) while the *valid* prefix is dynamic, so the
per-(batch,head) length rides in SMEM and masks ``kpos >= length``.
Fully-masked tail blocks keep ``m = NEG_INF``; probabilities are zeroed
with an explicit ``where`` (``exp(NEG_INF - NEG_INF) == 1`` otherwise),
so they contribute exactly nothing to ``l``/``acc``.

There is no backward: decode runs under ``lax.stop_gradient`` semantics
by construction (no ``custom_vjp`` needed — nothing differentiates
through the serving loop).  On CPU the wrapper in ``ops.py`` runs the
kernel with ``interpret=True``, bit-matching the TPU algorithm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, block_k: int,
                         n_kv_blocks: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (1, D)
    k = k_ref[0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)  # (1, BK)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    valid = kpos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                            # (1,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # explicit zero for masked columns: when a block is fully masked,
    # m_new stays NEG_INF and exp(s - m_new) would be exp(0) == 1.
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_decode(q, k, v, lengths, *, block_k=128, interpret=False):
    """q: (B, H, 1, D); k/v: (B, H, S, D) KV cache (kv heads already
    repeated to H); lengths: (B,) i32 — number of valid cache rows per
    batch element (linear layout).  Returns (B, H, 1, D)."""
    b, h, one, d = q.shape
    assert one == 1, q.shape
    s = k.shape[2]
    assert k.shape == v.shape == (b, h, s, d), (k.shape, v.shape)
    bk = min(block_k, s)
    if s % bk:
        sp = bk * pl.cdiv(s, bk)
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        s = sp
    nk = s // bk
    bh = b * h
    qr = q.reshape(bh, 1, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)
    lens = jnp.broadcast_to(lengths.astype(jnp.int32)[:, None],
                            (b, h)).reshape(bh, 1)

    kernel = functools.partial(_flash_decode_kernel, block_k=bk,
                               n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running sum l
            pltpu.VMEM((1, d), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, h, 1, d)
