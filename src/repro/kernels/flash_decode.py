"""Flash-decode Pallas TPU kernel — single-query attention over a KV
cache (the serving hot loop).

Decode attention is one query row per (batch, head) against ``S_cache``
cached keys/values, of which only a dynamic prefix ``lengths[b]`` is
valid (the linear, non-ring cache layout: slot ``t`` holds absolute
position ``t``).  The kernel blocks over the KV length with the kv
dimension innermost — grid ``(B*H, n_kv_blocks)`` — so the running
flash statistics (max ``m``, sum ``l``, weighted accumulator ``acc``)
live in VMEM scratch across kv steps, exactly like the full
flash-attention forward in ``flash_attention.py``; only q, the kv
blocks, and the (1, D) output ever cross the DMA boundary.

Masking: the cache length ``S_cache`` is static (zero-padded to a block
multiple outside the kernel) while the *valid* prefix is dynamic, so the
per-(batch,head) length rides in SMEM and masks ``kpos >= length``.
Fully-masked tail blocks keep ``m = NEG_INF``; probabilities are zeroed
with an explicit ``where`` (``exp(NEG_INF - NEG_INF) == 1`` otherwise),
so they contribute exactly nothing to ``l``/``acc``.

There is no backward: decode runs under ``lax.stop_gradient`` semantics
by construction (no ``custom_vjp`` needed — nothing differentiates
through the serving loop).  On CPU the wrapper in ``ops.py`` runs the
kernel with ``interpret=True``, bit-matching the TPU algorithm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, block_k: int,
                         n_kv_blocks: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (1, D)
    k = k_ref[0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)  # (1, BK)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    valid = kpos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                            # (1,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # explicit zero for masked columns: when a block is fully masked,
    # m_new stays NEG_INF and exp(s - m_new) would be exp(0) == 1.
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_decode(q, k, v, lengths, *, block_k=128, interpret=False):
    """q: (B, H, 1, D); k/v: (B, H, S, D) KV cache (kv heads already
    repeated to H); lengths: (B,) i32 — number of valid cache rows per
    batch element (linear layout).  Returns (B, H, 1, D)."""
    b, h, one, d = q.shape
    assert one == 1, q.shape
    s = k.shape[2]
    assert k.shape == v.shape == (b, h, s, d), (k.shape, v.shape)
    # No silent clamping: the requested (possibly autotuned) block size is
    # honored exactly; caches shorter than one block are zero-padded up to
    # it, so the tuned and executed block sizes can never diverge.
    assert block_k > 0, block_k
    bk = block_k
    if s % bk:
        sp = bk * pl.cdiv(s, bk)
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        s = sp
    nk = s // bk
    bh = b * h
    qr = q.reshape(bh, 1, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)
    lens = jnp.broadcast_to(lengths.astype(jnp.int32)[:, None],
                            (b, h)).reshape(bh, 1)

    kernel = functools.partial(_flash_decode_kernel, block_k=bk,
                               n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running sum l
            pltpu.VMEM((1, d), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, h, 1, d)


# ---------------------------------------------------------------------- #
# paged variant — KV lives in a shared page pool, addressed per slot via
# a block table (DESIGN.md §15)
# ---------------------------------------------------------------------- #
def _flash_decode_paged_kernel(pages_ref, len_ref, q_ref, k_ref, v_ref,
                               o_ref, m_ref, l_ref, acc_ref, *,
                               page_size: int, n_pages_tab: int,
                               n_heads: int):
    """Grid (B*H, P): one logical page per kv step.  ``pages_ref`` and
    ``len_ref`` are scalar-prefetch SMEM operands — the page table drives
    the k/v BlockSpec index maps (which physical pool page to DMA next),
    and the length masks the invalid tail.  Unassigned table entries
    (-1) are clamped to pool page 0 by the index map; every position of
    such a page lies at or beyond the valid length, so its probabilities
    are zeroed exactly (same NEG_INF discipline as the dense kernel)."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bi = pl.program_id(0) // n_heads
    q = q_ref[0].astype(jnp.float32)               # (1, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (PS, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)  # (1, PS)
    kpos = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = kpos < len_ref[bi]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_pages_tab - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_decode_paged(q, k_pool, v_pool, pages, lengths, *,
                       interpret=False):
    """Paged flash decode. q: (B, H, 1, D); k_pool/v_pool:
    (N_pages, page_size, H_kv, D) shared page pools; pages: (B, P) i32
    per-slot page table (-1 = unassigned); lengths: (B,) valid rows.
    Returns (B, H, 1, D).

    GQA is resolved in the BlockSpec index map (head ``h`` reads kv head
    ``h // groups`` of its page) — the kv heads are never materialized at
    ``H``.  The page table rides in SMEM via scalar prefetch, so the
    indirection costs nothing per step: each grid step DMAs exactly one
    (page_size, D) tile selected by ``pages[b, ki]``.
    """
    b, h, one, d = q.shape
    assert one == 1, q.shape
    n_pg, page_size, h_kv, dk = k_pool.shape
    assert v_pool.shape == k_pool.shape and dk == d, (
        k_pool.shape, v_pool.shape, q.shape)
    assert h % h_kv == 0, (h, h_kv)
    groups = h // h_kv
    p_tab = pages.shape[1]
    assert pages.shape == (b, p_tab), pages.shape
    bh = b * h
    qr = q.reshape(bh, 1, d)
    pages_i = jnp.maximum(pages.astype(jnp.int32), 0)  # -1 -> page 0, masked
    lens = lengths.astype(jnp.int32)

    def kv_map(bh_i, ki, pages_ref, len_ref):
        return (pages_ref[bh_i // h, ki], 0, (bh_i % h) // groups, 0)

    kernel = functools.partial(
        _flash_decode_paged_kernel, page_size=page_size,
        n_pages_tab=p_tab, n_heads=h)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bh, p_tab),
            in_specs=[
                pl.BlockSpec((1, 1, d),
                             lambda bh_i, ki, pages_ref, len_ref:
                             (bh_i, 0, 0)),
                pl.BlockSpec((1, page_size, 1, d), kv_map),
                pl.BlockSpec((1, page_size, 1, d), kv_map),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, d),
                lambda bh_i, ki, pages_ref, len_ref: (bh_i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),       # running max m
                pltpu.VMEM((1,), jnp.float32),       # running sum l
                pltpu.VMEM((1, d), jnp.float32),     # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        interpret=interpret,
    )(pages_i, lens, qr, k_pool, v_pool)
    return out.reshape(b, h, 1, d)
