"""Flash-attention Pallas TPU kernels — forward AND backward (trainable).

Forward grid: (B*H, n_q_blocks, n_kv_blocks) — the kv dimension is
innermost, so the running (m, l, acc) flash statistics live in VMEM
scratch across kv steps (TPU grids execute sequentially over the last
dimension). The forward also emits the per-row log-sum-exp
``lse = m + log(l)`` so the backward can recompute the probabilities
without materializing the (S, S) matrix.

Backward (recompute-based, DESIGN.md §11): with the standard
``D_i = rowsum(dO_i * O_i)`` trick,

    P_ij = exp(s_ij - lse_i)          s_ij = scale * q_i . k_j  (masked)
    dV_j = sum_i P_ij dO_i
    dP_ij = dO_i . v_j
    dS_ij = P_ij (dP_ij - D_i)
    dQ_i = scale * sum_j dS_ij k_j
    dK_j = scale * sum_i dS_ij q_i

split into two kernels so each output has a sequential accumulation
dimension innermost: the dq kernel iterates kv blocks innermost (dq tile
accumulates in VMEM), the dk/dv kernel iterates q blocks innermost
(dk/dv tiles accumulate in VMEM). D is a cheap fused jnp rowsum outside
the kernels. Everything is wired through ``jax.custom_vjp`` in
``flash_attention`` below, so ``jax.grad`` works natively on TPU and in
``interpret=True`` mode on CPU.

Block shapes are MXU-aligned (multiples of 128 on the matmul dims); the
VMEM working set per backward step is q/k/v/do blocks + the f32
accumulator + the (BQ, BK) score tile:
  (2*BQ*D + 2*BK*D) * 2B + BQ*D*4B + BQ*BK*4B ~= 0.6 MiB at
BQ=BK=D=128, comfortably inside the ~16 MiB v5e VMEM budget even with
double buffering. Sequences that are not a multiple of the block size
are zero-padded by ``flash_attention`` and masked inside the kernels via
the static ``seq_len`` bound (padding happens OUTSIDE the custom_vjp, so
cotangents of the pad rows are exactly zero).

Validated in ``interpret=True`` mode against ``ref.attention_ref`` (and
its ``jax.grad``) over a shape/dtype sweep (tests/test_kernels.py,
tests/test_kernel_grads.py); on CPU the ops wrapper always interprets
(this container has no TPU).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _score_mask(qi, ki, block_q, block_k, *, causal, window, seq_len):
    """(BQ, BK) validity mask for the score tile at (q block qi, kv block
    ki). ``seq_len`` masks zero-padded kv columns (qpos >= seq_len rows
    are garbage by design — their outputs/cotangents are sliced/zeroed
    outside the kernel)."""
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


# ---------------------------------------------------------------------- #
# forward
# ---------------------------------------------------------------------- #
def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                      acc_ref, *, block_q: int, block_k: int, causal: bool,
                      window: int, seq_len: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (BQ, D)
    k = k_ref[0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)  # (BQ, BK)
    mask = _score_mask(qi, ki, block_q, block_k, causal=causal,
                       window=window, seq_len=seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        # lse = m + log(l); fully-masked rows get 0 so the backward's
        # exp(NEG_INF - lse) recompute stays exactly 0 (no inf * 0).
        lse_ref[0] = jnp.where(l > 0, m_ref[...] + jnp.log(
            jnp.maximum(l, 1e-30)), 0.0)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False,
                        seq_len=None, return_lse=False):
    """q/k/v: (B, H, S, D) -> (B, H, S, D) [, lse (B, H, S) f32].

    Raw divisible-shape primitive; ``flash_attention`` below adds padding
    and the custom VJP. ``seq_len`` masks kv positions >= seq_len (used
    when S includes zero padding)."""
    b, h, s, d = q.shape
    assert k.shape == v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    if seq_len is None:
        seq_len = s
    nq, nk = s // block_q, s // block_k
    bh = b * h
    qr = q.reshape(bh, s, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)

    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=seq_len, n_kv_blocks=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, h, s, d)
    if return_lse:
        return out, lse.reshape(b, h, s)
    return out


# ---------------------------------------------------------------------- #
# backward
# ---------------------------------------------------------------------- #
def _recompute_p_ds(q, k, v, do, lse, delta, qi, ki, block_q, block_k, *,
                    causal, window, seq_len):
    """Shared bwd tile math: P = exp(s - lse) and dS = P * (dP - D)."""
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)    # (BQ, BK)
    mask = _score_mask(qi, ki, block_q, block_k, causal=causal,
                       window=window, seq_len=seq_len)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                      # masked entries -> 0
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    return p, ds


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, block_q: int, block_k: int,
                         causal: bool, window: int, seq_len: int,
                         n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    _, ds = _recompute_p_ds(q, k, v, do, lse_ref[0], delta_ref[0],
                            qi, ki, block_q, block_k, causal=causal,
                            window=window, seq_len=seq_len)
    d = q.shape[-1]
    acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32) \
        * (d ** -0.5)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                           dk_ref, dv_ref, dk_acc, dv_acc, *, block_q: int,
                           block_k: int, causal: bool, window: int,
                           seq_len: int, n_q_blocks: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    p, ds = _recompute_p_ds(q, k, v, do, lse_ref[0], delta_ref[0],
                            qi, ki, block_q, block_k, causal=causal,
                            window=window, seq_len=seq_len)
    d = q.shape[-1]
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) \
        * (d ** -0.5)

    @pl.when(qi == n_q_blocks - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False,
                        seq_len=None):
    """Raw backward: (B, H, S, D) residuals + cotangent -> dq, dk, dv."""
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    if seq_len is None:
        seq_len = s
    nq, nk = s // block_q, s // block_k
    bh = b * h
    qr, kr, vr = (t.reshape(bh, s, d) for t in (q, k, v))
    dor = do.reshape(bh, s, d)
    lser = lse.reshape(bh, s)
    # D_i = rowsum(dO_i * O_i): cheap fused elementwise outside the grid.
    delta = jnp.sum(dor.astype(jnp.float32)
                    * o.reshape(bh, s, d).astype(jnp.float32), axis=-1)

    common = dict(block_q=block_q, block_k=block_k, causal=causal,
                  window=window, seq_len=seq_len)
    q_spec = pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0))
    k_spec = pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, n_kv_blocks=nk, **common),
        grid=(bh, nq, nk),
        in_specs=[q_spec, k_spec, k_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lser, delta)

    # kv blocks outermost, q blocks innermost: dk/dv accumulate in VMEM.
    tq_spec = pl.BlockSpec((1, block_q, d), lambda bh, ki, qi: (bh, qi, 0))
    tk_spec = pl.BlockSpec((1, block_k, d), lambda bh, ki, qi: (bh, ki, 0))
    trow_spec = pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkdv_kernel, n_q_blocks=nq, **common),
        grid=(bh, nk, nq),
        in_specs=[tq_spec, tk_spec, tk_spec, tq_spec, trow_spec, trow_spec],
        out_specs=[tk_spec, tk_spec],
        out_shape=[jax.ShapeDtypeStruct((bh, s, d), k.dtype),
                   jax.ShapeDtypeStruct((bh, s, d), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lser, delta)
    shape = (b, h, s, d)
    return dq.reshape(shape), dk.reshape(shape), dv.reshape(shape)


# ---------------------------------------------------------------------- #
# custom_vjp core (divisible shapes) + padded public entry
# ---------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_core(q, k, v, seq_len, causal, window, block_q, block_k,
                interpret):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret, seq_len=seq_len)


def _flash_core_fwd(q, k, v, seq_len, causal, window, block_q, block_k,
                    interpret):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret, seq_len=seq_len,
                                 return_lse=True)
    return o, (q, k, v, o, lse)


def _flash_core_bwd(seq_len, causal, window, block_q, block_k, interpret,
                    res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret,
                               seq_len=seq_len)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention_hbm_bytes(b, h, s, d, *, block_q=128, block_k=128,
                              dtype_bytes=4):
    """Exact HBM (DMA) traffic of the flash kernels, from the same
    grid/BlockSpec geometry the ``pallas_call``s use: a block is fetched
    when its index-map output changes (Pallas elides refetches of an
    unchanged block across inner grid steps), score tiles and running
    statistics never leave VMEM. This is the TPU traffic measure used by
    ``benchmarks/kernels_bench.py``; interpret-mode HLO materializes the
    VMEM tiles into buffers and overcounts by orders of magnitude.
    Row statistics (lse, delta) are counted at ``dtype_bytes`` for
    simplicity (they are f32 regardless of the input dtype)."""
    bq, bk = min(block_q, s), min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    bh = b * h
    fwd = bh * (nq * bq * d                 # q: once per q block
                + nq * nk * 2 * bk * d      # k, v: refetched per (qi, ki)
                + nq * (bq * d + bq))       # o + lse writes
    delta = bh * (2 * s * d + s)            # rowsum(dO * O) read/write
    dq = bh * (nq * (2 * bq * d + 2 * bq)   # q, do, lse, delta: per qi
               + nq * nk * 2 * bk * d       # k, v: per (qi, ki)
               + nq * bq * d)               # dq write
    dkdv = bh * (nk * 2 * bk * d            # k, v: once per kv block
                 + nk * nq * (2 * bq * d + 2 * bq)  # q/do/lse/delta per (ki, qi)
                 + nk * 2 * bk * d)         # dk, dv writes
    out = {"fwd": float(fwd * dtype_bytes),
           "bwd": float((delta + dq + dkdv) * dtype_bytes)}
    out["fwd_bwd"] = out["fwd"] + out["bwd"]
    return out


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """Trainable flash attention, (B, H, S, D) layout, any S.

    Sequences that are not a multiple of the block size are zero-padded
    to the next block multiple and masked via the kernels' ``seq_len``
    bound; padding/slicing sit OUTSIDE the custom_vjp, so JAX's linear
    pad/slice rules zero the pad-row cotangents automatically."""
    b, h, s, d = q.shape
    bq, bk = min(block_q, s), min(block_k, s)
    if s % bq or s % bk:
        sp = math.lcm(block_q, block_k) * pl.cdiv(
            s, math.lcm(block_q, block_k))
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        bq, bk = min(block_q, sp), min(block_k, sp)
    out = _flash_core(q, k, v, s, causal, window, bq, bk, interpret)
    return out[:, :, :s]
