"""Flash-attention forward Pallas TPU kernel.

Grid: (B*H, n_q_blocks, n_kv_blocks) — the kv dimension is innermost, so
the running (m, l, acc) flash statistics live in VMEM scratch across kv
steps (TPU grids execute sequentially over the last dimension). Block
shapes are MXU-aligned (multiples of 128 on the matmul dims); the VMEM
working set per step is q/k/v blocks + the f32 accumulator:
  (BQ*D + 2*BK*D) * 2B + BQ*(D+2)*4B  ~= 0.4 MiB at BQ=BK=128, D=128,
comfortably inside the ~16 MiB v5e VMEM budget even with double buffering.

Validated in ``interpret=True`` mode against ``ref.attention_ref`` over a
shape/dtype sweep (tests/test_kernels.py); on CPU the ops wrapper always
interprets (this container has no TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      block_q: int, block_k: int, causal: bool, window: int,
                      n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (BQ, D)
    k = k_ref[0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.dot(q * (d ** -0.5), k.T,
                preferred_element_type=jnp.float32)  # (BQ, BK)

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False):
    """q/k/v: (B, H, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    assert k.shape == v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    bh = b * h
    qr = q.reshape(bh, s, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)

    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k,
        causal=causal, window=window, n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)
