"""Jit-able wrappers choosing kernel vs interpret mode by backend.

On TPU the Pallas kernels compile natively; on CPU (this container) they
execute in ``interpret=True`` mode — the kernel body runs as traced jnp,
bit-matching the TPU algorithm for validation.

Both wrappers are TRAINABLE: the underlying entries carry a
``jax.custom_vjp`` whose backward passes are themselves Pallas kernels
(recompute-based flash backward, reverse-chunk SSD backward — DESIGN.md
§11), so ``jax.grad`` through ``use_kernels=True`` works on both
backends. Sequence lengths that are not a multiple of the block/chunk
size are zero-padded and masked inside the kernels, so every ``configs/``
shape can take the kernel path.
"""
from __future__ import annotations

import jax

from . import flash_attention as _flash
from . import flash_decode as _decode
from . import mamba2_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128):
    """q/k/v: (B, S, H, D) (model layout) -> (B, S, H, D). Differentiable
    in q, k, v; any sequence length."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash.flash_attention(qt, kt, vt, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def flash_decode(q, k, v, lengths, *, block_k=128):
    """Single-query decode attention against a linear KV cache.
    q: (B, 1, H, D) (model layout), k/v: (B, S_cache, H, D) with kv heads
    already repeated to H, lengths: (B,) valid-prefix rows.  Not
    differentiable (serving only)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _decode.flash_decode(qt, kt, vt, lengths, block_k=block_k,
                               interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def ssd(x, dt, A, Bm, Cm, *, chunk=256):
    """Mamba2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).
    Differentiable in all five operands; any sequence length."""
    return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_interpret())
