"""Jit-able wrappers choosing kernel vs interpret mode by backend.

On TPU the Pallas kernels compile natively; on CPU (this container) they
execute in ``interpret=True`` mode — the kernel body runs as traced jnp,
bit-matching the TPU algorithm for validation.

Both wrappers are TRAINABLE: the underlying entries carry a
``jax.custom_vjp`` whose backward passes are themselves Pallas kernels
(recompute-based flash backward, reverse-chunk SSD backward — DESIGN.md
§11), so ``jax.grad`` through ``use_kernels=True`` works on both
backends. Sequence lengths that are not a multiple of the block/chunk
size are zero-padded and masked inside the kernels, so every ``configs/``
shape can take the kernel path.

Autotuned routing (DESIGN.md §15): when a call site leaves the block /
chunk arguments at ``None`` (the default — all production call sites do),
the wrapper consults the autotune table for this shape class.  A tuned
entry supplies block sizes; an entry recording ``backend: "ref"`` (the
sweep found XLA faster at this shape) routes to the reference path —
*bitwise identical* to the corresponding model jnp path, so token/loss
identity is preserved through the reroute.  With no artifact present the
hard-coded defaults apply unchanged.  Explicit block arguments always
win (tests pin them).
"""
from __future__ import annotations

import jax

from . import autotune
from . import flash_attention as _flash
from . import flash_decode as _decode
from . import mamba2_scan as _ssd
from . import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(kind: str, s: int, d: int, dtype, overrides: dict):
    """Merge explicit call-site arguments over the tuned entry (or the
    hard-coded defaults).  Returns (cfg, use_ref): ``use_ref`` only when
    the tuned winner is the reference AND the caller pinned nothing."""
    explicit = {k: v for k, v in overrides.items() if v is not None}
    if len(explicit) == len(overrides):
        return explicit, False
    entry = autotune.lookup(kind, s, d, dtype)
    if entry is not None and entry.get("backend") == "ref":
        if not explicit:
            return dict(autotune.DEFAULTS[kind]), True
        entry = None                       # caller pinned a block: honor it
    base = dict(autotune.DEFAULTS[kind])
    if entry is not None:
        base.update({k: entry[k] for k in base if k in entry})
    base.update(explicit)
    return base, False


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=None, block_k=None):
    """q/k/v: (B, S, H, D) (model layout) -> (B, S, H, D). Differentiable
    in q, k, v; any sequence length."""
    cfg, use_ref = _resolve(
        "flash_attention", q.shape[1], q.shape[3], q.dtype,
        {"block_q": block_q, "block_k": block_k})
    if use_ref:
        # lazy: models.attention imports this module inside functions only
        from repro.models.attention import full_attention
        return full_attention(q, k, v, causal=causal, window=window)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash.flash_attention(qt, kt, vt, causal=causal, window=window,
                                 block_q=cfg["block_q"],
                                 block_k=cfg["block_k"],
                                 interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def flash_decode(q, k, v, lengths, *, block_k=None):
    """Single-query decode attention against a linear KV cache.
    q: (B, 1, H, D) (model layout), k/v: (B, S_cache, H, D) with kv heads
    already repeated to H, lengths: (B,) valid-prefix rows.  Not
    differentiable (serving only)."""
    cfg, use_ref = _resolve("flash_decode", k.shape[1], q.shape[3],
                            q.dtype, {"block_k": block_k})
    if use_ref:
        return _ref.flash_decode_ref(q, k, v, lengths)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _decode.flash_decode(qt, kt, vt, lengths, block_k=cfg["block_k"],
                               interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def flash_decode_paged(q, k_pool, v_pool, pages, lengths):
    """Paged decode attention. q: (B, 1, H, D) (model layout);
    k_pool/v_pool: (N_pages, page_size, H_kv, D) shared pools; pages:
    (B, P) per-slot page table (-1 = unassigned); lengths: (B,) valid
    rows.  GQA is resolved inside the kernel's index maps — kv heads are
    never repeated.  Not differentiable (serving only).

    The kernel has no block knobs, so tuned routing is consulted
    directly (``_resolve`` would early-return on the empty override
    set): an entry recording ``backend: "ref"`` for this
    (page_size, head_dim, dtype) class routes to the gather oracle,
    bitwise identical to the engine's jnp paged path."""
    entry = autotune.lookup("flash_decode_paged", k_pool.shape[1],
                            q.shape[3], q.dtype)
    if entry is not None and entry.get("backend") == "ref":
        return _ref.flash_decode_paged_ref(q, k_pool, v_pool, pages,
                                           lengths)
    qt = q.transpose(0, 2, 1, 3)
    out = _decode.flash_decode_paged(qt, k_pool, v_pool, pages, lengths,
                                     interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def ssd(x, dt, A, Bm, Cm, *, chunk=None):
    """Mamba2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).
    Differentiable in all five operands; any sequence length."""
    cfg, use_ref = _resolve("ssd", x.shape[1], x.shape[3], x.dtype,
                            {"chunk": chunk})
    if use_ref:
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, A, Bm, Cm)
    return _ssd.ssd(x, dt, A, Bm, Cm, chunk=cfg["chunk"],
                    interpret=_interpret())
