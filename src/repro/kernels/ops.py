"""Jit-able wrappers choosing kernel vs interpret mode by backend.

On TPU the Pallas kernels compile natively; on CPU (this container) they
execute in ``interpret=True`` mode — the kernel body runs as traced jnp,
bit-matching the TPU algorithm for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd
from .mamba2_scan import ssd_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128):
    """q/k/v: (B, S, H, D) (model layout) -> (B, S, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def ssd(x, dt, A, Bm, Cm, *, chunk=256):
    """Mamba2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N)."""
    return ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_interpret())
