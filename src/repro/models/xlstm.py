"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-
parallel training form with log-space gate stabilization) and sLSTM
(scalar memory, strictly recurrent ``lax.scan``). xLSTM[7:1] stacks 7
mLSTM blocks per sLSTM block.

The mLSTM chunkwise recurrence mirrors the Mamba2 SSD structure (scan
over chunks carrying (C, n, m)); the stabilizer m keeps the exponential
input gate bounded, exactly as in the paper's Appendix.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.hooks import constrain

from .layers import linear, linear_init, rms_norm, rms_norm_init

CHUNK = 256
NEG = -1e30


# ====================================================================== #
# mLSTM
# ====================================================================== #
def mlstm_init(key, d_model, d_inner, n_heads, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    h = n_heads
    # in_proj packs q, k, v, z (gate), i_raw, f_raw
    return {
        "in_proj": linear_init(k1, d_model, 4 * d_inner + 2 * h, dtype=dtype),
        "norm": rms_norm_init(d_inner, dtype),
        "out_proj": linear_init(k2, d_inner, d_model, dtype=dtype),
    }


def _mlstm_split(proj, di, h):
    q = proj[..., 0 * di:1 * di]
    k = proj[..., 1 * di:2 * di]
    v = proj[..., 2 * di:3 * di]
    z = proj[..., 3 * di:4 * di]
    i_raw = proj[..., 4 * di:4 * di + h]
    f_raw = proj[..., 4 * di + h:]
    return q, k, v, z, i_raw, f_raw


def mlstm_chunked(q, k, v, i_log, f_log, *, chunk=CHUNK,
                  init_state=None, return_state=False):
    """q/k/v: (B,S,H,D) f32; i_log/f_log: (B,S,H) f32 (f_log <= 0).
    Returns h (B,S,H,D) [, state (C, n, m)]."""
    b, s, h, d = q.shape
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    shp = (b, nc, chunk, h)
    qc = q.reshape(*shp, d).transpose(1, 0, 2, 3, 4) * d ** -0.5
    kc = k.reshape(*shp, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(*shp, d).transpose(1, 0, 2, 3, 4)
    ic = i_log.reshape(shp).transpose(1, 0, 2, 3)
    fc = f_log.reshape(shp).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        C, n, m = carry                    # (b,h,d,d), (b,h,d), (b,h)
        qi, ki, vi, ii, fi = inp           # (b,L,h,*)
        cum = jnp.cumsum(fi, axis=1)       # (b,L,h)
        total = cum[:, -1]                 # (b,h)
        # D[i,j] = cum_i - cum_j + i_log_j (i >= j)
        Dm = cum[:, :, None, :] - cum[:, None, :, :] + ii[:, None, :, :]
        Dm = jnp.where(causal[None, :, :, None], Dm, NEG)     # (b,i,j,h)
        inter_log = cum + m[:, None, :]                       # (b,L,h)
        m_t = jnp.maximum(Dm.max(axis=2), inter_log)          # (b,L,h)
        scores = jnp.einsum("blhd,bjhd->bljh", qi, ki) \
            * jnp.exp(Dm - m_t[:, :, None, :])                # (b,i,j,h)
        h_num = jnp.einsum("bljh,bjhd->blhd", scores, vi)
        h_num += jnp.einsum("blhd,bhde->blhe", qi, C) \
            * jnp.exp(inter_log - m_t)[..., None]
        n_t = jnp.einsum("bljh,bjhd->blhd", scores, ki)
        n_t += n[:, None] * jnp.exp(inter_log - m_t)[..., None]
        qn = jnp.einsum("blhd,blhd->blh", qi, n_t)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        h_out = h_num / denom[..., None]
        # end-of-chunk state
        a = total[:, None, :] - cum + ii                      # (b,L,h)
        m_next = jnp.maximum(total + m, a.max(axis=1))        # (b,h)
        w = jnp.exp(a - m_next[:, None, :])                   # (b,L,h)
        C_next = C * jnp.exp(total + m - m_next)[..., None, None] \
            + jnp.einsum("blh,blhd,blhe->bhde", w, ki, vi)
        n_next = n * jnp.exp(total + m - m_next)[..., None] \
            + jnp.einsum("blh,blhd->bhd", w, ki)
        return (C_next, n_next, m_next), h_out

    if init_state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
        init_state = (C0, n0, m0)
    state, hs = jax.lax.scan(step, init_state, (qc, kc, vc, ic, fc))
    out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    if return_state:
        return out, state
    return out


def mlstm_forward(p, x, *, d_inner, n_heads):
    b, s, _ = x.shape
    h = n_heads
    dh = d_inner // h
    proj = linear(p["in_proj"], x)
    q, k, v, z, i_raw, f_raw = _mlstm_split(proj, d_inner, h)
    q = constrain(q, "act_inner")
    f_log = -jax.nn.softplus(-f_raw.astype(jnp.float32))   # log sigmoid
    i_log = i_raw.astype(jnp.float32)
    rs = lambda t: t.astype(jnp.float32).reshape(b, s, h, dh)
    y = mlstm_chunked(rs(q), rs(k), rs(v), i_log, f_log)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


def mlstm_prefill(p, x, cache, *, d_inner, n_heads):
    """Full-prompt prefill: (B, S, d_model) + (C, n, m) cache -> outputs
    plus the end-of-prompt state a per-token decode loop would reach.
    Chunkwise-parallel (``mlstm_chunked``), warm-started from the cache."""
    b, s, _ = x.shape
    h = n_heads
    dh = d_inner // h
    proj = linear(p["in_proj"], x)
    q, k, v, z, i_raw, f_raw = _mlstm_split(proj, d_inner, h)
    q = constrain(q, "act_inner")
    f_log = -jax.nn.softplus(-f_raw.astype(jnp.float32))
    i_log = i_raw.astype(jnp.float32)
    rs = lambda t: t.astype(jnp.float32).reshape(b, s, h, dh)
    y, (C, n, m) = mlstm_chunked(
        rs(q), rs(k), rs(v), i_log, f_log,
        init_state=(cache["C"], cache["n"], cache["m"]), return_state=True)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y), {"C": C, "n": n, "m": m}


def mlstm_init_cache(batch, d_inner, n_heads, dtype=jnp.float32):
    dh = d_inner // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.zeros((batch, n_heads), jnp.float32),
    }


def mlstm_decode(p, x, cache, *, d_inner, n_heads):
    b = x.shape[0]
    h, dh = n_heads, d_inner // n_heads
    proj = linear(p["in_proj"], x)[:, 0]
    q, k, v, z, i_raw, f_raw = _mlstm_split(proj, d_inner, h)
    f_log = -jax.nn.softplus(-f_raw.astype(jnp.float32))
    i_log = i_raw.astype(jnp.float32)
    rs = lambda t: t.astype(jnp.float32).reshape(b, h, dh)
    q, k, v = rs(q) * dh ** -0.5, rs(k), rs(v)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(f_log + m, i_log)
    f_s = jnp.exp(f_log + m - m_new)
    i_s = jnp.exp(i_log - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", k, v)
    n = f_s[..., None] * n + i_s[..., None] * k
    h_num = jnp.einsum("bhd,bhde->bhe", q, C)
    qn = jnp.einsum("bhd,bhd->bh", q, n)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    y = (h_num / denom[..., None]).reshape(b, 1, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z[:, None]))
    return linear(p["out_proj"], y), {"C": C, "n": n, "m": m_new}


# ====================================================================== #
# sLSTM
# ====================================================================== #
def slstm_init(key, d_model, n_heads, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    h, dh = n_heads, d_model // n_heads
    return {
        "w_in": linear_init(k1, d_model, 4 * d_model, dtype=dtype),
        # recurrent weights, block-diagonal per head: (h, dh, 4*dh)
        "r": (jax.random.normal(k2, (h, dh, 4 * dh)) / dh ** 0.5
              ).astype(dtype),
        "b": jnp.zeros((4 * d_model,), dtype),
        "norm": rms_norm_init(d_model, dtype),
        "out_proj": linear_init(k3, d_model, d_model, dtype=dtype),
    }


def _slstm_scan(p, u, h0, c0, n0, m0, n_heads):
    """u: (B, S, 4*d) pre-activations from the input projection."""
    b, s, d4 = u.shape
    d = d4 // 4
    h_heads, dh = n_heads, d // n_heads

    def step(carry, ut):
        hprev, c, n, m = carry                       # (b, d) ... m (b, d)
        hh = hprev.reshape(b, h_heads, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh,
                         _r(p)).reshape(b, 4 * d)
        pre = ut + rec + p["b"].astype(jnp.float32)
        i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
        f_log = -jax.nn.softplus(-f_raw)
        m_new = jnp.maximum(f_log + m, i_raw)
        i_s = jnp.exp(i_raw - m_new)
        f_s = jnp.exp(f_log + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(z_raw)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    def _r(p):
        return p["r"].astype(jnp.float32)

    (hT, cT, nT, mT), hs = jax.lax.scan(
        step, (h0, c0, n0, m0), u.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), (hT, cT, nT, mT)


def slstm_forward(p, x, *, n_heads):
    b, s, d = x.shape
    u = linear(p["w_in"], x).astype(jnp.float32)
    z0 = jnp.zeros((b, d), jnp.float32)
    hs, _ = _slstm_scan(p, u, z0, z0, z0 + 1e-6, z0, n_heads)
    y = rms_norm(p["norm"], hs.astype(x.dtype))
    return linear(p["out_proj"], y)


def slstm_init_cache(batch, d_model, dtype=jnp.float32):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": z}


def slstm_decode(p, x, cache, *, n_heads):
    b, _, d = x.shape
    u = linear(p["w_in"], x).astype(jnp.float32)
    hs, (hT, cT, nT, mT) = _slstm_scan(
        p, u, cache["h"], cache["c"], cache["n"], cache["m"], n_heads)
    y = rms_norm(p["norm"], hs.astype(x.dtype))
    return linear(p["out_proj"], y), {"h": hT, "c": cT, "n": nT, "m": mT}
