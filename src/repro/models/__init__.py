"""Model zoo: dense / MoE / SSM (Mamba2, xLSTM) / hybrid / VLM / audio
decoder architectures as pure-JAX pytree-param functions."""
from .model import (decode_step, encode, forward, init_cache, init_paged_cache,
                    init_params, param_count, prefill, prefill_cache_whisper,
                    prefill_extend)

__all__ = ["decode_step", "encode", "forward", "init_cache", "init_paged_cache",
           "init_params", "param_count", "prefill", "prefill_cache_whisper",
           "prefill_extend"]
