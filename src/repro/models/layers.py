"""Shared neural-net layers: norms, linears, embeddings, RoPE/M-RoPE,
sinusoidal positions, SwiGLU MLP. Pure-JAX pytree parameters."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return (std * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------- #
# norm
# ---------------------------------------------------------------------- #
def rms_norm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- #
# linear / embedding
# ---------------------------------------------------------------------- #
def linear_init(key, d_in, d_out, bias=False, std=0.02, dtype=jnp.float32):
    p = {"w": normal_init(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key, vocab, d, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x):
    # tied head: logits = x @ table.T
    return x @ p["table"].astype(x.dtype).T


# ---------------------------------------------------------------------- #
# positions
# ---------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int -> cos/sin (..., S, head_dim//2)."""
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def mrope_cos_sin(positions3: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, ...]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL M-RoPE. positions3: (3, B, S) — temporal/height/width
    position streams; ``sections`` split head_dim//2 rotary channels among
    the three streams. Returns (B, S, head_dim//2) cos/sin."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)          # (head_dim//2,)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3,B,S,D/2)
    chunks = []
    start = 0
    for i, sec in enumerate(sections):
        chunks.append(ang_all[i, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(chunks, axis=-1)       # (B, S, D/2)
    return jnp.cos(ang), jnp.sin(ang)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(B, S) or (S,) int positions -> (..., d_model) sinusoidal embeddings
    (whisper-style, length-extensible)."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- #
# MLP
# ---------------------------------------------------------------------- #
def swiglu_init(key, d, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d, d_ff, dtype=dtype),
        "up": linear_init(k2, d, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu(p, x):
    from repro.sharding.hooks import constrain
    h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    h = constrain(h, "act_ffn")
    return linear(p["down"], h)


def gelu_mlp_init(key, d, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"up": linear_init(k1, d, d_ff, bias=True, dtype=dtype),
            "down": linear_init(k2, d_ff, d, bias=True, dtype=dtype)}


def gelu_mlp(p, x):
    from repro.sharding.hooks import constrain
    h = jax.nn.gelu(linear(p["up"], x))
    h = constrain(h, "act_ffn")
    return linear(p["down"], h)
