"""Mixture-of-Experts FFN: top-k routing, grouped capacity-based dispatch
(GShard/MaxText lineage), optional shared expert (Llama-4 style) and
expert padding to a multiple of the expert-parallel axis (granite on a
16-way TP axis pads 40 -> 48 with -inf router logits; DESIGN.md §6).

Two dispatch modes:
  * ``einsum``  — one-hot dispatch/combine einsums over per-group capacity
                  slots. Partitions well under GSPMD (tokens over batch,
                  experts over 'model'); the dispatch einsums are gathers
                  in disguise and inflate HLO FLOP counts (~2*B*S*E*C*d) —
                  quantified in EXPERIMENTS.md §Roofline.
  * ``dense``   — every expert on every token, exact weighted sum; O(E)
                  compute, used only by tests as the routing oracle.

Tokens are processed in groups of ``group_size`` along the sequence;
capacity C = ceil(cf * g * k / E) per group bounds the dispatch tensors to
O(B*S*E*C) = O(cf * B*S*g*k) elements instead of the ungrouped O(B*S^2*k).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.hooks import constrain

from .layers import linear, linear_init, normal_init, swiglu, swiglu_init

GROUP_SIZE = 256
CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------- #
def moe_init(key, d_model, n_experts, d_ff, *, shared_expert=False,
             pad_to: int = 0, dtype=jnp.float32):
    """``pad_to``: pad the expert dimension to this count (router logits of
    pads are masked to -inf); 0 = no padding."""
    e_pad = max(n_experts, pad_to)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": {"w": normal_init(k1, (d_model, e_pad))},
        "experts": {
            "gate": normal_init(k2, (e_pad, d_model, d_ff)),
            "up": normal_init(k3, (e_pad, d_model, d_ff)),
            "down": normal_init(k4, (e_pad, d_ff, d_model)),
        },
    }
    if dtype != jnp.float32:
        p = jax.tree.map(lambda t: t.astype(dtype), p)
    if shared_expert:
        p["shared"] = swiglu_init(k5, d_model, d_ff, dtype=dtype)
    return p


# ---------------------------------------------------------------------- #
def _top_k_positions(mask_e, top_idx, n_experts_padded, capacity):
    """Assign capacity slots. mask_e: (G, g, k, E) one-hot; returns
    (position (G,g,k), keep (G,g,k)) respecting k-priority order."""
    G, g, k, E = mask_e.shape
    positions = []
    keeps = []
    offset = jnp.zeros((G, 1, E), jnp.int32)
    for slot in range(k):
        m = mask_e[:, :, slot, :]                       # (G, g, E)
        pos_in_e = jnp.cumsum(m, axis=1) - m + offset   # (G, g, E)
        pos = (pos_in_e * m).sum(-1)                    # (G, g)
        keep = pos < capacity
        positions.append(pos.astype(jnp.int32))
        keeps.append(keep)
        offset = offset + jnp.sum(m, axis=1, keepdims=True).astype(jnp.int32)
    return jnp.stack(positions, -1), jnp.stack(keeps, -1)


def moe_forward(p, x, *, n_experts: int, top_k: int,
                group_size: int = GROUP_SIZE,
                capacity_factor: float = CAPACITY_FACTOR,
                dispatch: str = "einsum") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y (B, S, D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    e_pad = p["router"]["w"].shape[-1]
    logits = linear(p["router"], x.astype(jnp.float32))     # (B,S,E_pad)
    if e_pad > n_experts:
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = constrain(probs, "router")

    # load-balance aux (Switch): E * sum_e f_e * P_e
    top1 = jnp.argmax(probs, axis=-1)
    f_e = jnp.mean(jax.nn.one_hot(top1, e_pad, dtype=jnp.float32),
                   axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(f_e * p_e)

    if dispatch == "dense":
        y = _dense_moe(p, x, probs, n_experts, top_k)
        return y + _shared(p, x), aux

    gate_vals, top_idx = jax.lax.top_k(probs, top_k)        # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    g = min(group_size, s)
    assert s % g == 0, (s, g)
    G = b * (s // g)
    cap = max(1, math.ceil(capacity_factor * g * top_k / n_experts))
    xg = x.reshape(G, g, d)
    idxg = top_idx.reshape(G, g, top_k)
    gateg = gate_vals.reshape(G, g, top_k)
    onehot = jax.nn.one_hot(idxg, e_pad, dtype=jnp.int32)   # (G,g,k,E)
    pos, keep = _top_k_positions(onehot, idxg, e_pad, cap)  # (G,g,k)

    if dispatch == "scatter":
        # §Perf C2: index-based dispatch/combine — no one-hot einsums
        # (which cost ~2*cf*B*S*k*d FLOPs each way); scatter/gather move
        # only the dispatched tokens.
        y = _scatter_moe(p, x, xg, idxg, pos, keep, gateg, cap, e_pad)
        return y + _shared(p, x), aux
    # dispatch tensor (G, g, E, C)
    slot_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) \
        * keep[..., None].astype(x.dtype)                   # (G,g,k,C)
    disp = jnp.einsum("tgke,tgkc->tgec",
                      onehot.astype(x.dtype), slot_oh)      # (G,g,E,C)
    comb = jnp.einsum("tgk,tgke,tgkc->tgec",
                      gateg.astype(jnp.float32),
                      onehot.astype(jnp.float32),
                      slot_oh.astype(jnp.float32))          # (G,g,E,C)

    xe = jnp.einsum("tgec,tgd->tecd", disp, x.reshape(G, g, d))
    xe = constrain(xe, "moe_dispatch")
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("tecd,edf->tecf", xe,
                               w["gate"].astype(x.dtype))) \
        * jnp.einsum("tecd,edf->tecf", xe, w["up"].astype(x.dtype))
    ye = jnp.einsum("tecf,efd->tecd", h, w["down"].astype(x.dtype))
    ye = constrain(ye, "moe_dispatch")
    # combine in the model dtype (§Perf C: the EP partial-sum all-reduce
    # over 'model' rides on this einsum's output — bf16 halves its bytes)
    y = jnp.einsum("tgec,tecd->tgd", comb.astype(x.dtype), ye)
    y = y.reshape(b, s, d).astype(x.dtype)
    return y + _shared(p, x), aux


def _scatter_moe(p, x, xg, idxg, pos, keep, gateg, cap, e_pad):
    """Scatter/gather dispatch: xe[G, e, c] += x[G, t] at (e, c) =
    (expert, slot) of each kept assignment; combine gathers back."""
    G, g, d = xg.shape
    top_k = idxg.shape[-1]
    gi = jnp.arange(G)[:, None, None]                   # (G,1,1)
    upd = xg[:, :, None, :] * keep[..., None].astype(xg.dtype)  # (G,g,k,d)
    xe = jnp.zeros((G, e_pad, cap, d), xg.dtype)
    # clip dropped slots to 0 — their update rows are zeroed anyway
    pos_c = jnp.minimum(pos, cap - 1)
    xe = xe.at[gi, idxg, pos_c].add(upd)
    from repro.sharding.hooks import constrain
    xe = constrain(xe, "moe_dispatch")
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("tecd,edf->tecf", xe,
                               w["gate"].astype(xg.dtype))) \
        * jnp.einsum("tecd,edf->tecf", xe, w["up"].astype(xg.dtype))
    ye = jnp.einsum("tecf,efd->tecd", h, w["down"].astype(xg.dtype))
    ye = constrain(ye, "moe_dispatch")
    picked = ye[gi, idxg, pos_c]                        # (G,g,k,d)
    wk = (gateg * keep.astype(gateg.dtype)).astype(xg.dtype)
    y = jnp.einsum("tgk,tgkd->tgd", wk, picked)
    b, s, _ = x.shape
    return y.reshape(b, s, d).astype(x.dtype)


def _shared(p, x):
    if "shared" not in p:
        return jnp.zeros((), x.dtype)
    return swiglu(p["shared"], x)


def _dense_moe(p, x, probs, n_experts, top_k):
    """Exact O(E) oracle: run every expert, weighted-sum the top-k."""
    gate_vals, top_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    e_pad = p["router"]["w"].shape[-1]
    w = p["experts"]

    def one_expert(gw, uw, dw):
        h = jax.nn.silu(x @ gw.astype(x.dtype)) * (x @ uw.astype(x.dtype))
        return h @ dw.astype(x.dtype)

    ys = jax.vmap(one_expert)(w["gate"], w["up"], w["down"])  # (E,B,S,D)
    weights = jnp.zeros(probs.shape, jnp.float32)
    for k in range(top_k):
        weights += gate_vals[..., k:k + 1] * jax.nn.one_hot(
            top_idx[..., k], e_pad, dtype=jnp.float32)
    y = jnp.einsum("ebsd,bse->bsd", ys.astype(jnp.float32), weights)
    return y.astype(x.dtype)
