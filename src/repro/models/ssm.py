"""Mamba2 (SSD) block — chunkwise-parallel training form + O(1) decode.

Training uses the chunked state-space-dual recurrence: a ``lax.scan`` over
sequence chunks carrying the inter-chunk state (B, H, P, N); within a
chunk the computation is the attention-like masked form. This is exactly
the structure of the Pallas kernel in ``repro.kernels/mamba2_scan`` (grid
over (B, H), sequential chunk loop); the jnp path here doubles as its
reference and as the CPU/lowering-friendly implementation.

All decay factors are exp of non-positive numbers (A < 0, dt > 0), so the
chunked form is numerically stable without extra rescaling.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.hooks import constrain

from .layers import linear, linear_init, rms_norm, rms_norm_init

CHUNK = 256


# ---------------------------------------------------------------------- #
# params
# ---------------------------------------------------------------------- #
def mamba2_init(key, d_model, d_inner, ssm_state, n_heads, d_conv=4,
                dtype=jnp.float32):
    assert d_inner % n_heads == 0, (d_inner, n_heads)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, h = ssm_state, n_heads
    d_in_proj = 2 * d_inner + 2 * n + h          # z, x, B, C, dt
    conv_ch = d_inner + 2 * n                    # x, B, C get convolved
    dt = jnp.exp(jax.random.uniform(k3, (h,),
                                    minval=jnp.log(1e-3),
                                    maxval=jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))      # inverse softplus
    return {
        "in_proj": linear_init(k1, d_model, d_in_proj, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (d_conv, conv_ch)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": rms_norm_init(d_inner, dtype),
        "out_proj": linear_init(k4, d_inner, d_model, dtype=dtype),
    }


def _split_proj(proj, d_inner, n, h):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., d_inner + d_inner + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, xbc: (B, S, C), w: (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    return jax.nn.silu(out + b.astype(xbc.dtype))


# ---------------------------------------------------------------------- #
# chunked SSD forward
# ---------------------------------------------------------------------- #
def ssd_chunked(x, dt, A, Bm, Cm, *, chunk=CHUNK,
                init_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """x: (B,S,H,P) f32, dt: (B,S,H) f32 (>0), A: (H,) f32 (<0),
    Bm/Cm: (B,S,N) f32. Returns y (B,S,H,P) [, final state (B,H,P,N)]."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk != 0:
        chunk = s  # degenerate small-sequence case
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)
    dA = A[None, None, None, :] * dtc            # (b,nc,L,h)  (<= 0)

    def step(state, inputs):
        xi, dti, Bi, Ci, dAi = inputs            # (b,L,h,p) ...
        cum = jnp.cumsum(dAi, axis=1)            # (b,L,h)
        total = cum[:, -1]                       # (b,h)
        # intra-chunk (attention-like) term; mask the exponent BEFORE exp
        # (i<j entries are exp of a positive number -> overflow otherwise)
        scores = jnp.einsum("bin,bjn->bij", Ci, Bi)          # (b,L,L)
        causal = jnp.tril(jnp.ones((xi.shape[1], xi.shape[1]), bool))
        diff = cum[:, :, None] - cum[:, None, :]             # (b,i,j,h)
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -jnp.inf))
        m = scores[..., None] * decay                        # (b,i,j,h)
        xdt = xi * dti[..., None]                             # (b,L,h,p)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xdt)
        # inter-chunk term
        y_inter = jnp.einsum("bin,bhpn->bihp", Ci, state) \
            * jnp.exp(cum)[..., None]                         # (b,L,h,p)
        # state update
        w = jnp.exp(total[:, None, :] - cum) * dti            # (b,L,h)
        s_local = jnp.einsum("blh,bln,blhp->bhpn", w, Bi, xi)
        state = jnp.exp(total)[..., None, None] * state + s_local
        return state, y_intra + y_inter

    state0 = (init_state if init_state is not None
              else jnp.zeros((b, h, p, n), jnp.float32))
    # scan over chunks: move nc to the front
    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3),
          dA.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    if return_state:
        return y, final
    return y


# ---------------------------------------------------------------------- #
# block forward (train / prefill)
# ---------------------------------------------------------------------- #
def mamba2_forward(p, x, *, d_inner, ssm_state, n_heads,
                   use_kernel: bool = False,
                   return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model)."""
    b, s, _ = x.shape
    n, h = ssm_state, n_heads
    pp = d_inner // h
    proj = linear(p["in_proj"], x)
    z, xbc, dt_raw = _split_proj(proj, d_inner, n, h)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = constrain(xbc, "act_inner")
    xs = xbc[..., :d_inner].astype(jnp.float32).reshape(b, s, h, pp)
    Bm = xbc[..., d_inner:d_inner + n].astype(jnp.float32)
    Cm = xbc[..., d_inner + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])          # (b,s,h)
    A = -jnp.exp(p["A_log"])
    if use_kernel:
        # Pallas SSD kernel (fwd + custom_vjp bwd; trainable, any S)
        from repro.kernels import mamba2_ops
        y = mamba2_ops.ssd(xs, dt, A, Bm, Cm)
        state = None
    else:
        out = ssd_chunked(xs, dt, A, Bm, Cm, return_state=return_state)
        y, state = out if return_state else (out, None)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    if return_state:
        return out, state
    return out


# ---------------------------------------------------------------------- #
# single-shot prefill (whole prompt -> populated decode cache)
# ---------------------------------------------------------------------- #
def mamba2_prefill(p, x, cache, *, d_inner, ssm_state, n_heads):
    """Process the full prompt (B, S, d_model) in one call, warm-starting
    from ``cache`` (conv window + SSM state) and returning the outputs
    plus the cache a per-token ``mamba2_decode`` loop would have left
    behind.  Same chunked SSD math as ``mamba2_forward``."""
    b, s, _ = x.shape
    n, h = ssm_state, n_heads
    pp = d_inner // h
    proj = linear(p["in_proj"], x)
    z, xbc_raw, dt_raw = _split_proj(proj, d_inner, n, h)
    # causal conv warm-started from the cached (d_conv - 1) raw rows
    k = p["conv_w"].shape[0]
    win = jnp.concatenate([cache["conv"].astype(xbc_raw.dtype), xbc_raw],
                          axis=1)                 # (B, k-1+S, C)
    conv = sum(win[:, i:i + s] * p["conv_w"][i].astype(win.dtype)
               for i in range(k))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(conv.dtype))
    new_conv = win[:, win.shape[1] - (k - 1):].astype(cache["conv"].dtype)
    xbc = constrain(xbc, "act_inner")
    xs = xbc[..., :d_inner].astype(jnp.float32).reshape(b, s, h, pp)
    Bm = xbc[..., d_inner:d_inner + n].astype(jnp.float32)
    Cm = xbc[..., d_inner + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs, dt, A, Bm, Cm,
                           init_state=cache["state"], return_state=True)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    return out, {"conv": new_conv, "state": constrain(state, "ssm_state")}


# ---------------------------------------------------------------------- #
# decode (single token, O(1) state)
# ---------------------------------------------------------------------- #
def mamba2_init_cache(batch, d_inner, ssm_state, n_heads, d_conv=4,
                      dtype=jnp.float32):
    conv_ch = d_inner + 2 * ssm_state
    return {
        "conv": jnp.zeros((batch, d_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, n_heads, d_inner // n_heads, ssm_state),
                           jnp.float32),
    }


def mamba2_decode(p, x, cache, *, d_inner, ssm_state, n_heads):
    """x: (B, 1, d_model) -> (y (B,1,d_model), new cache)."""
    b = x.shape[0]
    n, h = ssm_state, n_heads
    pp = d_inner // h
    proj = linear(p["in_proj"], x)[:, 0]          # (B, ...)
    z, xbc, dt_raw = _split_proj(proj, d_inner, n, h)
    # conv over [cache window, current]
    win = jnp.concatenate([cache["conv"],
                           xbc[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                          w.astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv = win[:, 1:]
    xs = xbc[..., :d_inner].reshape(b, h, pp)
    Bm = xbc[..., d_inner:d_inner + n]
    Cm = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(A[None] * dt)                    # (B, H)
    state = cache["state"]
    state = dA[..., None, None] * state + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm, xs)
    state = constrain(state, "ssm_state")
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z[:, None]))
    out = linear(p["out_proj"], y)
    return out, {"conv": new_conv, "state": state}
