"""Family-dispatching LM assembly for the 10 assigned architectures.

Every architecture is built from a repeating *unit* (``cfg.pattern_unit()``
layers) whose parameters are stacked with a leading ``n_units`` dimension
and executed with ``lax.scan`` (scan-over-layers keeps the HLO small and
the FSDP all-gather working set at one unit; DESIGN.md §7).

Public API (all functional, params are plain pytrees):
    init_params(cfg, key, dtype)             -> params
    forward(cfg, params, batch, ...)         -> (logits, aux)
    init_cache(cfg, params, batch, max_len)  -> decode cache
    decode_step(cfg, params, cache, tokens, index) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.hooks import constrain

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (embed, embed_init, gelu_mlp, gelu_mlp_init, linear,
                     linear_init, mrope_cos_sin, rms_norm, rms_norm_init,
                     rope_cos_sin, sinusoidal_positions, swiglu, swiglu_init,
                     unembed)


def _dtype(cfg: ArchConfig, override=None):
    if override is not None:
        return override
    return jnp.dtype(cfg.dtype)


def _stack(key, n: int, init_fn):
    """Stack ``n`` independent inits along a new leading axis."""
    keys = jax.random.split(key, n)
    inits = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


# ====================================================================== #
# per-family unit init
# ====================================================================== #
def _attn_block_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dtype),
        "ln2": rms_norm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def _moe_layer_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dtype),
        "ln2": rms_norm_init(cfg.d_model, dtype),
        "ffn": moe_mod.moe_init(
            k2, cfg.d_model, cfg.moe_experts, cfg.moe_d_ff or cfg.d_ff,
            shared_expert=cfg.moe_shared_expert,
            pad_to=getattr(cfg, "moe_pad_to", 0) or 0, dtype=dtype),
    }


def _unit_init(key, cfg: ArchConfig, dtype):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _attn_block_init(key, cfg, dtype)
    if fam == "moe":
        u = cfg.pattern_unit()
        keys = jax.random.split(key, u)
        unit = {}
        for i in range(u):
            is_moe = (i == u - 1)   # MoE is the last layer of the unit
            if is_moe:
                unit[f"sub{i}"] = _moe_layer_init(keys[i], cfg, dtype)
            else:
                unit[f"sub{i}"] = _attn_block_init(keys[i], cfg, dtype)
        return unit
    if fam == "hybrid":            # zamba2: u mamba layers (+ shared attn)
        u = cfg.pattern_unit()

        def one(k):
            return {
                "ln": rms_norm_init(cfg.d_model, dtype),
                "mamba": ssm_mod.mamba2_init(
                    k, cfg.d_model, cfg.d_inner, cfg.ssm_state,
                    cfg.n_ssm_heads, cfg.ssm_conv, dtype=dtype),
            }
        return {"mamba": _stack(key, u, one)}
    if fam == "ssm":               # xlstm: (u-1) mLSTM + 1 sLSTM
        u = cfg.pattern_unit()
        km, ks = jax.random.split(key)

        def one(k):
            return {
                "ln": rms_norm_init(cfg.d_model, dtype),
                "mlstm": xlstm_mod.mlstm_init(
                    k, cfg.d_model, cfg.d_inner, cfg.n_heads, dtype=dtype),
            }
        unit = {"mlstm": _stack(km, max(1, u - 1), one)}
        if cfg.slstm_every:
            unit["slstm"] = {
                "ln": rms_norm_init(cfg.d_model, dtype),
                "slstm": xlstm_mod.slstm_init(
                    ks, cfg.d_model, cfg.n_heads, dtype=dtype),
            }
        return unit
    if fam == "audio":             # whisper decoder unit (cross-attn)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": rms_norm_init(cfg.d_model, dtype),
            "attn": attn_mod.attention_init(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                dtype=dtype),
            "lnx": rms_norm_init(cfg.d_model, dtype),
            "xattn": attn_mod.attention_init(
                k2, cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.head_dim,
                dtype=dtype),
            "ln2": rms_norm_init(cfg.d_model, dtype),
            "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype),
        }
    raise ValueError(f"unknown family {fam}")


def init_params(cfg: ArchConfig, key, dtype=None) -> Dict[str, Any]:
    dt = _dtype(cfg, dtype)
    k_emb, k_units, k_extra, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "units": _stack(k_units, cfg.n_units,
                        lambda k: _unit_init(k, cfg, dt)),
        "ln_f": rms_norm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(k_head, cfg.d_model, cfg.vocab,
                                        dtype=dt)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = _attn_block_init(k_extra, cfg, dt)
    if cfg.is_encoder_decoder:
        ke1, ke2 = jax.random.split(k_extra)
        params["encoder"] = {
            "units": _stack(ke1, cfg.encoder_layers,
                            lambda k: _attn_block_init_audio(k, cfg, dt)),
            "ln_f": rms_norm_init(cfg.d_model, dt),
        }
    return params


def _attn_block_init_audio(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.head_dim,
            dtype=dtype),
        "ln2": rms_norm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ====================================================================== #
# position tables
# ====================================================================== #
def _rope_tables(cfg: ArchConfig, positions: jnp.ndarray):
    """positions: (S,) or (B, S). Returns (cos, sin) or (None, None)."""
    if not cfg.rope:
        return None, None
    if cfg.mrope_sections:
        pos3 = _mrope_positions(cfg, positions)
        return mrope_cos_sin(pos3, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    return rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)


def _mrope_positions(cfg: ArchConfig, positions: jnp.ndarray):
    """Qwen2-VL M-RoPE streams: text tokens use equal t/h/w; the stubbed
    vision prefix gets a (t=0, h, w) grid of width 32."""
    if positions.ndim == 1:
        positions = positions[None]
    tv = cfg.vision_tokens
    grid_w = 32
    is_vis = positions < tv
    h = jnp.where(is_vis, positions // grid_w, positions)
    w = jnp.where(is_vis, positions % grid_w, positions)
    t = jnp.where(is_vis, jnp.zeros_like(positions), positions)
    return jnp.stack([t, h, w])         # (3, B, S)


# ====================================================================== #
# unit forwards (training / prefill)
# ====================================================================== #
def _attn_block_fwd(p, cfg, x, cos, sin, window, use_kernels):
    h = attn_mod.attention(
        p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), cos, sin,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, window=window, use_kernel=use_kernels)
    x = x + h
    x = x + swiglu(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
    return constrain(x, "act_btd")


def _moe_layer_fwd(p, cfg, x, cos, sin, window, use_kernels):
    h = attn_mod.attention(
        p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), cos, sin,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, window=window, use_kernel=use_kernels)
    x = x + h
    y, aux = moe_mod.moe_forward(
        p["ffn"], rms_norm(p["ln2"], x, cfg.norm_eps),
        n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
        capacity_factor=cfg.moe_capacity_factor,
        dispatch=cfg.moe_dispatch)
    return constrain(x + y, "act_btd"), aux


def _make_unit_fwd(cfg: ArchConfig, shared_attn, cos, sin, window,
                   use_kernels):
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def unit_fwd(x, p):
            return _attn_block_fwd(p, cfg, x, cos, sin, window,
                                   use_kernels), jnp.zeros(())
    elif fam == "moe":
        u = cfg.pattern_unit()

        def unit_fwd(x, p):
            aux = jnp.zeros(())
            for i in range(u):
                sub = p[f"sub{i}"]
                if i == u - 1:
                    x, a = _moe_layer_fwd(sub, cfg, x, cos, sin, window,
                                          use_kernels)
                    aux = aux + a
                else:
                    x = _attn_block_fwd(sub, cfg, x, cos, sin, window,
                                        use_kernels)
            return x, aux
    elif fam == "hybrid":
        def unit_fwd(x, p):
            def layer(xc, lp):
                h = ssm_mod.mamba2_forward(
                    lp["mamba"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                    n_heads=cfg.n_ssm_heads, use_kernel=use_kernels)
                return constrain(xc + h, "act_btd"), None
            x, _ = jax.lax.scan(layer, x, p["mamba"])
            if shared_attn is not None:
                x = _attn_block_fwd(shared_attn, cfg, x, cos, sin, window,
                                    use_kernels)
            return x, jnp.zeros(())
    elif fam == "ssm":
        def unit_fwd(x, p):
            def layer(xc, lp):
                h = xlstm_mod.mlstm_forward(
                    lp["mlstm"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    d_inner=cfg.d_inner, n_heads=cfg.n_heads)
                return constrain(xc + h, "act_btd"), None
            x, _ = jax.lax.scan(layer, x, p["mlstm"])
            if "slstm" in p:
                h = xlstm_mod.slstm_forward(
                    p["slstm"]["slstm"],
                    rms_norm(p["slstm"]["ln"], x, cfg.norm_eps),
                    n_heads=cfg.n_heads)
                x = constrain(x + h, "act_btd")
            return x, jnp.zeros(())
    else:
        raise ValueError(fam)
    return unit_fwd


def _scan_units(x, units_params, unit_fwd, remat: bool):
    f = jax.checkpoint(unit_fwd) if remat else unit_fwd

    def body(carry, p):
        x, aux = carry
        x, a = f(x, p)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros(())), units_params)
    return x, aux


# ====================================================================== #
# full forward
# ====================================================================== #
def forward(cfg: ArchConfig, params, batch: Dict[str, jnp.ndarray], *,
            remat: bool = True, use_kernels: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {"tokens": (B,S) i32 [, "vision_embeds" (B,Tv,D),
    "frames" (B,Senc,D)]} -> (logits (B,S,V), aux)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens, dt)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        tv = batch["vision_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(dt), x[:, tv:]], axis=1)
    x = constrain(x, "act_btd")

    if cfg.is_encoder_decoder:
        enc = encode(cfg, params, batch["frames"], remat=remat)
        return _decoder_forward(cfg, params, x, enc, remat=remat)

    positions = jnp.arange(s)
    cos, sin = _rope_tables(cfg, positions)
    if cfg.family == "ssm" and not cfg.rope:
        cos = sin = None
    shared = params.get("shared_attn")
    unit_fwd = _make_unit_fwd(cfg, shared, cos, sin, cfg.sliding_window,
                              use_kernels)
    x, aux = _scan_units(x, params["units"], unit_fwd, remat)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    return constrain(logits, "logits"), aux


def _lm_head(cfg, params, x):
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return linear(params["lm_head"], x)


# ---------------------------------------------------------------------- #
# whisper encoder / decoder
# ---------------------------------------------------------------------- #
def encode(cfg: ArchConfig, params, frames, *, remat: bool = True):
    """frames: (B, Senc, D) stubbed conv-frontend output."""
    dt = _dtype(cfg)
    b, s, _ = frames.shape
    pos = sinusoidal_positions(jnp.arange(s), cfg.d_model).astype(dt)
    x = frames.astype(dt) + pos[None]

    def unit_fwd(x, p):
        h = attn_mod.attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), None, None,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
            head_dim=cfg.head_dim, causal=False)
        x = x + h
        x = x + gelu_mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
        return constrain(x, "act_btd"), jnp.zeros(())

    x, _ = _scan_units(x, params["encoder"]["units"], unit_fwd, remat)
    return rms_norm(params["encoder"]["ln_f"], x, cfg.norm_eps)


def _decoder_forward(cfg, params, x, enc, *, remat: bool):
    b, s, _ = x.shape
    dt = x.dtype
    pos = sinusoidal_positions(jnp.arange(s), cfg.d_model).astype(dt)
    x = x + pos[None]

    def unit_fwd(x, p):
        h = attn_mod.attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), None, None,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
            head_dim=cfg.head_dim, causal=True)
        x = x + h
        # cross attention over encoder output
        xq = rms_norm(p["lnx"], x, cfg.norm_eps)
        h = _cross_attention(p["xattn"], cfg, xq, enc)
        x = x + h
        x = x + gelu_mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
        return constrain(x, "act_btd"), jnp.zeros(())

    x, aux = _scan_units(x, params["units"], unit_fwd, remat)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    return _lm_head(cfg, params, x), aux


def _cross_attention(p, cfg, xq, enc):
    b, s, _ = xq.shape
    se = enc.shape[1]
    hd, nh = cfg.head_dim, cfg.n_heads
    q = linear(p["wq"], xq).reshape(b, s, nh, hd)
    k = linear(p["wk"], enc).reshape(b, se, nh, hd)
    v = linear(p["wv"], enc).reshape(b, se, nh, hd)
    out = attn_mod.full_attention(q, k, v, causal=False)
    return linear(p["wo"], out.reshape(b, s, nh * hd))


# ====================================================================== #
# decode (serving)
# ====================================================================== #
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> Any:
    """Zeroed decode cache pytree (stacked over units). ``max_len`` is the
    KV-cache length; sliding-window archs allocate min(window, max_len)."""
    dt = _dtype(cfg, dtype)
    fam = cfg.family
    win = cfg.sliding_window
    kv_len = min(win, max_len) if win else max_len

    def kv(h_kv):
        return {"k": jnp.zeros((batch, kv_len, h_kv, cfg.head_dim), dt),
                "v": jnp.zeros((batch, kv_len, h_kv, cfg.head_dim), dt)}

    def unit_cache():
        if fam in ("dense", "vlm"):
            return kv(cfg.n_kv_heads)
        if fam == "moe":
            return {f"sub{i}": kv(cfg.n_kv_heads)
                    for i in range(cfg.pattern_unit())}
        if fam == "hybrid":
            u = cfg.pattern_unit()
            m = ssm_mod.mamba2_init_cache(
                batch, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                cfg.ssm_conv, dt)
            return {"mamba": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (u,) + t.shape), m),
                "shared": kv(cfg.n_kv_heads)}
        if fam == "ssm":
            u = cfg.pattern_unit()
            mc = xlstm_mod.mlstm_init_cache(batch, cfg.d_inner, cfg.n_heads)
            cache = {"mlstm": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (max(1, u - 1),) + t.shape),
                mc)}
            if cfg.slstm_every:
                cache["slstm"] = xlstm_mod.slstm_init_cache(
                    batch, cfg.d_model)
            return cache
        if fam == "audio":
            cross = {"k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_heads,
                                     cfg.head_dim), dt),
                     "v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_heads,
                                     cfg.head_dim), dt)}
            return {"self": kv(cfg.n_heads), "cross": cross}
        raise ValueError(fam)

    units = jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.n_units,) + t.shape),
        unit_cache())
    return {"units": units, "index": jnp.zeros((), jnp.int32)}


def init_paged_cache(cfg: ArchConfig, batch: int, max_len: int, *,
                     page_size: int, n_pages: int, dtype=None) -> Any:
    """Paged decode cache (DESIGN.md §15): every *linear-layout* KV leaf
    — the ``{"k","v"}`` caches that ``init_cache`` allocates densely as
    ``(batch, max_len, Hkv, D)`` — becomes a shared pool
    ``(n_pages, page_size, Hkv, D)`` addressed through one top-level
    block table ``cache["pages"]: (batch, max_len // page_size) i32``
    (-1 = unassigned).  One table serves every attention leaf because all
    of them write the same row position each step.  Non-attention state
    (SSM, conv, mLSTM) and non-linear layouts (sliding-window rings,
    whisper cross K/V) keep their dense per-slot allocation — they are
    O(1) per slot, not O(max_len).

    ``max_len % page_size == 0`` is required: the jnp read path gathers
    the table into a ``(batch, P * page_size, ...)`` view whose shape
    must equal the dense cache for bitwise token identity."""
    assert max_len % page_size == 0, (max_len, page_size)
    assert cfg.sliding_window == 0, \
        "paged KV requires the linear cache layout (window == 0)"
    dt = _dtype(cfg, dtype)

    def paged_kv(h_kv):
        return {"k": jnp.zeros((n_pages, page_size, h_kv, cfg.head_dim),
                               dt),
                "v": jnp.zeros((n_pages, page_size, h_kv, cfg.head_dim),
                               dt)}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        unit = paged_kv(cfg.n_kv_heads)
    elif fam == "moe":
        unit = {f"sub{i}": paged_kv(cfg.n_kv_heads)
                for i in range(cfg.pattern_unit())}
    elif fam == "hybrid":
        u = cfg.pattern_unit()
        m = ssm_mod.mamba2_init_cache(
            batch, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
            cfg.ssm_conv, dt)
        unit = {"mamba": jax.tree.map(
            lambda t: jnp.broadcast_to(t, (u,) + t.shape), m),
            "shared": paged_kv(cfg.n_kv_heads)}
    elif fam == "audio":
        cross = {"k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_heads,
                                 cfg.head_dim), dt),
                 "v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_heads,
                                 cfg.head_dim), dt)}
        unit = {"self": paged_kv(cfg.n_heads), "cross": cross}
    else:
        raise ValueError(f"family {fam!r} has no linear KV cache to page")

    units = jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.n_units,) + t.shape), unit)
    return {"units": units, "index": jnp.zeros((), jnp.int32),
            "pages": jnp.full((batch, max_len // page_size), -1,
                              jnp.int32)}


def prefill_cache_whisper(cfg, params, frames, batch, max_len, dtype=None):
    """Whisper: run the encoder once, precompute per-layer cross K/V."""
    cache = init_cache(cfg, batch, max_len, dtype)
    enc = encode(cfg, params, frames, remat=False)
    b, se, _ = enc.shape

    def per_unit(p):
        k = linear(p["xattn"]["wk"], enc).reshape(
            b, se, cfg.n_heads, cfg.head_dim)
        v = linear(p["xattn"]["wv"], enc).reshape(
            b, se, cfg.n_heads, cfg.head_dim)
        return k, v

    ks, vs = jax.vmap(per_unit)(params["units"])    # (U, B, Se, H, D)
    cross = cache["units"]["cross"]
    pad = cross["k"].shape[2] - ks.shape[2]
    if pad >= 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        ks, vs = ks[:, :, :cross["k"].shape[2]], vs[:, :, :cross["k"].shape[2]]
    cache["units"]["cross"] = {"k": ks.astype(cross["k"].dtype),
                               "v": vs.astype(cross["v"].dtype)}
    cache["cross_len"] = jnp.asarray(min(se, cross["k"].shape[2]), jnp.int32)
    return cache


def prefill(cfg: ArchConfig, params, cache, tokens, *,
            use_kernels: bool = False) -> Tuple[jnp.ndarray, Any]:
    """Single-shot prefill: populate a FRESH decode cache (index 0) from
    the whole prompt in ONE call instead of S sequential ``decode_step``
    dispatches.  tokens: (B, S) i32; for whisper, ``cache`` comes from
    ``prefill_cache_whisper`` (cross K/V already populated).

    Returns (logits (B, S, V), cache): the logits match teacher-forced
    ``forward`` position by position, and the cache is the one a
    per-token decode_step loop would have produced (KV rows / ring slots
    / SSM, conv, mLSTM, sLSTM states), with ``index`` advanced to S."""
    dt = _dtype(cfg)
    b, s = tokens.shape
    fam = cfg.family
    win = cfg.sliding_window
    x = embed(params["embed"], tokens, dt)
    cos = sin = None
    if cfg.is_encoder_decoder:
        pos = sinusoidal_positions(jnp.arange(s), cfg.d_model).astype(dt)
        x = x + pos[None]
    else:
        cos, sin = _rope_tables(cfg, jnp.arange(s))

    shared = params.get("shared_attn")
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               head_dim=cfg.head_dim, window=win, use_kernel=use_kernels)

    def unit_prefill(x, p, c):
        new_c = c
        if fam in ("dense", "vlm"):
            h, kv = attn_mod.attention_prefill(
                p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps),
                cos, sin, c, **akw)
            x = x + h
            x = x + swiglu(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
            new_c = kv
        elif fam == "moe":
            new_c = dict(c)
            u = cfg.pattern_unit()
            for i in range(u):
                sub = p[f"sub{i}"]
                h, kv = attn_mod.attention_prefill(
                    sub["attn"], rms_norm(sub["ln1"], x, cfg.norm_eps),
                    cos, sin, c[f"sub{i}"], **akw)
                x = x + h
                hn = rms_norm(sub["ln2"], x, cfg.norm_eps)
                if i == u - 1:
                    y, _ = moe_mod.moe_forward(
                        sub["ffn"], hn, n_experts=cfg.moe_experts,
                        top_k=cfg.moe_top_k,
                        capacity_factor=cfg.moe_capacity_factor,
                        dispatch=cfg.moe_dispatch)
                else:
                    y = swiglu(sub["mlp"], hn)
                x = x + y
                new_c[f"sub{i}"] = kv
        elif fam == "hybrid":
            def layer(carry, pc):
                xc = carry
                lp, lc = pc
                h, nc = ssm_mod.mamba2_prefill(
                    lp["mamba"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    lc, d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                    n_heads=cfg.n_ssm_heads)
                return xc + h, nc
            x, new_mamba = jax.lax.scan(layer, x, (p["mamba"], c["mamba"]))
            new_c = {"mamba": new_mamba, "shared": c["shared"]}
            if shared is not None:
                h, kv = attn_mod.attention_prefill(
                    shared["attn"], rms_norm(shared["ln1"], x, cfg.norm_eps),
                    cos, sin, c["shared"], **akw)
                x = x + h
                x = x + swiglu(shared["mlp"],
                               rms_norm(shared["ln2"], x, cfg.norm_eps))
                new_c["shared"] = kv
        elif fam == "ssm":
            def layer(carry, pc):
                xc = carry
                lp, lc = pc
                h, nc = xlstm_mod.mlstm_prefill(
                    lp["mlstm"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    lc, d_inner=cfg.d_inner, n_heads=cfg.n_heads)
                return xc + h, nc
            x, new_m = jax.lax.scan(layer, x, (p["mlstm"], c["mlstm"]))
            new_c = {"mlstm": new_m}
            if "slstm" in p:
                # slstm_decode scans any S — it doubles as the prefill
                h, nc = xlstm_mod.slstm_decode(
                    p["slstm"]["slstm"],
                    rms_norm(p["slstm"]["ln"], x, cfg.norm_eps),
                    c["slstm"], n_heads=cfg.n_heads)
                x = x + h
                new_c["slstm"] = nc
        elif fam == "audio":
            h, kv = attn_mod.attention_prefill(
                p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps),
                None, None, c["self"],
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                head_dim=cfg.head_dim, window=0, use_kernel=use_kernels)
            x = x + h
            xq = rms_norm(p["lnx"], x, cfg.norm_eps)
            h = _cross_attention_cached(p["xattn"], cfg, xq, c["cross"],
                                        cache.get("cross_len"))
            x = x + h
            x = x + gelu_mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
            new_c = {"self": kv, "cross": c["cross"]}
        else:
            raise ValueError(fam)
        return x, new_c

    def body(x, pc):
        p, c = pc
        return unit_prefill(x, p, c)

    x = constrain(x, "act_btd")
    x, new_units = jax.lax.scan(body, x, (params["units"], cache["units"]))
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    new_cache = dict(cache)
    new_cache["units"] = new_units
    new_cache["index"] = jnp.full_like(cache["index"], s)
    return constrain(logits, "logits"), new_cache


def prefill_extend(cfg: ArchConfig, params, cache, tokens, *,
                   start: int) -> Tuple[jnp.ndarray, Any]:
    """Suffix prefill (DESIGN.md §18): continue a cache whose rows
    ``[0, start)`` are already populated — the prefix-shared serving path
    gathers a request's matched prompt prefix out of the page pool and
    computes only the un-cached suffix here.  tokens: (B, S_suffix) i32
    at absolute positions ``start .. start+S-1``.

    Families with position-local per-layer state only (dense / vlm /
    moe): attention is the sole cross-position op, so every suffix row's
    hidden state — and therefore the K/V rows and logits — is BITWISE
    identical to the same rows of a full ``prefill`` (suffix >= 2 rows;
    see ``attention_prefill_extend``).  SSM/conv state (hybrid, ssm)
    would need a snapshot at ``start`` and is rejected.  MoE caveat: the
    router's capacity semantics see only the suffix tokens, mirroring
    the one-shot-prefill caveat in ``serve.generate`` — at generous
    capacity factors (no drops) routing is per-token and identity holds.

    Returns (logits (B, S_suffix, V), cache with index start+S)."""
    assert cfg.family in ("dense", "vlm", "moe"), \
        f"prefill_extend requires position-local state; family " \
        f"{cfg.family!r} carries recurrent state across positions"
    assert cfg.sliding_window == 0, "linear cache layout only"
    dt = _dtype(cfg)
    b, s = tokens.shape
    fam = cfg.family
    x = embed(params["embed"], tokens, dt)
    cos, sin = _rope_tables(cfg, jnp.arange(start, start + s))
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               head_dim=cfg.head_dim, start=start)

    def unit_extend(x, p, c):
        if fam in ("dense", "vlm"):
            h, kv = attn_mod.attention_prefill_extend(
                p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps),
                cos, sin, c, **akw)
            x = x + h
            x = x + swiglu(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
            return x, kv
        new_c = dict(c)                                     # moe
        u = cfg.pattern_unit()
        for i in range(u):
            sub = p[f"sub{i}"]
            h, kv = attn_mod.attention_prefill_extend(
                sub["attn"], rms_norm(sub["ln1"], x, cfg.norm_eps),
                cos, sin, c[f"sub{i}"], **akw)
            x = x + h
            hn = rms_norm(sub["ln2"], x, cfg.norm_eps)
            if i == u - 1:
                y, _ = moe_mod.moe_forward(
                    sub["ffn"], hn, n_experts=cfg.moe_experts,
                    top_k=cfg.moe_top_k,
                    capacity_factor=cfg.moe_capacity_factor,
                    dispatch=cfg.moe_dispatch)
            else:
                y = swiglu(sub["mlp"], hn)
            x = x + y
            new_c[f"sub{i}"] = kv
        return x, new_c

    def body(x, pc):
        p, c = pc
        return unit_extend(x, p, c)

    x = constrain(x, "act_btd")
    x, new_units = jax.lax.scan(body, x, (params["units"], cache["units"]))
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    new_cache = dict(cache)
    new_cache["units"] = new_units
    new_cache["index"] = jnp.full_like(cache["index"], start + s)
    return constrain(logits, "logits"), new_cache


def decode_step(cfg: ArchConfig, params, cache, tokens, *,
                index=None, use_kernels: bool = False
                ) -> Tuple[jnp.ndarray, Any]:
    """tokens: (B, 1) i32; index: absolute position, scalar or per-example
    (B,) vector (defaults to cache['index']). Returns (logits (B,1,V),
    new cache).  ``use_kernels=True`` routes linear-layout KV attention
    through the Pallas flash-decode kernel."""
    dt = _dtype(cfg)
    b = tokens.shape[0]
    idx = cache["index"] if index is None else jnp.asarray(index)
    x = embed(params["embed"], tokens, dt)
    fam = cfg.family
    win = cfg.sliding_window

    if cfg.is_encoder_decoder:
        pos = sinusoidal_positions(idx if idx.ndim else idx[None],
                                   cfg.d_model).astype(dt)
        x = x + pos[:, None]                     # (B or 1, 1, D)
    else:
        positions = idx[:, None] if idx.ndim else idx[None][None]
        cos, sin = _rope_tables(cfg, positions)  # (B or 1, S=1) positions
        if cos is not None and cos.shape[0] == 1:
            cos = jnp.broadcast_to(cos, (b,) + cos.shape[1:])
            sin = jnp.broadcast_to(sin, (b,) + sin.shape[1:])

    shared = params.get("shared_attn")
    pages = cache.get("pages")        # paged KV block table (B, P) or None
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               head_dim=cfg.head_dim, window=win, use_kernel=use_kernels,
               pages=pages)

    def unit_step(x, p, c):
        new_c = c
        if fam in ("dense", "vlm"):
            h, kv = attn_mod.attention_decode(
                p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps),
                cos, sin, c, idx, **akw)
            x = x + h
            x = x + swiglu(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
            new_c = kv
        elif fam == "moe":
            new_c = dict(c)
            u = cfg.pattern_unit()
            for i in range(u):
                sub = p[f"sub{i}"]
                h, kv = attn_mod.attention_decode(
                    sub["attn"], rms_norm(sub["ln1"], x, cfg.norm_eps),
                    cos, sin, c[f"sub{i}"], idx, **akw)
                x = x + h
                hn = rms_norm(sub["ln2"], x, cfg.norm_eps)
                if i == u - 1:
                    y, _ = moe_mod.moe_forward(
                        sub["ffn"], hn, n_experts=cfg.moe_experts,
                        top_k=cfg.moe_top_k,
                        capacity_factor=cfg.moe_capacity_factor,
                        dispatch=cfg.moe_dispatch)
                else:
                    y = swiglu(sub["mlp"], hn)
                x = x + y
                new_c[f"sub{i}"] = kv
        elif fam == "hybrid":
            def layer(carry, pc):
                xc = carry
                lp, lc = pc
                h, nc = ssm_mod.mamba2_decode(
                    lp["mamba"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    lc, d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                    n_heads=cfg.n_ssm_heads)
                return xc + h, nc
            x, new_mamba = jax.lax.scan(layer, x, (p["mamba"], c["mamba"]))
            new_c = {"mamba": new_mamba, "shared": c["shared"]}
            if shared is not None:
                h, kv = attn_mod.attention_decode(
                    shared["attn"], rms_norm(shared["ln1"], x, cfg.norm_eps),
                    cos, sin, c["shared"], idx, **akw)
                x = x + h
                x = x + swiglu(shared["mlp"],
                               rms_norm(shared["ln2"], x, cfg.norm_eps))
                new_c["shared"] = kv
        elif fam == "ssm":
            def layer(carry, pc):
                xc = carry
                lp, lc = pc
                h, nc = xlstm_mod.mlstm_decode(
                    lp["mlstm"], rms_norm(lp["ln"], xc, cfg.norm_eps),
                    lc, d_inner=cfg.d_inner, n_heads=cfg.n_heads)
                return xc + h, nc
            x, new_m = jax.lax.scan(layer, x, (p["mlstm"], c["mlstm"]))
            new_c = {"mlstm": new_m}
            if "slstm" in p:
                h, nc = xlstm_mod.slstm_decode(
                    p["slstm"]["slstm"],
                    rms_norm(p["slstm"]["ln"], x, cfg.norm_eps),
                    c["slstm"], n_heads=cfg.n_heads)
                x = x + h
                new_c["slstm"] = nc
        elif fam == "audio":
            h, kv = attn_mod.attention_decode(
                p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps),
                None, None, c["self"], idx,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                head_dim=cfg.head_dim, window=0, use_kernel=use_kernels,
                pages=pages)
            x = x + h
            xq = rms_norm(p["lnx"], x, cfg.norm_eps)
            h = _cross_decode(p["xattn"], cfg, xq, c["cross"],
                              cache.get("cross_len"))
            x = x + h
            x = x + gelu_mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
            new_c = {"self": kv, "cross": c["cross"]}
        else:
            raise ValueError(fam)
        return x, new_c

    def body(x, pc):
        p, c = pc
        return unit_step(x, p, c)

    x = constrain(x, "act_btd")
    x, new_units = jax.lax.scan(body, x, (params["units"], cache["units"]))
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    new_cache = dict(cache)
    new_cache["units"] = new_units
    new_cache["index"] = idx + 1
    return constrain(logits, "logits"), new_cache


def _cross_attention_cached(p, cfg, xq, cross, cross_len):
    """Cross attention of S query positions against cached (padded)
    encoder K/V, masked to the ``cross_len`` valid prefix."""
    b, s, _ = xq.shape
    hd, nh = cfg.head_dim, cfg.n_heads
    q = linear(p["wq"], xq).reshape(b, s, nh, hd)
    k, v = cross["k"], cross["v"]
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cross_len is not None:
        valid = jnp.arange(k.shape[1])[None, :] < cross_len
        scores = jnp.where(valid[:, None, None, :], scores, attn_mod.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return linear(p["wo"], out.astype(xq.dtype).reshape(b, s, nh * hd))


def _cross_decode(p, cfg, xq, cross, cross_len):
    return _cross_attention_cached(p, cfg, xq, cross, cross_len)
