"""GQA attention: full, chunked (flash-style, jnp — the lowering-friendly
path used for long sequences; the Pallas TPU kernel in ``repro.kernels``
implements the same algorithm and is TRAINABLE — its ``custom_vjp``
backward is a recompute-based Pallas kernel, so ``use_kernel=True`` works
under ``jax.grad`` at any sequence length), and single-token decode
against a KV cache.

Sliding-window masking supports the sub-quadratic dense variants used by
``long_500k`` (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.hooks import constrain

from .layers import apply_rope, linear, linear_init

NEG_INF = -1e30

# sequences at or above this length take the chunked (flash-style) path
CHUNKED_THRESHOLD = 8192
Q_CHUNK = 1024
KV_CHUNK = 1024


# ---------------------------------------------------------------------- #
# params
# ---------------------------------------------------------------------- #
def attention_init(key, d_model, n_heads, n_kv_heads, head_dim,
                   qkv_bias=False, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": linear_init(kq, d_model, n_heads * head_dim, bias=qkv_bias,
                          dtype=dtype),
        "wk": linear_init(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                          dtype=dtype),
        "wv": linear_init(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                          dtype=dtype),
        "wo": linear_init(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D)."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, groups, axis=2)


# ---------------------------------------------------------------------- #
# full (quadratic) attention — short sequences
# ---------------------------------------------------------------------- #
def full_attention(q, k, v, *, causal=True, window=0,
                   q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, H, D). ``q_offset`` is the absolute
    position of q[0] (decode: Sk-1).

    Mixed precision (§Perf iteration A1): for bf16 inputs the QK/PV
    matmuls run in bf16 with f32 accumulation (preferred_element_type)
    and the probabilities are cast to bf16 before PV — no f32 copies of
    q/k/v/probs ever hit HBM. f32 inputs (tests) keep the exact path."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5
    low = q.dtype == jnp.bfloat16
    if low:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
    else:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if low:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(jnp.bfloat16), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------- #
# chunked (flash-style) attention — long sequences, O(S * chunk) memory
# ---------------------------------------------------------------------- #
def chunked_attention(q, k, v, *, causal=True, window=0,
                      q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK) -> jnp.ndarray:
    """Two-level scan with running (max, sum, acc) — the flash-attention
    recurrence in pure jnp. Same math as ``full_attention``."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = d ** -0.5

    qc = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    kc = k.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)

    kpos = (jnp.arange(nk)[:, None] * kv_chunk + jnp.arange(kv_chunk))

    low = q.dtype == jnp.bfloat16   # §Perf A1: bf16 matmuls, f32 accum

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = iq * q_chunk + jnp.arange(q_chunk)
        qif = qi if low else qi.astype(jnp.float32) * scale

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            ki, vi, kp = kv_and_idx
            if low:
                s = jnp.einsum("bhqd,bhkd->bhqk", qif, ki,
                               preferred_element_type=jnp.float32) * scale
            else:
                s = jnp.einsum("bhqd,bhkd->bhqk", qif,
                               ki.astype(jnp.float32))
            mask = jnp.ones((q_chunk, kv_chunk), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kp[None, :]
            if window > 0:
                mask &= kp[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            if low:
                pv = jnp.einsum("bhqk,bhkd->bhqd",
                                p.astype(jnp.bfloat16), vi,
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bhqk,bhkd->bhqd", p,
                                vi.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # outs: (nq, B, H, qc, D) -> (B, S, H, D)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d)


# ---------------------------------------------------------------------- #
# module-level forward
# ---------------------------------------------------------------------- #
def attention(p, x, cos, sin, *, n_heads, n_kv_heads, head_dim,
              causal=True, window=0, use_kernel: bool = False
              ) -> jnp.ndarray:
    """Training / prefill attention over the whole sequence.

    cos/sin: RoPE tables (may be None for NoPE/xLSTM-style blocks)."""
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "act_heads")
    groups = n_heads // n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if use_kernel:
        # Pallas flash kernel (fwd + custom_vjp bwd); pads internally, so
        # every configs/ sequence length is eligible.
        from repro.kernels import flash_attention_ops
        out = flash_attention_ops.flash_attention(
            q, k, v, causal=causal, window=window)
    elif s >= CHUNKED_THRESHOLD:
        out = chunked_attention(q, k, v, causal=causal, window=window)
    else:
        out = full_attention(q, k, v, causal=causal, window=window)
    out = constrain(out, "act_heads")
    return linear(p["wo"], out.reshape(b, s, n_heads * head_dim))


def attention_prefill(p, x, cos, sin, cache, *, n_heads, n_kv_heads,
                      head_dim, window=0, use_kernel: bool = False
                      ) -> Tuple[jnp.ndarray, dict]:
    """Single-shot prefill: attend over the whole prompt (same math as
    ``attention``) AND write the per-position K/V rows into a FRESH decode
    cache.  x: (B, S, D); cache: {"k","v"} (B, S_cache, Hkv, D) — linear
    layout (slot t == position t) when ``window == 0``, ring-buffered
    (slot t == t % S_cache) when ``window > 0``.  The cache must start at
    index 0; callers continue decoding at absolute position S."""
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "act_heads")
    s_cache = cache["k"].shape[1]
    if window > 0:
        # ring buffer: only the last min(S, S_cache) positions survive;
        # their slots (t % S_cache) are distinct, so one scatter suffices.
        keep = min(s, s_cache)
        slots = (jnp.arange(s - keep, s) % s_cache).astype(jnp.int32)
        ck = cache["k"].at[:, slots].set(
            k[:, s - keep:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(
            v[:, s - keep:].astype(cache["v"].dtype))
    else:
        assert s <= s_cache, (s, s_cache)
        ck = cache["k"].at[:, :s].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, :s].set(v.astype(cache["v"].dtype))
    ck = constrain(ck, "kv_cache")
    cv = constrain(cv, "kv_cache")

    groups = n_heads // n_kv_heads
    kk = _repeat_kv(k, groups)
    vv = _repeat_kv(v, groups)
    if use_kernel:
        from repro.kernels import flash_attention_ops
        out = flash_attention_ops.flash_attention(
            q, kk, vv, causal=True, window=window)
    elif s >= CHUNKED_THRESHOLD:
        out = chunked_attention(q, kk, vv, causal=True, window=window)
    else:
        out = full_attention(q, kk, vv, causal=True, window=window)
    out = constrain(out, "act_heads")
    return (linear(p["wo"], out.reshape(b, s, n_heads * head_dim)),
            {"k": ck, "v": cv})


def attention_prefill_extend(p, x, cos, sin, cache, *, start, n_heads,
                             n_kv_heads, head_dim
                             ) -> Tuple[jnp.ndarray, dict]:
    """Suffix prefill (DESIGN.md §18): rows ``[0, start)`` of the linear
    cache are already populated (a shared-prefix gather); write rows
    ``[start, start+s)`` and attend the suffix queries over rows
    ``[0, start+s)``.  x: (B, S_suffix, D) — already the residual stream
    of the suffix positions only.

    Because attention rows are independent (each output row reduces over
    the same key extent), the outputs and cache rows are BITWISE
    identical to the corresponding rows of ``attention_prefill`` over
    the full sequence — provided the suffix has >= 2 rows (a single-row
    matmul dispatches to a different XLA accumulation path) and the
    cache dtype equals the compute dtype (prefix rows are read back
    through the cache here, but attended uncast in full prefill).
    Linear layout only; the flash kernel assumes q/k aligned, so this
    path is always jnp."""
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "act_heads")
    s_cache = cache["k"].shape[1]
    assert start + s <= s_cache, (start, s, s_cache)
    ck = cache["k"].at[:, start:start + s].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[:, start:start + s].set(v.astype(cache["v"].dtype))
    ck = constrain(ck, "kv_cache")
    cv = constrain(cv, "kv_cache")

    groups = n_heads // n_kv_heads
    kk = _repeat_kv(ck[:, :start + s], groups)
    vv = _repeat_kv(cv[:, :start + s], groups)
    out = full_attention(q, kk, vv, causal=True, q_offset=start)
    out = constrain(out, "act_heads")
    return (linear(p["wo"], out.reshape(b, s, n_heads * head_dim)),
            {"k": ck, "v": cv})


def attention_decode(p, x, cos, sin, cache, index, *, n_heads, n_kv_heads,
                     head_dim, window=0, use_kernel: bool = False,
                     pages=None) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache: {"k","v"} (B, S_cache, Hkv, D)
    ring-buffered when ``window > 0`` (S_cache == window), else linear
    (S_cache == max_len). ``index`` is the absolute decode position (B,)
    or scalar.  ``use_kernel=True`` takes the Pallas flash-decode kernel
    for the linear layout (the ring buffer's valid set is not a prefix,
    so it keeps the jnp path).

    ``pages`` switches the cache to the PAGED layout (DESIGN.md §15):
    cache k/v are shared pools ``(N_pages, page_size, Hkv, D)`` and
    ``pages`` is the per-example block table ``(B, P)`` mapping logical
    page ``index // page_size`` to a pool page (-1 = unassigned).  Linear
    layout only (``window == 0``)."""
    b, one, _ = x.shape
    assert one == 1
    q = linear(p["wq"], x).reshape(b, 1, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, 1, n_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, 1, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if pages is not None:
        assert window == 0, "paged KV requires the linear layout"
        return _attention_decode_paged(
            p, q, k, v, cache, index, pages, n_heads=n_heads,
            n_kv_heads=n_kv_heads, head_dim=head_dim,
            use_kernel=use_kernel, out_dtype=x.dtype)
    s_cache = cache["k"].shape[1]
    index = jnp.asarray(index)
    slot = index % s_cache if window > 0 else index  # ring buffer vs linear
    if index.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    else:
        ck = _scatter_rows(cache["k"], k, slot)
        cv = _scatter_rows(cache["v"], v, slot)
    ck = constrain(ck, "kv_cache")
    cv = constrain(cv, "kv_cache")

    groups = n_heads // n_kv_heads
    kk = _repeat_kv(ck, groups)
    vv = _repeat_kv(cv, groups)
    idx = index if index.ndim > 0 else index[None]
    if use_kernel and window == 0:
        from repro.kernels import flash_attention_ops
        lengths = jnp.broadcast_to(idx + 1, (b,))
        out = flash_attention_ops.flash_decode(q, kk, vv, lengths)
    else:
        scale = head_dim ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            kk.astype(jnp.float32)) * scale
        kpos = jnp.arange(s_cache)[None, :]             # (1, S)
        if window > 0:
            # ring buffer: reconstruct the absolute position held by each
            # slot; valid iff written and within the window.
            abs_pos = _ring_abs_pos(idx, s_cache)       # (B, S)
            valid = (abs_pos <= idx[:, None]) \
                & (abs_pos > idx[:, None] - window) & (abs_pos >= 0)
        else:
            valid = kpos <= idx[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, n_heads * head_dim)
    return linear(p["wo"], out), {"k": ck, "v": cv}


def _attention_decode_paged(p, q, k, v, cache, index, pages, *, n_heads,
                            n_kv_heads, head_dim, use_kernel, out_dtype
                            ) -> Tuple[jnp.ndarray, dict]:
    """Paged one-token decode: write this step's K/V row into the pool
    page that owns position ``index``, then attend over the pages listed
    in the block table.

    The jnp path gathers the table back into a ``(B, P*page_size, ...)``
    view — the same shape, row content, and masked-softmax reduction as
    the dense linear cache (``P * page_size == max_len``), so tokens are
    BITWISE identical to the dense engine.  The kernel path walks the
    table inside ``flash_decode_paged`` without materializing the gather.

    Write-safety: an example whose table has no page for ``index`` (an
    inactive engine slot, or index beyond the table) maps to pool page
    ``N_pages`` — out of bounds — and the ``mode="drop"`` scatter makes
    it a no-op.  A plain ``.at[-1]`` would *wrap* and corrupt the last
    pool page."""
    b = q.shape[0]
    n_pg, page_size, _, _ = cache["k"].shape
    p_tab = pages.shape[1]
    index = jnp.asarray(index)
    idx = index if index.ndim > 0 else jnp.broadcast_to(index[None], (b,))
    pidx = idx // page_size
    off = idx % page_size
    ar = jnp.arange(b)
    pid = jnp.where(pidx < p_tab,
                    pages[ar, jnp.minimum(pidx, p_tab - 1)], -1)
    safe = jnp.where(pid >= 0, pid, n_pg)          # unassigned -> OOB drop
    ck = cache["k"].at[safe, off].set(
        k[:, 0].astype(cache["k"].dtype), mode="drop")
    cv = cache["v"].at[safe, off].set(
        v[:, 0].astype(cache["v"].dtype), mode="drop")
    # no kv_cache constrain here: the pool layout (N_pages, ...) does not
    # match the (B, S, H, D) sharding rule, and serving runs single-host

    groups = n_heads // n_kv_heads
    if use_kernel:
        from repro.kernels import flash_attention_ops
        lengths = idx + 1
        out = flash_attention_ops.flash_decode_paged(
            q, ck, cv, pages, lengths)
    else:
        # gather the table into the dense linear view; unassigned pages
        # read pool page 0 but every such position is masked below.
        gpid = jnp.maximum(pages, 0)               # (B, P)
        gk = ck[gpid].reshape(b, p_tab * page_size, n_kv_heads, head_dim)
        gv = cv[gpid].reshape(b, p_tab * page_size, n_kv_heads, head_dim)
        kk = _repeat_kv(gk, groups)
        vv = _repeat_kv(gv, groups)
        scale = head_dim ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            kk.astype(jnp.float32)) * scale
        kpos = jnp.arange(p_tab * page_size)[None, :]
        valid = kpos <= idx[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    out = out.astype(out_dtype).reshape(b, 1, n_heads * head_dim)
    return linear(p["wo"], out), {"k": ck, "v": cv}


def _ring_abs_pos(idx: jnp.ndarray, s_cache: int) -> jnp.ndarray:
    """Absolute position stored in each ring slot after writing at
    ``idx % s_cache``. idx: (B,) -> (B, S)."""
    slots = jnp.arange(s_cache)[None, :]
    cur = idx[:, None] % s_cache
    # slot j holds abs position idx - ((cur - j) mod s_cache)
    back = (cur - slots) % s_cache
    return idx[:, None] - back


def _scatter_rows(cache: jnp.ndarray, new: jnp.ndarray,
                  slots: jnp.ndarray) -> jnp.ndarray:
    """Per-example dynamic row write: cache (B,S,H,D), new (B,1,H,D),
    slots (B,)."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), slots].set(
        new[:, 0].astype(cache.dtype))
