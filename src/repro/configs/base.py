"""Architecture config system.

Every assigned architecture is an :class:`ArchConfig`; ``reduced()`` gives
the CPU-smoke-test variant (<=2 layers, d_model<=512, <=4 experts) of the
same family. The FULL configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- MoE ----------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1              # MoE block every k-th layer (1 = all)
    moe_d_ff: int = 0               # expert hidden (0 -> d_ff)
    moe_shared_expert: bool = False
    moe_pad_to: int = 0             # pad experts to this count (EP axis)
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"    # einsum | scatter (§Perf C2)

    # --- SSM / hybrid / xLSTM ------------------------------------------
    ssm_state: int = 0              # Mamba2 N
    ssm_heads: int = 0              # Mamba2 H (0 -> d_inner // 64)
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0             # zamba2: shared attn block every k layers
    slstm_every: int = 0            # xlstm: sLSTM block every k layers

    # --- positions / attention variants ---------------------------------
    rope: bool = True
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    sliding_window: int = 0         # 0 = full causal attention
    qkv_bias: bool = False

    # --- encoder-decoder (whisper) --------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0            # frames after the (stubbed) conv frontend
    is_encoder_decoder: bool = False

    # --- VLM stub --------------------------------------------------------
    vision_tokens: int = 0          # prefix length of stubbed patch embeds

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""                # citation

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm" or self.slstm_every > 0 or False

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 0.5M-token decode? SSM/hybrid natively; dense
        and VLM via the sliding-window variant we implement; whisper no."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    # ------------------------------------------------------------------ #
    def pattern_unit(self) -> int:
        """Layers per scanned 'superlayer' (heterogeneous layer patterns
        are grouped into repeating units)."""
        if self.family == "moe" and self.moe_every > 1:
            return self.moe_every
        if self.family == "hybrid" and self.attn_every > 0:
            return self.attn_every
        if self.slstm_every > 0:
            return self.slstm_every
        return 1

    @property
    def n_units(self) -> int:
        u = self.pattern_unit()
        assert self.n_layers % u == 0, (self.name, self.n_layers, u)
        return self.n_layers // u

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = 0
        n_dense_mlp = 0
        n_moe = 0
        n_ssm = 0
        n_slstm = 0
        total = emb
        hd = self.head_dim
        attn_p = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        for i in range(self.n_layers):
            is_moe = (self.moe_experts > 0
                      and (i % max(1, self.moe_every)
                           == max(1, self.moe_every) - 1))
            if self.family in ("dense", "vlm", "audio"):
                total += attn_p + 3 * d * self.d_ff + 2 * d
            elif self.family == "moe":
                total += attn_p + 2 * d
                if is_moe:
                    ff = self.moe_d_ff or self.d_ff
                    total += self.moe_experts * 3 * d * ff + d * self.moe_experts
                    if self.moe_shared_expert:
                        total += 3 * d * ff
                else:
                    total += 3 * d * self.d_ff
            elif self.family == "ssm":
                if self.slstm_every and (i % self.slstm_every
                                         == self.slstm_every - 1):
                    total += 4 * d * d + 2 * d      # sLSTM-ish
                else:
                    total += self._mamba_params() + 2 * d
            elif self.family == "hybrid":
                total += self._mamba_params() + 2 * d
        if self.family == "hybrid" and self.attn_every:
            total += attn_p + 3 * d * self.d_ff + 2 * d  # one shared block
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attn already excluded above;
            # add encoder stack and cross attention
            total += self.encoder_layers * (attn_p + 3 * d * self.d_ff + 2 * d)
            total += self.n_layers * attn_p       # cross-attn per dec layer
        return int(total)

    def _mamba_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.n_ssm_heads
        # in_proj (x, z, B, C, dt) + conv + out_proj
        return (d * (2 * di + 2 * n + h) + self.ssm_conv * (di + 2 * n)
                + di * d + 2 * h)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe_experts == 0:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        dead = 0
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if i % max(1, self.moe_every) == max(1, self.moe_every) - 1)
        inactive = self.moe_experts - self.moe_top_k
        dead = n_moe_layers * inactive * 3 * d * ff
        return int(self.param_count() - dead)

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        u = self.pattern_unit()
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        hd = max(16, d // heads)
        if self.mrope_sections:
            # keep the 1:1.5:1.5 t/h/w split, resized to hd//2 channels
            t = hd // 8
            h = (hd // 2 - t) // 2
            sections = (hd // 2 - 2 * h, h, h)
        else:
            sections = ()
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(u, 2 if u == 1 else u),
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) or self.d_ff,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            vocab=min(self.vocab, 1024),
            mrope_sections=sections,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.n_ssm_heads, 4) if self.ssm_state else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_seq else 0,
            vision_tokens=min(self.vision_tokens, 16) if self.vision_tokens else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )


# ---------------------------------------------------------------------- #
_REGISTRY: Dict[str, str] = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "glm4-9b": "repro.configs.glm4_9b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "llama3-405b": "repro.configs.llama3_405b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_NAMES = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(_REGISTRY[name])
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}
