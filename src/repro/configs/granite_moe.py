"""granite-moe-3b-a800m [moe]: every layer MoE, 40 experts top-8,
expert d_ff=512 [hf:ibm-granite/granite-3.0-1b-a400m-base scaled per the
assignment]. 32L, d_model=1536, 24 heads / 8 KV heads, vocab=49155.
Experts are padded to a multiple of the expert-parallel axis at dry-run
time (DESIGN.md §6)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    vocab=49155,
    moe_experts=40,
    moe_top_k=8,
    moe_every=1,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
