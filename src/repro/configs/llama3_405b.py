"""llama3-405b [dense] [arXiv:2407.21783]: 126L, d_model=16384,
128 heads / 8 KV heads (head_dim 128), d_ff=53248, vocab=128256,
rope_theta=500000."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783",
)
