"""qwen2-vl-2b [vlm]: text decoder with M-RoPE (t/h/w sections) and a
stubbed vision tower [arXiv:2409.12191]. 28L, d_model=1536, 12 heads /
2 KV heads (head_dim 128), d_ff=8960, vocab=151936. ``input_specs``
supplies precomputed patch embeddings for the first ``vision_tokens``
positions (the allowed modality-frontend stub)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    mrope_sections=(16, 24, 24),   # sums to head_dim/2
    vision_tokens=1024,
    tie_embeddings=True,
    source="arXiv:2409.12191",
)
