"""The four assigned input shapes and the ShapeDtypeStruct stand-ins the
dry-run lowers against (no device allocation).

  train_4k     seq_len=  4,096  global_batch=256   train_step
  prefill_32k  seq_len= 32,768  global_batch= 32   prefill forward
  decode_32k   seq_len= 32,768  global_batch=128   serve_step (1 token +
                                                   KV cache of seq_len)
  long_500k    seq_len=524,288  global_batch=  1   serve_step, sub-quadratic
                                                   archs only (DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .base import ArchConfig

# sliding window used by the long-context variant of full-attention archs
LONG_CTX_WINDOW = 8192


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """None if (cfg, shape) runs; else a skip reason (recorded in
    EXPERIMENTS.md)."""
    if shape.name == "long_500k" and cfg.family == "audio":
        return ("enc-dec whisper decoder is trained for 448 positions; "
                "0.5M-token decode is out of family semantics "
                "(DESIGN.md §5)")
    return None


def variant_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """long_500k on full-attention families uses the sliding-window
    sub-quadratic variant; SSM/hybrid run natively."""
    if (shape.name == "long_500k" and cfg.family in ("dense", "vlm", "moe")
            and cfg.sliding_window == 0):
        return dataclasses.replace(cfg, sliding_window=LONG_CTX_WINDOW)
    return cfg


# ---------------------------------------------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    train/prefill: token batch (+ modality-stub embeddings).
    decode: one new token per sequence + the decode cache (KV cache of
    ``seq_len`` / recurrent state), via ``jax.eval_shape`` over
    ``init_cache`` — weak-type-correct, shardable, no allocation.
    """
    cfg = variant_for_shape(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
        if cfg.family == "vlm":
            specs["vision_embeds"] = _sds(
                (b, cfg.vision_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            specs["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt)
        return specs

    # decode: one token + cache of length seq_len
    from repro.models import model as model_lib
    cache = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, b, s, dt))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}
