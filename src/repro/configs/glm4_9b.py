"""glm4-9b [dense]: RoPE + aggressive GQA (2 KV heads) [hf:THUDM/glm-4-9b].
40L, d_model=4096, 32 heads / 2 KV heads, d_ff=13696, vocab=151552,
qkv bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    qkv_bias=True,
    source="hf:THUDM/glm-4-9b",
)
