"""whisper-tiny [audio]: encoder-decoder [arXiv:2212.04356]. 4 encoder +
4 decoder layers, d_model=384, 6 heads (MHA), d_ff=1536, vocab=51865.
The mel-spectrogram + conv frontend is the allowed stub: ``input_specs``
supplies (batch, 1500, d_model) frame embeddings. Sinusoidal positions
(extended for the mechanical long-decode shapes; DESIGN.md §5)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    encoder_layers=4,
    encoder_seq=1500,
    is_encoder_decoder=True,
    rope=False,
    source="arXiv:2212.04356",
)
