"""llama4-maverick-400b-a17b [moe]: interleaved MoE every other layer
(24 MoE layers: 128 routed experts top-1 + 1 shared expert, expert
d_ff=8192; 24 dense layers d_ff=16384), GQA 8 KV heads, vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E + Llama-4 model card]. The flat
reading (MoE in all 48 layers) would be ~770B params; interleaving lands
at ~0.4T, matching the name (DESIGN.md §6)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,              # dense interleaved layers
    moe_d_ff=8192,           # routed + shared experts
    vocab=202048,
    moe_experts=128,
    moe_top_k=1,
    moe_every=2,
    moe_shared_expert=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
