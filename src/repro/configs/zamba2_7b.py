"""zamba2-7b [hybrid]: Mamba2 backbone + ONE shared attention+MLP block
re-applied periodically (weights shared across applications), per Zamba2
[arXiv:2411.15242]. 81 Mamba2 layers, d_model=3584, shared block has 32
full-MHA heads and a 14336 MLP; ssm_state=64. We apply the shared block
every 9 layers (81 % 6 != 0; cadence is a config choice, see DESIGN.md §6).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_heads=112,           # d_inner 7168 / head 64
    attn_every=9,
    rope=True,
    source="arXiv:2411.15242",
)
