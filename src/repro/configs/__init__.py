from .base import ARCH_NAMES, ArchConfig, all_configs, get_config
from .shapes import INPUT_SHAPES, InputShape, input_specs

__all__ = ["ARCH_NAMES", "ArchConfig", "INPUT_SHAPES", "InputShape",
           "all_configs", "get_config", "input_specs"]
