"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks, xLSTM[7:1] cadence
[arXiv:2405.04517]. 48 blocks, d_model=2048, 4 heads, vocab=50304,
no separate FFN (d_ff=0; the mLSTM block has its own up/down projection,
factor 2)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_expand=2,
    slstm_every=8,           # one sLSTM per 8 blocks (7:1)
    rope=False,
    source="arXiv:2405.04517",
)
