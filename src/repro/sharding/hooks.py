"""Activation sharding hook.

Model code calls ``constrain(x, "act_ffn")`` at propagation choke points.
Outside a rules context (CPU smoke tests, single device) it is the
identity; inside (dry-run / launcher) it applies
``jax.lax.with_sharding_constraint`` with the PartitionSpec registered for
that logical name. Rules are installed *before* ``jit(...).lower()`` so the
trace picks them up.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_CURRENT: Optional[Dict[str, object]] = None  # name -> (PartitionSpec, mesh)


def current_rules() -> Optional[Dict[str, object]]:
    return _CURRENT


@contextlib.contextmanager
def activation_rules(table: Dict[str, PartitionSpec], mesh=None, rules=None):
    """Install a logical-name -> PartitionSpec table for the duration of a
    trace. ``mesh`` (optional) turns specs into NamedSharding constraints;
    when omitted the bare PartitionSpec is used (requires an ambient mesh
    context at trace time). ``rules`` (a ShardingRules) additionally
    enables ``constrain_params_tree`` (gradient resharding hints)."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = {"table": dict(table), "mesh": mesh, "rules": rules}
    try:
        yield
    finally:
        _CURRENT = prev


def constrain_params_tree(tree):
    """Constrain a param-shaped pytree (e.g. gradients) to the parameter
    sharding — forces XLA to reduce-scatter gradients instead of
    all-reducing them at full size. No-op outside a rules context."""
    if _CURRENT is None or _CURRENT.get("rules") is None:
        return tree
    rules = _CURRENT["rules"]
    mesh = _CURRENT["mesh"] or rules.mesh

    def one(path, leaf):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            else:
                parts.append(str(getattr(p, "idx", p)))
        spec = rules.param_spec("/".join(parts), leaf.ndim)
        from .rules import sanitize_spec
        spec = sanitize_spec(mesh, leaf.shape, spec)
        return jax.lax.with_sharding_constraint(
            leaf, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)


def constrain(x, name: str):
    """Apply the sharding constraint registered under ``name`` (identity
    when no rules are installed or the name has no entry)."""
    if _CURRENT is None:
        return x
    spec = _CURRENT["table"].get(name)
    if spec is None:
        return x
    mesh = _CURRENT["mesh"]
    if mesh is not None:
        spec = jax.sharding.NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)
