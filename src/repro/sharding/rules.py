"""Parameter + activation PartitionSpec rules (DESIGN.md §7).

Meshes: single pod ``('data'=16, 'model'=16)``; multi-pod
``('pod'=2, 'data'=16, 'model'=16)`` where 'pod' extends data parallelism
(params replicated across pods; gradient all-reduce crosses pods once per
step).

Parameters are 2-D sharded: the tensor-parallel dimension over 'model',
the FSDP dimension over 'data'. Rules are matched on the parameter's tree
path (a '/'-joined key string); stacked scan-over-layers parameters (under
``units/``) get a leading ``None`` for the layer dimension.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclass(frozen=True)
class ShardingRules:
    """Bundle of (mesh, fsdp axis, tp axis, activation table)."""

    mesh: Mesh
    fsdp: str = "data"
    tp: str = "model"
    # long-decode mode: KV cache sequence-sharded instead of batch-sharded
    seq_shard_cache: bool = False

    # ------------------------------------------------------------------ #
    @property
    def batch(self) -> Tuple[str, ...]:
        return batch_axes(self.mesh)

    def activation_table(self) -> Dict[str, P]:
        b, tp = self.batch, self.tp
        table = {
            # residual stream (B, S, D)
            "act_btd": P(b, None, None),
            # ffn hidden (B, S, F) — TP over F
            "act_ffn": P(b, None, tp),
            # attention heads (B, S, H, hd) — TP over query heads
            "act_heads": P(b, None, tp, None),
            # mamba/xlstm inner (B, S, d_inner) — TP over channels
            "act_inner": P(b, None, tp),
            # logits (B, S, V) — TP over vocab
            "logits": P(b, None, tp),
            # MoE dispatched tokens (G, E, cap, D): token groups stay on
            # the batch axes, experts over 'model' (EP). (§Perf C fixed a
            # bug here: the old spec P(tp, None, None) sharded the GROUP
            # dim over 'model', forcing collective-permute resharding
            # around every expert einsum.)
            "moe_dispatch": P(b, tp, None, None),
            # per-token router probs (B, S, E)
            "router": P(b, None, None),
        }
        if self.seq_shard_cache:
            # 0.5M-token decode, batch=1: cache (B, S, Hkv, hd) sharded on S
            table["kv_cache"] = P(None, ("data", tp) if "data" in
                                  self.mesh.axis_names else (tp,), None, None)
            table["ssm_state"] = P(None, tp, None, None)
        else:
            # cache (B, S, Hkv, hd): batch over data, KV heads over TP
            # (§Perf B — must agree with launch.specs.cache_spec or the
            # in-model constraint re-gathers the heads)
            table["kv_cache"] = P(b, None, self.tp, None)
            # ssm state (B, H, dh, N) batch-sharded, heads TP
            table["ssm_state"] = P(b, tp, None, None)
        return table

    # ------------------------------------------------------------------ #
    def param_spec(self, path: str, ndim: int) -> P:
        prefix = 0
        if "units/" in path or path.startswith("units"):
            prefix += 1                  # scan-stacked over units
        if "/mamba/" in path or "/mlstm/" in path:
            prefix += 1                  # inner per-unit layer stack
        base = max(ndim - prefix, 0)
        spec = _match_param(path, base, self.fsdp, self.tp)
        if prefix:
            spec = P(*([None] * prefix), *spec)
            spec = P(*(list(spec)[:ndim] + [None] * (ndim - len(spec))))
        return spec


def sanitize_spec(mesh: Mesh, shape, spec: P) -> P:
    """Drop mesh axes whose size does not divide the dimension (jit
    in_shardings and with_sharding_constraint require divisibility for
    clean layouts; odd dims fall back to replicated on that dim)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, axes in enumerate(spec):
        if axes is None or i >= len(shape):
            out.append(None)
            continue
        tup = axes if isinstance(axes, tuple) else (axes,)
        prod = 1
        for a in tup:
            prod *= sizes[a]
        out.append(axes if shape[i] % prod == 0 else None)
    return P(*out)


# -------------------------------------------------------------------- #
# path rules
# -------------------------------------------------------------------- #
def _match_param(path: str, ndim: int, fsdp: str, tp: str) -> P:
    """Map one parameter path to its (non-stacked) PartitionSpec."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("b",) or ndim == 0:
        return P(*([None] * ndim))
    if "norm" in path or leaf == "scale":
        return P(*([None] * ndim))
    if "embed" in path and leaf == "table":            # (V, D)
        return P(tp, fsdp)
    if "router" in path:                               # (D, E)
        return P(fsdp, None)
    if "experts" in path:
        # (E, D, F) gate/up; (E, F, D) down — experts over TP (EP)
        if ndim == 3:
            return P(tp, fsdp, None)
        return P(tp, None)
    if leaf in ("A_log", "D", "dt_bias"):              # (H,) ssm scalars
        return P(tp) if ndim == 1 else P(*([None] * ndim))
    if "conv" in path:                                 # (k, channels)
        return P(None, tp) if ndim == 2 else P(*([None] * ndim))
    # projections: direction decides which dim is TP
    in_proj = any(k in path for k in
                  ("wq", "wk", "wv", "gate", "up", "in_proj", "w_qkv",
                   "q_proj", "k_proj", "v_proj"))
    out_proj = any(k in path for k in ("wo", "down", "out_proj", "o_proj"))
    if ndim == 2:
        if out_proj:
            return P(tp, fsdp)
        if in_proj:
            return P(fsdp, tp)
        return P(fsdp, tp)   # default: last dim TP
    if ndim == 1:
        # bias of a TP-column projection: shard over tp only if it is an
        # inner/hidden vector; keep replicated for safety
        return P(None)
    return P(*([None] * ndim))


# -------------------------------------------------------------------- #
# public helpers
# -------------------------------------------------------------------- #
def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(rules: ShardingRules, params_shape) -> Dict:
    """PartitionSpec pytree mirroring ``params_shape`` (a pytree of
    ShapeDtypeStructs or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rules.param_spec(_path_str(path), len(leaf.shape)),
        params_shape)


def param_sharding(rules: ShardingRules, params_shape) -> Dict:
    """NamedSharding pytree for ``jit(in_shardings=...)``."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(rules.mesh, spec),
        param_specs(rules, params_shape),
        is_leaf=lambda x: isinstance(x, P))


def make_rules(mesh: Mesh, *, seq_shard_cache: bool = False) -> ShardingRules:
    return ShardingRules(mesh=mesh, seq_shard_cache=seq_shard_cache)
