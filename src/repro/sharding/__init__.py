"""Sharding rules: logical activation names + parameter-path rules ->
``jax.sharding.PartitionSpec`` for the FSDP('data') x TP('model')
(+ 'pod' pure-DP) meshes of DESIGN.md §7."""
from .hooks import activation_rules, constrain, current_rules
from .rules import (ShardingRules, batch_axes, make_rules, param_sharding,
                    param_specs)

__all__ = [
    "ShardingRules", "activation_rules", "batch_axes", "constrain",
    "current_rules", "make_rules", "param_sharding", "param_specs",
]
