"""Bounded retry with exponential backoff, full jitter, and an optional
wall-clock deadline.

One small primitive shared by every retry path in the repo (the schedule
executor's fault recovery, the serve engine's segment retries, and the
fleet master's lease re-dispatch): retry a callable a bounded number of
times, sleeping ``U(0, min(cap, base * 2**attempt))`` between attempts —
AWS-style *full jitter*, which decorrelates retry storms while keeping
the expected backoff exponential. The jitter stream comes from a
caller-owned ``random.Random``, so a seeded RNG makes the whole retry
schedule deterministic (the executor tests replay failures bit-exactly).

A :class:`RetryPolicy` may additionally carry a ``deadline`` — an
overall wall-clock budget in seconds. A retry whose backoff sleep would
land past the deadline is not attempted; :class:`RetryBudgetExceeded`
is raised instead (chained to the last underlying failure). The fleet
master uses this so re-dispatching a dead agent's lease can never retry
past a group's recovery budget.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type


class RetryBudgetExceeded(RuntimeError):
    """The overall wall-clock ``deadline`` of a :class:`RetryPolicy` ran
    out before the attempts did. Carries how far the retry loop got; the
    underlying failure is chained as ``__cause__``."""

    def __init__(self, attempts: int, elapsed: float,
                 deadline: float) -> None:
        self.attempts = attempts
        self.elapsed = elapsed
        self.deadline = deadline
        super().__init__(
            f"retry budget exceeded after {attempts} attempt(s): "
            f"{elapsed:.3f}s elapsed of a {deadline:.3f}s deadline")


@dataclass(frozen=True)
class RetryPolicy:
    """Backoff shape: ``attempts`` total tries, delay before retry *k*
    (0-indexed) drawn from ``U(0, min(cap, base * 2**k))``; ``jitter=
    False`` uses the deterministic upper bound instead. ``deadline``
    (seconds, ``None`` = unbounded) caps the whole loop's wall clock: a
    retry is only attempted if its backoff sleep still fits inside the
    budget."""

    attempts: int = 3
    base: float = 0.05
    cap: float = 2.0
    jitter: bool = True
    deadline: Optional[float] = None

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")
        if self.base < 0 or self.cap < 0:
            raise ValueError("base/cap must be >= 0")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError(f"deadline must be >= 0, got {self.deadline}")

    def delay(self, attempt: int, rng: random.Random) -> float:
        bound = min(self.cap, self.base * (2.0 ** attempt))
        return rng.uniform(0.0, bound) if self.jitter else bound


def retry_call(fn: Callable, *,
               policy: Optional[RetryPolicy] = None,
               retry_on: Tuple[Type[BaseException], ...] = (Exception,),
               rng: Optional[random.Random] = None,
               seed: int = 0,
               sleep: Callable[[float], None] = time.sleep,
               clock: Callable[[], float] = time.monotonic,
               on_retry: Optional[Callable] = None):
    """Call ``fn()`` up to ``policy.attempts`` times.

    Exceptions matching ``retry_on`` trigger a backoff sleep and a
    retry; the last attempt's exception propagates unchanged (callers
    escalate — e.g. the executor turns an exhausted transient fault into
    a fatal member drop). When the policy carries a ``deadline``, a
    retry whose sleep would overrun it raises
    :class:`RetryBudgetExceeded` from the triggering exception instead
    of sleeping. ``on_retry(attempt, exc, delay)`` observes every retry
    (stats counters); ``sleep`` and ``clock`` are injectable so tests
    run without wall-clock delays.
    """
    policy = policy or RetryPolicy()
    rng = rng if rng is not None else random.Random(seed)
    start = clock()
    for attempt in range(policy.attempts):
        try:
            return fn()
        except retry_on as exc:
            if attempt == policy.attempts - 1:
                raise
            delay = policy.delay(attempt, rng)
            if policy.deadline is not None:
                elapsed = clock() - start
                if elapsed + delay > policy.deadline:
                    raise RetryBudgetExceeded(
                        attempt + 1, elapsed, policy.deadline) from exc
            if on_retry is not None:
                on_retry(attempt, exc, delay)
            sleep(delay)
    raise AssertionError("unreachable")   # pragma: no cover
