"""Bounded retry with exponential backoff and full jitter.

One small primitive shared by every retry path in the repo (the schedule
executor's fault recovery and the serve engine's segment retries): retry
a callable a bounded number of times, sleeping ``U(0, min(cap,
base * 2**attempt))`` between attempts — AWS-style *full jitter*, which
decorrelates retry storms while keeping the expected backoff
exponential. The jitter stream comes from a caller-owned
``random.Random``, so a seeded RNG makes the whole retry schedule
deterministic (the executor tests replay failures bit-exactly).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type


@dataclass(frozen=True)
class RetryPolicy:
    """Backoff shape: ``attempts`` total tries, delay before retry *k*
    (0-indexed) drawn from ``U(0, min(cap, base * 2**k))``; ``jitter=
    False`` uses the deterministic upper bound instead."""

    attempts: int = 3
    base: float = 0.05
    cap: float = 2.0
    jitter: bool = True

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")
        if self.base < 0 or self.cap < 0:
            raise ValueError("base/cap must be >= 0")

    def delay(self, attempt: int, rng: random.Random) -> float:
        bound = min(self.cap, self.base * (2.0 ** attempt))
        return rng.uniform(0.0, bound) if self.jitter else bound


def retry_call(fn: Callable, *,
               policy: Optional[RetryPolicy] = None,
               retry_on: Tuple[Type[BaseException], ...] = (Exception,),
               rng: Optional[random.Random] = None,
               seed: int = 0,
               sleep: Callable[[float], None] = time.sleep,
               on_retry: Optional[Callable] = None):
    """Call ``fn()`` up to ``policy.attempts`` times.

    Exceptions matching ``retry_on`` trigger a backoff sleep and a
    retry; the last attempt's exception propagates unchanged (callers
    escalate — e.g. the executor turns an exhausted transient fault into
    a fatal member drop). ``on_retry(attempt, exc, delay)`` observes
    every retry (stats counters); ``sleep`` is injectable so tests run
    without wall-clock delays.
    """
    policy = policy or RetryPolicy()
    rng = rng if rng is not None else random.Random(seed)
    for attempt in range(policy.attempts):
        try:
            return fn()
        except retry_on as exc:
            if attempt == policy.attempts - 1:
                raise
            delay = policy.delay(attempt, rng)
            if on_retry is not None:
                on_retry(attempt, exc, delay)
            sleep(delay)
    raise AssertionError("unreachable")   # pragma: no cover
