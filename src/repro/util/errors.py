"""Shared exception types for versioned on-disk artifacts.

Every persisted artifact in this repo (``calibration.json``,
``autotune.json``) carries a ``version`` field; loaders must fail with a
*descriptive* error naming the found and expected versions — a bare
``KeyError``/``ValueError`` from deep inside a consumer tells the user
nothing about which file is stale or how to regenerate it.
"""
from __future__ import annotations


class ArtifactVersionError(ValueError):
    """A persisted artifact has the wrong version or a broken schema.

    Subclasses :class:`ValueError` so existing ``except ValueError``
    guards (e.g. the lazy autotune-table load) keep treating a stale
    artifact as "no artifact" instead of crashing.
    """

    def __init__(self, path: str, found, expected, *, kind: str = "artifact",
                 detail: str = "") -> None:
        self.path = path
        self.found = found
        self.expected = expected
        self.kind = kind
        msg = (f"{kind} {path!r}: found version {found!r}, expected "
               f"{expected!r}")
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)
