"""Dependency-free shared utilities (stdlib only — importable from the
numpy-less, jax-less simulator core and from the launch layer alike)."""
from .errors import ArtifactVersionError
from .retry import RetryBudgetExceeded, RetryPolicy, retry_call

__all__ = ["ArtifactVersionError", "RetryBudgetExceeded", "RetryPolicy",
           "retry_call"]
