"""Synthetic LM data pipeline: deterministic per-step token batches with
next-token labels, plus the stubbed modality-frontend embeddings for the
VLM/audio architectures (the one allowed stub). Host-sharded feed: each
process materializes only its addressable slice when a mesh is given."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def make_batch(cfg: ArchConfig, batch: int, seq: int, *, step: int = 0,
               seed: int = 0, dtype=None,
               structured: bool = False) -> Dict[str, jnp.ndarray]:
    """One training batch: tokens (B,S), labels = next token, and modality
    stubs where the family requires them.

    ``structured=True`` draws deterministic affine sequences
    t_{i+1} = (a*t_i + b) mod V — i.i.d. uniform tokens have an
    irreducible loss of ln(V), so demos that must SHOW learning (the
    quickstart) need learnable structure."""
    dt = jnp.dtype(dtype or cfg.dtype)
    rng = np.random.default_rng(seed * 1_000_003 + step)
    if structured:
        a = 5 * (seed % 97) + 3
        bconst = (seed % 1009) + 1
        start = rng.integers(0, cfg.vocab, size=(batch, 1), dtype=np.int64)
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, :1] = start
        for i in range(seq):
            toks[:, i + 1] = (a * toks[:, i] + bconst) % cfg.vocab
        toks = toks.astype(np.int32)
    else:
        toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1),
                            dtype=np.int32)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.family == "vlm":
        out["vision_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vision_tokens, cfg.d_model),
                                dtype=np.float32) * 0.02, dtype=dt)
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model),
                                dtype=np.float32) * 0.02, dtype=dt)
    return out


class SyntheticLM:
    """Iterator over deterministic synthetic batches."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 dtype=None, structured: bool = False):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.dtype = seed, dtype
        self.structured = structured
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        b = make_batch(self.cfg, self.batch, self.seq, step=self._step,
                       seed=self.seed, dtype=self.dtype,
                       structured=self.structured)
        self._step += 1
        return b
