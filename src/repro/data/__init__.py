from .synthetic import SyntheticLM, make_batch

__all__ = ["SyntheticLM", "make_batch"]
