"""Sharding specs for every dry-run input: params, optimizer state, data
batch and decode cache. Kept separate from ``dryrun.py`` so the train /
serve drivers and tests reuse them (this module never forces the 512
placeholder devices)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape, input_specs, variant_for_shape
from repro.models import init_cache, init_params
from repro.sharding.rules import ShardingRules, param_specs
from repro.train.optimizer import adamw_init

# archs whose optimizer moments are kept in bf16 (fit 16 GiB/chip)
BF16_MOMENTS_ABOVE = 50e9

# gradient-accumulation sub-steps for the train_4k dry-run (the paper's
# memory mechanism; tuned so activations fit per chip — EXPERIMENTS.md)
TRAIN_ACCUM_STEPS: Dict[str, int] = {
    "llama3-405b": 16,
    "llama4-maverick-400b-a17b": 8,
    "zamba2-7b": 2,
    "glm4-9b": 2,
    "stablelm-12b": 2,
}


def params_shape(cfg: ArchConfig) -> Any:
    """ShapeDtypeStruct pytree of the model parameters (no allocation)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def opt_shape(cfg: ArchConfig, p_shape) -> Any:
    n_params = sum(x.size for x in jax.tree.leaves(p_shape))
    mdt = jnp.bfloat16 if n_params * 2 > BF16_MOMENTS_ABOVE else jnp.float32
    return jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shape),
        moment_dtype=mdt))


# ---------------------------------------------------------------------- #
def batch_spec(rules: ShardingRules, name: str, ndim: int) -> P:
    b = rules.batch
    if ndim == 0:
        return P()
    return P(b, *([None] * (ndim - 1)))


def cache_spec(rules: ShardingRules, path: str, ndim: int,
               *, seq_shard: bool) -> P:
    """Decode-cache leaf specs. Leaves are stacked over units (leading U).
    ``seq_shard``: long_500k mode — batch=1, shard the KV sequence dim."""
    b, tp = rules.batch, rules.tp
    leaf = path.rsplit("/", 1)[-1]
    if ndim <= 1:
        return P(*([None] * ndim))
    if leaf in ("k", "v"):                      # (U, B, S, H, D)
        if seq_shard:
            axes = ("data", tp) if "data" in rules.mesh.axis_names else (tp,)
            return P(None, None, axes, *([None] * (ndim - 3)))
        # batch over the data axes AND KV heads over 'model' (§Perf B:
        # an unsharded-head cache was all-gathered in f32 inside every
        # unit of the decode scan — 71 GB/token on zamba2). Archs whose
        # kv-head count does not divide the TP axis fall back to
        # replicated heads via sanitize_spec.
        return P(None, b, None, tp, *([None] * (ndim - 4)))
    if leaf in ("state", "C"):                  # (U, B, H, P, N)
        if seq_shard:
            return P(None, None, tp, *([None] * (ndim - 3)))
        return P(None, b, tp, *([None] * (ndim - 3)))
    if leaf == "conv":                          # (U, B, k-1, ch)
        if seq_shard:
            return P(*([None] * (ndim - 1)), tp)
        return P(None, b, *([None] * (ndim - 2)))
    if leaf in ("n", "m", "h", "c"):            # mLSTM/sLSTM vectors
        if seq_shard:
            return P(None, None, tp, *([None] * (ndim - 3))) if ndim >= 3 \
                else P(*([None] * ndim))
        return P(None, b, *([None] * (ndim - 2)))
    return P(*([None] * ndim))


# re-exported: canonical implementation lives in repro.sharding.rules
from repro.sharding.rules import sanitize_spec  # noqa: E402


def _sanitized_sharding(mesh, leaf, spec) -> NamedSharding:
    return NamedSharding(mesh, sanitize_spec(mesh, leaf.shape, spec))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(getattr(p, "idx", p)))
    return "/".join(parts)


def cache_shardings(rules: ShardingRules, cache_shape,
                    *, seq_shard: bool):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _sanitized_sharding(
            rules.mesh, leaf,
            cache_spec(rules, _path_str(path), len(leaf.shape),
                       seq_shard=seq_shard)),
        cache_shape)


def batch_shardings(rules: ShardingRules, batch_shape):
    return jax.tree.map(
        lambda leaf: _sanitized_sharding(
            rules.mesh, leaf, batch_spec(rules, "", len(leaf.shape))),
        batch_shape)


def param_shardings(rules: ShardingRules, p_shape):
    specs = param_specs(rules, p_shape)
    return jax.tree.map(
        lambda leaf, spec: _sanitized_sharding(rules.mesh, leaf, spec),
        p_shape, specs,
        is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def opt_shardings(rules: ShardingRules, o_shape, p_shape):
    pspec = param_shardings(rules, p_shape)
    return type(o_shape)(
        step=NamedSharding(rules.mesh, P()),
        m=pspec, v=pspec)
