"""Radix-trie prefix index over token IDs, at page granularity
(DESIGN.md §18).

The serving analogue of the paper's wise-sharing thesis applied to cache
memory: identical prompt prefixes (system prompts, few-shot headers) are
stored once in the page pool and mapped read-only into every request that
matches them.  The trie is the host-side index that makes the lookup
cheap: each node covers the tokens of exactly ONE pool page (up to
``page_size`` of them — the tail of a prompt may populate a partial
node), children are keyed by their token tuple, and a lookup walks the
longest matching chain.

Refcount protocol (owned by the engine, not the trie): the trie holds
+1 on every page its nodes reference, each block-table entry holds +1,
and a page is writable only at refcount 1.  The trie never touches the
refcount array itself — ``insert`` returns the pages that gained a node
and ``evict_lru`` returns the page it dropped, so the engine's
bookkeeping stays in one place and the invariant

    sum(refcounts) == mapped block-table entries + trie nodes

is checkable from outside.

Recency is a logical clock (ticked per ``match``/``insert``), so LRU
eviction is deterministic under test.
"""
from __future__ import annotations

from typing import List, Optional, Tuple


class _Node:
    __slots__ = ("toks", "page", "children", "parent", "last_used")

    def __init__(self, toks: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.toks = toks            # 1..page_size token IDs this page holds
        self.page = page            # pool page with the matching K/V rows
        self.children = {}          # toks tuple -> _Node
        self.parent = parent
        self.last_used = 0


def _common(a: Tuple[int, ...], b: Tuple[int, ...]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixTrie:
    """Longest-cached-prefix index mapping prompts to pool pages."""

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self.root = _Node((), -1, None)
        self._clock = 0
        self._n_pages = 0

    def page_count(self) -> int:
        """Number of nodes == number of pages the trie holds a ref on."""
        return self._n_pages

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------------ #
    def match(self, prompt, *, touch: bool = True
              ) -> Tuple[List[int], int]:
        """Longest cached prefix of ``prompt``.

        Returns ``(pages, n_matched)``: the pool pages covering prompt
        rows ``[0, n_matched)`` in order.  The walk descends only through
        fully-matched FULL nodes; a partial node (a cached prompt tail)
        or a mid-node divergence contributes its matched rows and ends
        the chain — its page is a gather source for the caller, never a
        further branch point.  ``touch=False`` makes the lookup
        side-effect free (no LRU update) for admission planning."""
        prompt = tuple(int(t) for t in prompt)
        now = self._tick() if touch else self._clock
        node, pages, pos = self.root, [], 0
        while pos < len(prompt):
            rem = prompt[pos:]
            best, blen = None, 0
            for ch in node.children.values():
                n = _common(ch.toks, rem)
                if n > blen:
                    best, blen = ch, n
            if best is None or blen == 0:
                break
            pages.append(best.page)
            pos += blen
            if touch:
                best.last_used = now
            if blen < len(best.toks) or len(best.toks) < self.page_size:
                break
            node = best
        return pages, pos

    # ------------------------------------------------------------------ #
    def insert(self, prompt, pages) -> List[int]:
        """Publish a prompt's block-table pages into the trie.

        ``pages[j]`` is the pool page holding prompt rows
        ``[j*page_size, (j+1)*page_size)``.  Segments already present are
        reused (their node's page may differ from ``pages[j]`` — e.g. the
        caller forked a boundary page — and stays authoritative); new
        segments get nodes pointing at the caller's pages.  A divergent
        or longer tail becomes a SIBLING of the existing node — node
        pages are immutable once shared, so an upgrade-in-place would
        corrupt concurrent readers.  Returns the pages that gained a new
        trie reference, for the caller to incref."""
        prompt = tuple(int(t) for t in prompt)
        ps = self.page_size
        now = self._tick()
        node, new_pages = self.root, []
        for j in range(-(-len(prompt) // ps)):
            toks = prompt[j * ps:(j + 1) * ps]
            ch = node.children.get(toks)
            if ch is None and len(toks) < ps:
                # partial tail already covered by a longer sibling: a
                # duplicate node would spend a page on rows the longer
                # one already serves
                if any(_common(c.toks, toks) == len(toks)
                       for c in node.children.values()):
                    break
            if ch is None:
                ch = _Node(toks, int(pages[j]), node)
                node.children[toks] = ch
                self._n_pages += 1
                new_pages.append(int(pages[j]))
            ch.last_used = now
            if len(ch.toks) < ps:
                break
            node = ch
        return new_pages

    # ------------------------------------------------------------------ #
    def evict_lru(self, refs) -> Optional[int]:
        """Drop the least-recently-used zero-ref LEAF (a page only the
        trie still references: ``refs[page] == 1``) and return its page
        for the caller to decref/free.  Interior nodes become evictable
        leaves once their subtrees drain — cascading happens by repeated
        calls.  Returns None when nothing is evictable."""
        best = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                if ch.children:
                    stack.append(ch)
                elif refs[ch.page] == 1 and (
                        best is None or ch.last_used < best.last_used):
                    best = ch
        if best is None:
            return None
        del best.parent.children[best.toks]
        self._n_pages -= 1
        return best.page

    def evictable_pages(self, refs) -> int:
        """Pages reclaimable by cascading ``evict_lru``: nodes whose
        ENTIRE subtree is referenced only by the trie.  A node pinned by
        an active slot (refs > 1) blocks its ancestors — they can never
        become leaves — but not its evictable siblings/descendants."""
        def rec(node: _Node) -> Tuple[int, bool]:
            total, all_ev = 0, True
            for ch in node.children.values():
                t, ev = rec(ch)
                total += t
                all_ev = all_ev and ev
            ev = all_ev and refs[node.page] == 1
            return total + (1 if ev else 0), ev

        return sum(rec(ch)[0] for ch in self.root.children.values())
