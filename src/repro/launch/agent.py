"""Fleet agent: one emulated server process (DESIGN.md §17).

Runs as ``python -m repro.launch.agent --host H --port P --id aN``.
Connects to the master, sends a hello, then loops on lease commands.
Each lease is executed with a fresh :class:`ScheduleExecutor` (sharing
one compiled-program cache across leases, so a composition compiles once
per agent process), restoring every member from its best valid-epoch
checkpoint, stepping the fused group program round-robin in the same
``sorted(names)`` order the single-host executor uses — which is what
makes fleet runs bit-comparable to single-host runs — and finally
checkpointing all members and draining the async writer *before* the
result message goes out (satellite 3: no exit with queued writes).

A heartbeat thread reports ``{job: steps_done}`` progress watermarks on
a fixed interval, tagged with the current lease epoch so the master can
fence messages from a lease it has already revoked. The reporter and
the heartbeat share one send lock; frames never interleave.
"""
from __future__ import annotations

import argparse
import os
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import CheckpointError, checkpoint_crc
from repro.launch.cluster import ScheduleExecutor
from repro.launch.wire import (MessageReader, WireError, send_msg,
                               spec_from_wire)

__all__ = ["AgentRuntime", "agent_main"]


class _LeaseCancelled(Exception):
    pass


def _best_checkpoints(ckpt_dir: str, name: str,
                      epochs: List[int]) -> List[Tuple[int, int, str]]:
    """Candidate restore files for ``name``, best first: highest step,
    then highest epoch. Unreadable files are skipped here; corrupt-but-
    parseable ones are caught by the CRC check at restore time."""
    cands = []
    for e in epochs:
        path = os.path.join(ckpt_dir, f"{name}.e{int(e):04d}.npz")
        if not os.path.exists(path):
            continue
        try:
            with np.load(path) as data:
                step = int(data["step"])
        except Exception:
            continue
        cands.append((step, int(e), path))
    return sorted(cands, reverse=True)


class AgentRuntime:
    """One agent process: reader thread feeding a command loop, plus a
    heartbeat thread. Leases execute on the main thread."""

    def __init__(self, sock: socket.socket, agent_id: str,
                 heartbeat_interval: float = 0.25) -> None:
        self.sock = sock
        self.id = agent_id
        self.heartbeat_interval = heartbeat_interval
        self.send_lock = threading.Lock()
        self._wm_lock = threading.Lock()
        self.watermark: Dict[str, int] = {}
        self.epoch: Optional[int] = None
        self._cancelled: set = set()
        self._stop = threading.Event()
        self._queue: "List[Optional[Dict[str, Any]]]" = []
        self._queue_cond = threading.Condition()
        self._programs: Dict[tuple, Any] = {}   # shared across leases
        self.leases_run = 0

    # -- threads ------------------------------------------------------- #
    def _reader_loop(self) -> None:
        reader = MessageReader(self.sock)
        while True:
            try:
                msg = reader.read()
            except WireError:
                msg = None
            if msg is not None and msg.get("type") == "cancel":
                # out-of-band: the main thread may be inside a lease
                self._cancelled.add(msg.get("lease_id"))
                continue
            with self._queue_cond:
                self._queue.append(msg)
                self._queue_cond.notify()
            if msg is None:
                return

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            with self._wm_lock:
                wm = dict(self.watermark)
                epoch = self.epoch
            try:
                send_msg(self.sock, {"type": "heartbeat", "agent": self.id,
                                     "watermark": wm, "epoch": epoch},
                         self.send_lock)
            except WireError:
                return      # master gone; main loop sees EOF and exits

    # -- main loop ----------------------------------------------------- #
    def run(self) -> None:
        send_msg(self.sock, {"type": "hello", "role": "agent",
                             "id": self.id, "pid": os.getpid()},
                 self.send_lock)
        for target in (self._reader_loop, self._heartbeat_loop):
            threading.Thread(target=target, daemon=True).start()
        try:
            while True:
                with self._queue_cond:
                    while not self._queue:
                        self._queue_cond.wait()
                    msg = self._queue.pop(0)
                if msg is None or msg.get("type") == "shutdown":
                    return
                if msg.get("type") == "lease":
                    self._run_lease(msg)
        finally:
            self._stop.set()
            try:
                self.sock.close()
            except OSError:
                pass

    # -- lease execution ----------------------------------------------- #
    def _run_lease(self, msg: Dict[str, Any]) -> None:
        lease_id, epoch = msg["lease_id"], int(msg["epoch"])
        try:
            report, walltime = self._execute_lease(msg)
        except Exception as exc:   # noqa: BLE001 — reported, not hidden
            with self._wm_lock:
                self.epoch = None
            try:
                send_msg(self.sock,
                         {"type": "lease_error", "lease_id": lease_id,
                          "epoch": epoch,
                          "error": f"{type(exc).__name__}: {exc}"},
                         self.send_lock)
            except WireError:
                pass
            return
        with self._wm_lock:
            self.epoch = None
        try:
            send_msg(self.sock,
                     {"type": "lease_done", "lease_id": lease_id,
                      "epoch": epoch, "walltime": walltime,
                      "report": report},
                     self.send_lock)
        except WireError:
            pass

    def _execute_lease(self, msg: Dict[str, Any]
                       ) -> Tuple[Dict[str, Dict[str, Any]], float]:
        lease_id, epoch = msg["lease_id"], int(msg["epoch"])
        ckpt_dir = msg["ckpt_dir"]
        step_sleep = float(msg.get("step_sleep", 0.0))
        tag = f".e{epoch:04d}"
        self.leases_run += 1
        with self._wm_lock:
            self.epoch = epoch
            self.watermark = {}
        with ScheduleExecutor(
                donate=True, checkpoint_dir=ckpt_dir,
                checkpoint_every=int(msg.get("checkpoint_every", 0)),
                checkpoint_tag=tag,
                program_cache=self._programs) as ex:
            left: Dict[str, int] = {}
            resumed: Dict[str, int] = {}
            for m in msg["members"]:
                name = m["name"]
                ex.submit(name, spec_from_wire(m["spec"]),
                          int(m["total_steps"]))
                ex.start(name, sub_batch=m.get("sub_batch"))
                self._restore_member(ex, name, ckpt_dir,
                                     m.get("restore_epochs") or [])
                steps = ex.runs[name].steps_done
                resumed[name] = steps
                with self._wm_lock:
                    self.watermark[name] = steps
                if steps < int(m["end_step"]):
                    left[name] = int(m["end_step"])
            walltime = 0.0
            while left:
                if lease_id in self._cancelled:
                    raise _LeaseCancelled(f"lease {lease_id} cancelled")
                names = sorted(left)
                res = ex.step_group(names)
                if "dropped" in res:
                    raise RuntimeError(
                        f"member {res['dropped']!r} dropped mid-lease")
                walltime += res["walltime"]
                with self._wm_lock:
                    for n in names:
                        self.watermark[n] = ex.runs[n].steps_done
                for n in names:
                    if ex.runs[n].steps_done >= left[n]:
                        del left[n]
                if step_sleep:
                    time.sleep(step_sleep)
            paths = {m["name"]: ex.checkpoint(m["name"])
                     for m in msg["members"]}
            report: Dict[str, Dict[str, Any]] = {}
            for m in msg["members"]:
                name = m["name"]
                run = ex.runs[name]
                loss = (run.last_metrics or {}).get("loss")
                report[name] = {
                    "steps": run.steps_done,
                    "resumed_from": resumed[name],
                    "loss": None if loss is None else float(loss),
                    "ckpt": os.path.basename(paths[name]),
                }
        # executor closed: every write has landed; CRCs are readable
        for name, rep in report.items():
            rep["crc"] = checkpoint_crc(
                os.path.join(ckpt_dir, rep["ckpt"]))
        return report, walltime

    def _restore_member(self, ex: ScheduleExecutor, name: str,
                        ckpt_dir: str, epochs: List[int]) -> None:
        """Restore from the best valid-epoch checkpoint, falling back to
        the next-best on CRC failure (satellite 1 is what makes reading
        a possibly-mid-crash file safe) and to seeded-init step 0 when
        no usable file exists."""
        for _step, _epoch, path in _best_checkpoints(ckpt_dir, name,
                                                     epochs):
            try:
                ex.restore_run(name, path)
                return
            except (CheckpointError, FileNotFoundError, ValueError):
                continue


def agent_main(host: str, port: int, agent_id: str,
               heartbeat_interval: float = 0.25) -> None:
    sock = socket.create_connection((host, port))
    AgentRuntime(sock, agent_id,
                 heartbeat_interval=heartbeat_interval).run()


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description="repro fleet agent")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--id", default=f"a{os.getpid()}")
    ap.add_argument("--heartbeat", type=float, default=0.25)
    args = ap.parse_args(argv)
    agent_main(args.host, args.port, args.id,
               heartbeat_interval=args.heartbeat)


if __name__ == "__main__":
    main()
