import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------- #
# Multi-pod dry-run (deliverable e): for every (architecture x input
# shape x mesh), ``jit(step).lower(**ShapeDtypeStructs).compile()`` must
# succeed on the production meshes — 16x16 (one pod, 256 chips) and
# 2x16x16 (two pods, 512 chips). The 512 placeholder host devices are
# forced by the XLA_FLAGS line above, set before ANY other import.
#
# Outputs: memory_analysis (fits?), cost_analysis (FLOPs/bytes for
# §Roofline), collective bytes parsed from the optimized HLO, written as
# one JSON artifact per combination under artifacts/dryrun/.
# --------------------------------------------------------------------- #
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import (INPUT_SHAPES, InputShape, input_specs,
                                  shape_applicable, variant_for_shape)
from repro.launch import specs as S
from repro.launch.hlo_flops import hlo_flops_bytes
from repro.launch.hlo_stats import collective_stats, count_op
from repro.launch.mesh import (HBM_BW, HBM_CAPACITY, ICI_BW,
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.models import decode_step, forward
from repro.sharding.hooks import activation_rules
from repro.sharding.rules import ShardingRules, make_rules
from repro.train import TrainConfig, adamw_update, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")


MOE_DISPATCH = "einsum"     # overridden by --moe-dispatch (§Perf C2)


def _arch_for(arch: str, shape: InputShape):
    cfg = get_config(arch)
    cfg = variant_for_shape(cfg, shape)
    if cfg.moe_experts and cfg.moe_experts % 16 != 0:
        # pad experts to the 16-way EP axis (granite 40 -> 48; DESIGN.md §6)
        cfg = dataclasses.replace(
            cfg, moe_pad_to=((cfg.moe_experts + 15) // 16) * 16)
    if cfg.moe_experts and MOE_DISPATCH != "einsum":
        cfg = dataclasses.replace(cfg, moe_dispatch=MOE_DISPATCH)
    return cfg


def build(arch: str, shape_name: str, mesh, *,
          accum_steps: Optional[int] = None,
          seq_shard_override: Optional[bool] = None,
          optimized: bool = False):
    """Returns (fn, kwargs_sds, in_shardings dict, out_shardings)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = _arch_for(arch, shape)
    seq_shard = (shape.name == "long_500k"
                 if seq_shard_override is None else seq_shard_override)
    rules = make_rules(mesh, seq_shard_cache=seq_shard)
    sds = input_specs(cfg, shape)
    p_shape = S.params_shape(cfg)
    p_shard = S.param_shardings(rules, p_shape)

    if shape.kind == "train":
        accum = accum_steps or S.TRAIN_ACCUM_STEPS.get(arch, 1)
        o_shape = S.opt_shape(cfg, p_shape)
        o_shard = S.opt_shardings(rules, o_shape, p_shape)
        tc = TrainConfig(accum_steps=accum,
                         reshard_grads=optimized,
                         grad_reduce_dtype="bfloat16" if optimized
                         else None)
        step = make_train_step(cfg, tc)
        args = (p_shape, o_shape, sds)
        in_sh = (p_shard, o_shard, S.batch_shardings(rules, sds))
        out_sh = (p_shard, o_shard, None)
        fn = step
    elif shape.kind == "prefill":
        def fn(params, batch):
            logits, _ = forward(cfg, params, batch, remat=False)
            return logits
        args = (p_shape, sds)
        in_sh = (p_shard, S.batch_shardings(rules, sds))
        out_sh = None
    else:  # decode
        cache_sds = sds["cache"]
        tok_sds = sds["tokens"]

        def fn(params, cache, tokens):
            return decode_step(cfg, params, cache, tokens)
        args = (p_shape, cache_sds, tok_sds)
        c_shard = S.cache_shardings(rules, cache_sds, seq_shard=seq_shard)
        t_shard = NamedSharding(
            mesh, P(rules.batch, None) if shape.global_batch > 1 else P())
        in_sh = (p_shard, c_shard, t_shard)
        out_sh = (None, c_shard)
    return cfg, rules, fn, args, in_sh, out_sh


def lower_and_compile(arch: str, shape_name: str, *, multi_pod: bool,
                      accum_steps: Optional[int] = None,
                      keep_hlo: bool = False,
                      optimized: bool = False) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    skip = shape_applicable(get_config(arch), shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, rules, fn, args, in_sh, out_sh = build(
        arch, shape_name, mesh, accum_steps=accum_steps,
        optimized=optimized)
    t0 = time.time()
    with activation_rules(rules.activation_table(), mesh, rules=rules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception:   # CPU backend may not implement it
        mem_stats = {}
    hlo = compiled.as_text()
    # trip-count-aware FLOPs/bytes/collectives (XLA cost_analysis counts
    # while bodies once — orders of magnitude off under scan; see
    # hlo_flops.py); collectives use the max(out, operand) wire proxy.
    fb = hlo_flops_bytes(hlo)
    coll = fb["collectives"]

    n_dev = mesh.devices.size
    flops = float(fb["flops"])
    bytes_accessed = float(fb["bytes"])
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": n_dev,
        "accum_steps": (accum_steps or S.TRAIN_ACCUM_STEPS.get(arch, 1)
                        if shape.kind == "train" else None),
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        # per-device numbers for the partitioned module (trip-count aware)
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        # XLA's own (while-bodies-once) numbers kept for comparison
        "xla_cost_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_cost_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "memory_analysis": mem_stats,
        "hlo_instructions": hlo.count("\n"),
        "n_allgather": count_op(hlo, "all-gather"),
        "n_allreduce": count_op(hlo, "all-reduce"),
        "n_reducescatter": count_op(hlo, "reduce-scatter"),
        "n_alltoall": count_op(hlo, "all-to-all"),
        "n_collectivepermute": count_op(hlo, "collective-permute"),
    }
    # roofline terms (single-pod reporting; §Roofline)
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll.get("total", 0.0) / ICI_BW,
    }
    rec["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"),
        key=lambda k: rec["roofline"][k])
    if keep_hlo:
        rec["hlo_path"] = _save_hlo(arch, shape_name, multi_pod, hlo)
    return rec


def _save_hlo(arch, shape_name, multi_pod, hlo) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    p = os.path.join(ARTIFACT_DIR,
                     f"{arch}_{shape_name}_"
                     f"{'2x16x16' if multi_pod else '16x16'}.hlo.txt")
    with open(p, "w") as f:
        f.write(hlo)
    return p


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {tuple(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=None)
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=("einsum", "scatter"))
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper variant: grad reduce-scatter + "
                         "bf16 grad reduction (see EXPERIMENTS.md §Perf)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    global MOE_DISPATCH
    MOE_DISPATCH = args.moe_dispatch
    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failed = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = lower_and_compile(
                        arch, shape, multi_pod=mp,
                        accum_steps=args.accum_steps,
                        keep_hlo=args.keep_hlo,
                        optimized=args.optimized)
                    records.append(rec)
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        print(f"[ok] {tag}: compile={rec['seconds_compile']}s"
                              f" flops/dev={rec['hlo_flops_per_device']:.3e}"
                              f" coll/dev={rec['collective_bytes_per_device']['total']:.3e}B"
                              f" dominant={r['dominant']}", flush=True)
                    else:
                        print(f"[skip] {tag}: {rec['reason']}", flush=True)
                except Exception as e:
                    failed += 1
                    traceback.print_exc()
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "error", "error": str(e)[:2000]})
                    print(f"[FAIL] {tag}: {e}", flush=True)

    out = args.out
    if out is None:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        out = os.path.join(ARTIFACT_DIR, "dryrun.json")
    existing = []
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    merged = {key(r): r for r in existing}
    for r in records:
        merged[key(r)] = r
    with open(out, "w") as f:
        json.dump(list(merged.values()), f, indent=1)
    print(f"wrote {out} ({len(records)} new records, {failed} failures)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
