"""Master/agent multi-host cluster runtime (DESIGN.md §17).

The ScheduleExecutor (§13) runs a whole simulated SJF-BSBF schedule on
ONE host, group by group. This module is the next layer up: a **master**
process that replays a full :func:`plan_from_sim` schedule by *leasing*
sharing groups onto N **agent** processes (process-per-server emulation
over localhost TCP; the lease/heartbeat protocol is transport-agnostic,
so a ``jax.distributed`` deployment swaps the socket for a real network
without touching the state machine). Each agent runs the existing fused
group-step programs; job state crosses processes only through the shared
CRC-verified checkpoint directory.

Robustness is the headline. Real multi-tenant clusters lose workers
constantly (Philly: Jeon et al. 1901.05758 attributes a large share of
job failures to infrastructure), so the master assumes agents die:

* **Heartbeats with progress watermarks** — every agent reports
  ``{job: steps_done}`` on a fixed interval; the master asserts the
  watermark is monotone per lease epoch.
* **Suspect -> dead state machine** — an agent missing heartbeats for
  ``suspect_after`` seconds is SUSPECT (logged, still leased); after
  ``dead_after`` it is DEAD: its leases are revoked and re-dispatched.
  A socket EOF from a *confirmed-exited* process short-circuits straight
  to DEAD (SIGKILL detection is near-instant); EOF from a process the
  master cannot confirm dead only raises SUSPECT — a half-open
  connection is not a death certificate.
* **Lease epochs + fencing** — every lease carries a fresh monotonically
  increasing epoch; agents write checkpoints into per-epoch files
  (``job.e0007.npz``). Results or heartbeats tagged with a revoked epoch
  are discarded (counted in ``stats["fenced"]``), and a fenced epoch's
  checkpoint files are never named in a later lease's
  ``restore_epochs`` — a zombie agent (SIGSTOPped through its timeout,
  then resumed) can neither report stale work nor poison recovery state.
* **Recovery** — a re-dispatched lease restarts each member bit-exactly
  from its best valid-epoch checkpoint (PR 8 restore machinery), or,
  with ``recovery="degrade"``, drops members that never checkpointed and
  re-fuses the survivors. Dispatch itself retries with
  ``repro.util.retry`` full-jitter backoff under an overall wall-clock
  ``deadline`` (:class:`RetryBudgetExceeded` caps a group's recovery
  budget).
* **Chaos** — :class:`ChaosKiller` SIGKILLs/SIGSTOPs agents when their
  progress watermark crosses a scripted threshold, the fleet-tier
  analogue of §16's ScriptedFaults: the same spec replays the same
  failure scenario.

The master doubles as an mgpu_server-shaped job service (submit / queue
/ cancel / status over the same socket) for the ``repro-fleet`` CLI.
"""
from __future__ import annotations

import os
import random
import signal
import socket
import subprocess
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.launch.cluster import JobSpec, SchedulePlan
from repro.launch.wire import (MessageReader, WireError, send_msg,
                               spec_to_wire)
from repro.util.retry import RetryPolicy, retry_call

__all__ = ["AgentHandle", "ChaosKiller", "FleetConfig", "FleetError",
           "FleetMaster", "KillSpec", "Lease", "MasterJob"]


class FleetError(RuntimeError):
    """The fleet could not make progress (no agents, phase timeout, or
    an agent reported an unrecoverable lease error)."""


# --------------------------------------------------------------------- #
# Chaos injection: scripted agent kills
# --------------------------------------------------------------------- #
@dataclass
class KillSpec:
    """Kill ``agent`` once its total progress watermark (steps summed
    over the jobs it is stepping) reaches ``after_steps``. ``sig``
    defaults to SIGKILL (hard crash mid-step); SIGSTOP scripts a zombie
    — alive but silent, which must trip the heartbeat timeout and then
    be fenced if it ever resumes."""

    agent: str
    after_steps: int = 1
    sig: int = signal.SIGKILL


class ChaosKiller:
    """Deterministic agent-kill injector, consulted by the master on
    every heartbeat. Fleet-tier sibling of §16's ScriptedFaults."""

    def __init__(self, specs: Sequence[KillSpec]) -> None:
        self._specs = list(specs)
        self.kills: List[Dict[str, Any]] = []

    def maybe_kill(self, agent_id: str, pid: Optional[int],
                   total_steps: int) -> Optional[KillSpec]:
        for spec in list(self._specs):
            if spec.agent == agent_id and total_steps >= spec.after_steps:
                self._specs.remove(spec)
                if pid is not None:
                    os.kill(pid, spec.sig)
                self.kills.append({"agent": agent_id, "t": time.monotonic(),
                                   "at_steps": total_steps,
                                   "sig": int(spec.sig)})
                return spec
        return None


# --------------------------------------------------------------------- #
# Master-side bookkeeping records
# --------------------------------------------------------------------- #
@dataclass
class FleetConfig:
    heartbeat_interval: float = 0.25
    suspect_after: float = 0.75     # no heartbeat for this long -> SUSPECT
    dead_after: float = 1.5         # -> DEAD: revoke + re-dispatch
    checkpoint_every: int = 1       # agent-side steps between checkpoints
    step_sleep: float = 0.0         # agent pause between fused calls
    recovery: str = "restart"       # "restart" | "degrade"
    respawn: bool = False           # replace dead agents
    retry_policy: RetryPolicy = field(default_factory=lambda: RetryPolicy(
        attempts=6, base=0.05, cap=0.5, deadline=30.0))
    phase_timeout: float = 600.0    # wall-clock cap per plan phase
    spawn_timeout: float = 120.0    # agent hello deadline (jax import)
    retry_seed: int = 0

    def __post_init__(self) -> None:
        if self.recovery not in ("restart", "degrade"):
            raise ValueError(f"unknown recovery mode {self.recovery!r}")


@dataclass
class AgentHandle:
    id: str
    sock: Optional[socket.socket] = None
    proc: Optional[subprocess.Popen] = None
    state: str = "connecting"       # connecting|alive|suspect|dead
    last_hb: float = 0.0
    kill_time: Optional[float] = None
    watermark: Dict[str, int] = field(default_factory=dict)
    leases: set = field(default_factory=set)
    send_lock: threading.Lock = field(default_factory=threading.Lock)

    def confirmed_exited(self) -> bool:
        return self.proc is not None and self.proc.poll() is not None


@dataclass
class MasterJob:
    name: str
    wire_spec: Dict[str, Any]
    total_steps: int
    sub_batch: Optional[int] = None
    steps_done: int = 0
    started: bool = False
    finished: bool = False
    failed: bool = False
    cancelled: bool = False
    queued: bool = False            # service mode: awaiting dispatch
    valid_epochs: List[int] = field(default_factory=list)
    crc: Optional[int] = None
    loss: Optional[float] = None
    walltime: float = 0.0
    redispatches: int = 0

    def report(self) -> Dict[str, Any]:
        return {"steps": self.steps_done, "total_steps": self.total_steps,
                "walltime": self.walltime, "sub_batch": self.sub_batch,
                "finished": self.finished, "failed": self.failed,
                "cancelled": self.cancelled, "crc": self.crc,
                "loss": self.loss, "redispatches": self.redispatches}


@dataclass
class Lease:
    id: int
    epoch: int
    agent_id: str
    members: Tuple[str, ...]
    targets: Dict[str, int]          # name -> end step
    start_steps: Dict[str, int]      # name -> steps_done at dispatch
    plan_group: Tuple[str, ...]      # full group incl. zero-quota members
    status: str = "active"           # active|done|lost|error
    service: bool = False
    error: str = ""
    dispatched_t: float = 0.0


# --------------------------------------------------------------------- #
class FleetMaster:
    """Owns the agent fleet, the lease ledger, and the heartbeat state
    machine. Thread layout: an accept loop (one reader thread per
    connection), a monitor loop (timeout state machine + service-queue
    dispatch), and the caller's thread driving :meth:`run_plan` /
    :meth:`serve_forever`. All shared state sits behind one condition
    variable."""

    def __init__(self, checkpoint_dir: str, *,
                 config: Optional[FleetConfig] = None,
                 chaos: Optional[ChaosKiller] = None) -> None:
        self.checkpoint_dir = checkpoint_dir
        os.makedirs(checkpoint_dir, exist_ok=True)
        self.cfg = config or FleetConfig()
        self.chaos = chaos
        self.agents: Dict[str, AgentHandle] = {}
        self.jobs: Dict[str, MasterJob] = {}
        self.leases: Dict[int, Lease] = {}
        self.events: List[Dict[str, Any]] = []
        self.stats = {"redispatches": 0, "fenced": 0, "respawns": 0,
                      "steps_executed": 0, "steps_lost": 0,
                      "watermark_regressions": 0}
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._epoch = 0
        self._lease_ids = iter(range(1, 1 << 31))
        self._fenced_epochs: set = set()
        self._rng = random.Random(self.cfg.retry_seed)
        self._server: Optional[socket.socket] = None
        self._closing = False
        self._threads: List[threading.Thread] = []
        self._agent_seq = 0
        self._service_queue: List[str] = []   # job names awaiting dispatch
        self.port: Optional[int] = None

    # -- lifecycle ----------------------------------------------------- #
    def start(self, n_agents: int = 0) -> "FleetMaster":
        self._server = socket.create_server(("127.0.0.1", 0))
        self.port = self._server.getsockname()[1]
        for target in (self._accept_loop, self._monitor_loop):
            t = threading.Thread(target=target, daemon=True)
            t.start()
            self._threads.append(t)
        for _ in range(n_agents):
            self.spawn_agent()
        if n_agents:
            self.wait_for_agents(n_agents)
        return self

    def spawn_agent(self, agent_id: Optional[str] = None) -> str:
        """Launch one agent subprocess pointed at this master. Its
        stdout/stderr stream into ``<ckpt_dir>/<id>.log``."""
        import repro
        with self._lock:
            if agent_id is None:
                agent_id = f"a{self._agent_seq}"
                self._agent_seq += 1
        # repro is a namespace package: locate its source root via __path__
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        log = open(os.path.join(self.checkpoint_dir, f"{agent_id}.log"),
                   "ab")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.agent",
             "--host", "127.0.0.1", "--port", str(self.port),
             "--id", agent_id,
             "--heartbeat", str(self.cfg.heartbeat_interval)],
            env=env, stdout=log, stderr=log, close_fds=True)
        log.close()
        with self._lock:
            handle = self.agents.get(agent_id)
            if handle is None:
                handle = AgentHandle(id=agent_id)
                self.agents[agent_id] = handle
            handle.proc = proc
            handle.state = "connecting"
        return agent_id

    def wait_for_agents(self, n: int, timeout: Optional[float] = None
                        ) -> None:
        deadline = time.monotonic() + (timeout or self.cfg.spawn_timeout)
        with self._cond:
            while sum(1 for a in self.agents.values()
                      if a.state == "alive") < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise FleetError(
                        f"{n} agent(s) did not register within "
                        f"{timeout or self.cfg.spawn_timeout:.0f}s")
                self._cond.wait(min(left, 0.1))

    def shutdown(self) -> None:
        with self._lock:
            if self._closing:
                return
            self._closing = True
            handles = list(self.agents.values())
        for h in handles:
            if h.sock is not None:
                try:
                    send_msg(h.sock, {"type": "shutdown"}, h.send_lock)
                except WireError:
                    pass
        if self._server is not None:
            try:
                self._server.close()
            except OSError:
                pass
        for h in handles:
            if h.proc is not None:
                try:
                    h.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    h.proc.kill()
                    h.proc.wait()
            if h.sock is not None:
                try:
                    h.sock.close()
                except OSError:
                    pass

    def __enter__(self) -> "FleetMaster":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    # -- event log ----------------------------------------------------- #
    def _event(self, kind: str, **kw) -> None:
        self.events.append({"t": time.monotonic(), "kind": kind, **kw})

    # -- connection plumbing ------------------------------------------- #
    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                sock, _ = self._server.accept()
            except OSError:
                return
            t = threading.Thread(target=self._conn_loop, args=(sock,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _conn_loop(self, sock: socket.socket) -> None:
        reader = MessageReader(sock)
        try:
            hello = reader.read()
        except WireError:
            hello = None
        if hello is None or hello.get("type") != "hello":
            sock.close()
            return
        if hello.get("role") == "client":
            self._serve_client(sock, reader)
            return
        agent_id = str(hello.get("id"))
        with self._cond:
            handle = self.agents.get(agent_id)
            if handle is None:
                handle = AgentHandle(id=agent_id)
                self.agents[agent_id] = handle
            handle.sock = sock
            handle.state = "alive"
            handle.last_hb = time.monotonic()
            self._event("agent_up", agent=agent_id,
                        pid=hello.get("pid"))
            self._cond.notify_all()
        while True:
            try:
                msg = reader.read()
            except WireError:
                msg = None
            if msg is None:
                self._on_agent_eof(handle)
                return
            self._on_agent_msg(handle, msg)

    # -- agent message handling ---------------------------------------- #
    def _on_agent_msg(self, handle: AgentHandle, msg: Dict[str, Any]
                      ) -> None:
        kind = msg.get("type")
        if kind == "heartbeat":
            self._on_heartbeat(handle, msg)
        elif kind in ("lease_done", "lease_error"):
            self._on_lease_result(handle, msg)

    def _on_heartbeat(self, handle: AgentHandle, msg: Dict[str, Any]
                      ) -> None:
        epoch = msg.get("epoch")
        wm = {str(k): int(v) for k, v in (msg.get("watermark") or
                                          {}).items()}
        kill_pid = None
        with self._cond:
            if handle.state == "dead":
                # a zombie past its timeout: fenced, not resurrected
                self.stats["fenced"] += 1
                return
            handle.last_hb = time.monotonic()
            if handle.state == "suspect":
                handle.state = "alive"
                self._event("agent_recovered", agent=handle.id)
            if epoch is not None and epoch in self._fenced_epochs:
                self.stats["fenced"] += 1
                return
            for name, steps in wm.items():
                prev = handle.watermark.get(name, -1)
                if steps < prev:
                    self.stats["watermark_regressions"] += 1
                handle.watermark[name] = steps
            total = sum(handle.watermark.values())
            if self.chaos is not None and handle.proc is not None:
                kill_pid = handle.proc.pid
            self._cond.notify_all()
        if kill_pid is not None:
            spec = self.chaos.maybe_kill(handle.id, kill_pid, total)
            if spec is not None:
                with self._cond:
                    handle.kill_time = time.monotonic()
                    self._event("chaos_kill", agent=handle.id,
                                sig=int(spec.sig), at_steps=total)

    def _on_lease_result(self, handle: AgentHandle, msg: Dict[str, Any]
                         ) -> None:
        with self._cond:
            lease = self.leases.get(msg.get("lease_id"))
            if (lease is None or lease.status != "active"
                    or msg.get("epoch") != lease.epoch
                    or lease.epoch in self._fenced_epochs):
                self.stats["fenced"] += 1
                self._event("fenced_result", agent=handle.id,
                            lease=msg.get("lease_id"),
                            epoch=msg.get("epoch"))
                return
            handle.leases.discard(lease.id)
            if msg["type"] == "lease_error":
                lease.status = "error"
                lease.error = str(msg.get("error", ""))
                self._event("lease_error", lease=lease.id,
                            agent=handle.id, error=lease.error)
                self._cond.notify_all()
                return
            lease.status = "done"
            report = msg.get("report", {})
            walltime = float(msg.get("walltime", 0.0))
            for name in lease.plan_group:
                job = self.jobs.get(name)
                if job is not None and job.started and not job.finished:
                    job.walltime += walltime
            for name in lease.members:
                job = self.jobs.get(name)
                rep = report.get(name)
                if job is None or rep is None:
                    continue
                job.steps_done = int(rep["steps"])
                job.crc = rep.get("crc")
                if rep.get("loss") is not None:
                    job.loss = float(rep["loss"])
                job.valid_epochs.append(lease.epoch)
                self.stats["steps_executed"] += (
                    int(rep["steps"]) - int(rep.get("resumed_from", 0)))
                if lease.service and job.steps_done >= job.total_steps:
                    job.finished = True
            self._event("lease_done", lease=lease.id, agent=handle.id,
                        epoch=lease.epoch, walltime=walltime)
            self._cond.notify_all()

    # -- failure detection --------------------------------------------- #
    def _on_agent_eof(self, handle: AgentHandle) -> None:
        """Reader saw EOF. A confirmed-exited process is DEAD now; an
        unconfirmed one is only SUSPECT — the heartbeat timeout (or a
        later exit confirmation) finishes the job."""
        with self._cond:
            if handle.state == "dead" or self._closing:
                return
            if handle.confirmed_exited():
                self._mark_dead(handle, reason="exit")
            elif handle.state == "alive":
                handle.state = "suspect"
                self._event("agent_suspect", agent=handle.id,
                            reason="eof")
            self._cond.notify_all()

    def _monitor_loop(self) -> None:
        interval = min(0.05, self.cfg.heartbeat_interval / 4)
        while not self._closing:
            time.sleep(interval)
            now = time.monotonic()
            with self._cond:
                for handle in list(self.agents.values()):
                    if handle.state not in ("alive", "suspect"):
                        continue
                    silent = now - handle.last_hb
                    if handle.sock is None:
                        continue
                    if (handle.state == "alive"
                            and silent > self.cfg.suspect_after):
                        handle.state = "suspect"
                        self._event("agent_suspect", agent=handle.id,
                                    reason="heartbeat", silent=silent)
                    if silent > self.cfg.dead_after or (
                            handle.state == "suspect"
                            and handle.confirmed_exited()):
                        self._mark_dead(
                            handle,
                            reason=("exit" if handle.confirmed_exited()
                                    else "heartbeat"))
                self._dispatch_service_queue()
                self._cond.notify_all()

    def _mark_dead(self, handle: AgentHandle, *, reason: str) -> None:
        """State machine sink (callers hold the lock): revoke the dead
        agent's leases, fence its epochs unless the process provably
        exited, and flag the leases for re-dispatch."""
        if handle.state == "dead":
            return
        handle.state = "dead"
        now = time.monotonic()
        anchor = handle.kill_time if handle.kill_time is not None \
            else handle.last_hb
        latency = max(0.0, now - anchor)
        self._event("agent_dead", agent=handle.id, reason=reason,
                    detection_latency=latency,
                    killed=handle.kill_time is not None)
        trusted = handle.confirmed_exited()
        for lease_id in sorted(handle.leases):
            lease = self.leases.get(lease_id)
            if lease is None or lease.status != "active":
                continue
            lease.status = "lost"
            if trusted:
                # writes that landed before the crash are authoritative
                for name in lease.members:
                    job = self.jobs.get(name)
                    if job is not None:
                        job.valid_epochs.append(lease.epoch)
            else:
                self._fenced_epochs.add(lease.epoch)
            for name in lease.members:
                got = handle.watermark.get(name,
                                           lease.start_steps[name])
                self.stats["steps_lost"] += max(
                    0, got - lease.start_steps[name])
            self._event("lease_lost", lease=lease.id, agent=handle.id,
                        epoch=lease.epoch, fenced=not trusted)
        handle.leases.clear()
        if self.cfg.respawn and not self._closing:
            self.stats["respawns"] += 1
            threading.Thread(target=self.spawn_agent,
                             daemon=True).start()

    # -- lease dispatch ------------------------------------------------ #
    def _pick_agent(self) -> AgentHandle:
        alive = [a for a in self.agents.values() if a.state == "alive"]
        if not alive:
            raise FleetError("no alive agents")
        return min(alive, key=lambda a: (len(a.leases), a.id))

    def _next_epoch(self) -> int:
        self._epoch += 1
        return self._epoch

    def _send_lease(self, lease: Lease, handle: AgentHandle) -> None:
        members = []
        for name in lease.members:
            job = self.jobs[name]
            members.append({
                "name": name,
                "spec": job.wire_spec,
                "total_steps": job.total_steps,
                "sub_batch": job.sub_batch,
                "end_step": lease.targets[name],
                "restore_epochs": [e for e in job.valid_epochs
                                   if e not in self._fenced_epochs],
            })
        send_msg(handle.sock, {
            "type": "lease", "lease_id": lease.id, "epoch": lease.epoch,
            "ckpt_dir": self.checkpoint_dir,
            "checkpoint_every": self.cfg.checkpoint_every,
            "step_sleep": self.cfg.step_sleep,
            "members": members,
        }, handle.send_lock)

    def _dispatch(self, members: Tuple[str, ...],
                  targets: Dict[str, int],
                  plan_group: Tuple[str, ...], *,
                  service: bool = False) -> Lease:
        """Create a fresh-epoch lease for ``members`` and place it on an
        alive agent, retrying with backoff (and an overall deadline)
        through transient dispatch failures — an agent dying between
        pick and send is exactly such a transient."""

        def attempt() -> Lease:
            with self._cond:
                handle = self._pick_agent()
                lease = Lease(
                    id=next(self._lease_ids), epoch=self._next_epoch(),
                    agent_id=handle.id, members=tuple(members),
                    targets=dict(targets),
                    start_steps={n: self.jobs[n].steps_done
                                 for n in members},
                    plan_group=tuple(plan_group), service=service,
                    dispatched_t=time.monotonic())
                try:
                    self._send_lease(lease, handle)
                except WireError as exc:
                    self._mark_dead(handle, reason="send-failed")
                    raise FleetError(str(exc)) from exc
                self.leases[lease.id] = lease
                handle.leases.add(lease.id)
                self._event("lease_dispatch", lease=lease.id,
                            agent=handle.id, epoch=lease.epoch,
                            members=list(members))
                return lease

        return retry_call(attempt, policy=self.cfg.retry_policy,
                          retry_on=(FleetError,), rng=self._rng)

    def _redispatch(self, lost: Lease) -> Optional[Lease]:
        """Re-dispatch a lost lease's group. In ``degrade`` mode,
        members that never reached a usable checkpoint are dropped
        (marked failed) and the survivors re-fuse; in ``restart`` mode
        every member restarts from its best checkpoint or, absent one,
        from step zero — bit-exact either way."""
        with self._lock:
            members = []
            for name in lost.members:
                job = self.jobs[name]
                if job.finished or job.failed or job.cancelled:
                    continue
                if self.cfg.recovery == "degrade" and not any(
                        self._has_checkpoint(name, e)
                        for e in job.valid_epochs
                        if e not in self._fenced_epochs):
                    job.failed = True
                    self._event("member_degraded", job=name,
                                lease=lost.id)
                    continue
                members.append(name)
            for name in members:
                self.jobs[name].redispatches += 1
        if not members:
            return None
        self.stats["redispatches"] += 1
        lease = self._dispatch(tuple(members),
                               {n: lost.targets[n] for n in members},
                               lost.plan_group, service=lost.service)
        self._event("lease_redispatch", old=lost.id, new=lease.id,
                    members=members)
        return lease

    def _has_checkpoint(self, name: str, epoch: int) -> bool:
        return os.path.exists(os.path.join(
            self.checkpoint_dir, f"{name}.e{epoch:04d}.npz"))

    # -- plan execution ------------------------------------------------ #
    def run_plan(self, plan: "SchedulePlan | Sequence",
                 specs: Mapping[str, JobSpec]) -> Dict[str, Dict]:
        """Execute a :class:`SchedulePlan` across the fleet: per phase,
        every sharing group becomes a lease placed on an agent (groups
        run concurrently — the whole simulated schedule executes, not
        one group at a time), with the failure machinery above keeping
        the phase running when agents die. Returns the per-job report,
        with simulator predictions joined when the plan carries them."""
        phases = plan.phases if isinstance(plan, SchedulePlan) else plan
        totals: Dict[str, int] = {}
        for phase in phases:
            for name, q in phase.quotas:
                totals[name] = totals.get(name, 0) + q
        with self._lock:
            for name, spec in specs.items():
                self.jobs[name] = MasterJob(
                    name=name, wire_spec=spec_to_wire(spec),
                    total_steps=totals.get(name, 0))
        for phase in phases:
            for op in phase.ops:
                self._apply_plan_op(op)
            with self._lock:
                targets: Dict[str, int] = {}
                for name, q in phase.quotas:
                    job = self.jobs[name]
                    if (q > 0 and job.started and not job.finished
                            and not job.failed):
                        targets[name] = job.steps_done + q
            leases = []
            for group in phase.groups:
                members = tuple(n for n in group if n in targets)
                if members:
                    leases.append(self._dispatch(
                        members, {n: targets[n] for n in members},
                        plan_group=tuple(group)))
            self._await_leases(leases)
        report = {name: job.report()
                  for name, job in sorted(self.jobs.items())}
        if isinstance(plan, SchedulePlan):
            for name, pred in plan.predicted.items():
                rep = report.get(name)
                if rep is not None:
                    rep["predicted_exec"] = pred["exec_seconds"]
        return report

    def _apply_plan_op(self, op) -> None:
        with self._lock:
            job = self.jobs[op.job]
            if op.kind == "start":
                job.started = True
                if op.sub_batch is not None:
                    job.sub_batch = int(op.sub_batch)
            elif op.kind == "reconfig":
                job.sub_batch = int(op.sub_batch)
            elif op.kind == "finish":
                if job.failed:
                    return
                if job.steps_done != job.total_steps:
                    raise FleetError(
                        f"job {op.job!r} finished at {job.steps_done}/"
                        f"{job.total_steps} steps")
                job.finished = True
            else:
                raise ValueError(f"unknown plan op {op.kind!r}")

    def _await_leases(self, leases: List[Lease]) -> None:
        """Block until every lease reaches a terminal state, re-
        dispatching lost ones as the monitor flags them. Bounded by
        ``phase_timeout`` so a wedged fleet fails loudly, never hangs."""
        pending = {l.id: l for l in leases}
        deadline = time.monotonic() + self.cfg.phase_timeout
        while pending:
            redo: List[Lease] = []
            with self._cond:
                for lease in list(pending.values()):
                    if lease.status == "done":
                        del pending[lease.id]
                    elif lease.status == "lost":
                        del pending[lease.id]
                        redo.append(lease)
                    elif lease.status == "error":
                        raise FleetError(
                            f"lease {lease.id} failed on agent "
                            f"{lease.agent_id}: {lease.error}")
                if not redo:
                    if not pending:
                        return
                    if time.monotonic() > deadline:
                        raise FleetError(
                            f"phase timed out after "
                            f"{self.cfg.phase_timeout:.0f}s with "
                            f"{len(pending)} lease(s) outstanding")
                    self._cond.wait(0.05)
            for lost in redo:
                fresh = self._redispatch(lost)
                if fresh is not None:
                    pending[fresh.id] = fresh

    # -- service mode (mgpu_server-shaped) ----------------------------- #
    def submit_job(self, wire_spec: Dict[str, Any], steps: int,
                   name: Optional[str] = None,
                   sub_batch: Optional[int] = None) -> str:
        with self._cond:
            if name is None:
                name = f"job{len(self.jobs)}"
            if name in self.jobs:
                raise FleetError(f"job {name!r} already submitted")
            job = MasterJob(name=name, wire_spec=wire_spec,
                            total_steps=int(steps), sub_batch=sub_batch,
                            started=True, queued=True)
            self.jobs[name] = job
            self._service_queue.append(name)
            self._event("submit", job=name, steps=int(steps))
            self._cond.notify_all()
        return name

    def _dispatch_service_queue(self) -> None:
        """Monitor-loop hook (lock held): lease queued jobs onto idle
        agents, requeue jobs whose lease was lost."""
        for lease in list(self.leases.values()):
            if lease.service and lease.status == "lost":
                lease.status = "requeued"
                for name in lease.members:
                    job = self.jobs.get(name)
                    if job and not (job.finished or job.cancelled
                                    or job.queued):
                        job.queued = True
                        job.redispatches += 1
                        self._service_queue.append(name)
                        self.stats["redispatches"] += 1
        while self._service_queue:
            idle = [a for a in self.agents.values()
                    if a.state == "alive" and not a.leases]
            if not idle:
                return
            name = self._service_queue[0]
            job = self.jobs[name]
            if job.cancelled or job.finished:
                self._service_queue.pop(0)
                job.queued = False
                continue
            handle = min(idle, key=lambda a: a.id)
            lease = Lease(
                id=next(self._lease_ids), epoch=self._next_epoch(),
                agent_id=handle.id, members=(name,),
                targets={name: job.total_steps},
                start_steps={name: job.steps_done},
                plan_group=(name,), service=True,
                dispatched_t=time.monotonic())
            try:
                self._send_lease(lease, handle)
            except WireError:
                self._mark_dead(handle, reason="send-failed")
                continue
            self._service_queue.pop(0)
            job.queued = False
            self.leases[lease.id] = lease
            handle.leases.add(lease.id)
            self._event("lease_dispatch", lease=lease.id,
                        agent=handle.id, epoch=lease.epoch,
                        members=[name], service=True)

    def cancel_job(self, name: str) -> bool:
        with self._cond:
            job = self.jobs.get(name)
            if job is None or job.finished or job.cancelled:
                return False
            job.cancelled = True
            job.queued = False
            if name in self._service_queue:
                self._service_queue.remove(name)
            for lease in self.leases.values():
                if lease.status == "active" and name in lease.members:
                    handle = self.agents.get(lease.agent_id)
                    if handle is not None and handle.sock is not None:
                        try:
                            send_msg(handle.sock,
                                     {"type": "cancel",
                                      "lease_id": lease.id},
                                     handle.send_lock)
                        except WireError:
                            pass
            self._event("cancel", job=name)
            return True

    def status(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "port": self.port,
                "agents": {a.id: {"state": a.state,
                                  "leases": sorted(a.leases),
                                  "watermark": dict(a.watermark)}
                           for a in self.agents.values()},
                "jobs": {n: j.report() for n, j in self.jobs.items()},
                "queue": list(self._service_queue),
                "stats": dict(self.stats),
            }

    def wait_for_job(self, name: str, timeout: float = 600.0) -> Dict:
        deadline = time.monotonic() + timeout
        with self._cond:
            while True:
                job = self.jobs[name]
                if job.finished or job.failed or job.cancelled:
                    return job.report()
                if time.monotonic() > deadline:
                    raise FleetError(f"job {name!r} did not finish in "
                                     f"{timeout:.0f}s")
                self._cond.wait(0.1)

    # -- client (CLI) connections -------------------------------------- #
    def _serve_client(self, sock: socket.socket,
                      reader: MessageReader) -> None:
        try:
            msg = reader.read()
            if msg is None:
                return
            kind = msg.get("type")
            if kind == "submit":
                try:
                    name = self.submit_job(
                        msg["spec"], int(msg["steps"]),
                        name=msg.get("name"),
                        sub_batch=msg.get("sub_batch"))
                    resp = {"ok": True, "job": name}
                except (FleetError, KeyError, ValueError) as exc:
                    resp = {"ok": False, "error": str(exc)}
            elif kind in ("status", "queue"):
                resp = {"ok": True, **self.status()}
            elif kind == "cancel":
                resp = {"ok": self.cancel_job(str(msg.get("job")))}
            elif kind == "shutdown":
                resp = {"ok": True}
            else:
                resp = {"ok": False, "error": f"unknown request {kind!r}"}
            send_msg(sock, resp)
            if kind == "shutdown":
                threading.Thread(target=self.shutdown,
                                 daemon=True).start()
        except WireError:
            pass
        finally:
            try:
                sock.close()
            except OSError:
                pass
