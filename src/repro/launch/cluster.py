"""Schedule-driven multi-job executor (DESIGN.md §13).

The physical layer beneath the scheduling policies: where
``repro.core.coschedule`` could only time a fixed 2-job pair, the
:class:`ScheduleExecutor` runs an **N-way interleaved fused step
program** per sharing group — one jitted XLA program that advances every
member one (possibly gradient-accumulated) training step per call, the
TPU analogue of the paper's GPU time multiplexing — and consumes a
timeline of schedule events:

* ``start``     — a job joins a group with the sub-batch Algorithm 2
                  chose (its gradient-accumulation count follows as
                  ``s = ceil(B / b)``);
* ``reconfig``  — mid-run (τ, sub-batch) reconfiguration: the group
                  program is re-fused with the new accumulation
                  sub-batch while the job's params/optimizer state carry
                  through bit-exactly (the effective batch — and hence
                  convergence — is unchanged; the ragged final
                  micro-batch is masked, see ``repro.train.grad_accum``);
* ``finish``    — the member leaves; the surviving group re-fuses.

Fused programs are AOT-compiled (``jit(...).lower(...).compile()``) and
cached by group composition — (arch config, accumulation count, batch,
seq) per member — so compile time never pollutes the measured walltimes
and a recurring composition costs one compile per executor.

:func:`plan_from_sim` closes the loop with the simulator: it replays a
``Simulator`` event log into a :class:`SchedulePlan` — phases between
schedule events, each with per-job step quotas derived from the
simulated rates and the sharing groups as connected components of GPU
co-tenancy — which :meth:`ScheduleExecutor.execute` runs on this host,
reporting measured per-job execution seconds next to the simulator's
prediction (the Table-2-style validation of
``benchmarks/replay_validation.py``).
"""
from __future__ import annotations

import dataclasses
import math
import os
import queue
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import checkpoint as _ckpt
from repro.configs.base import ArchConfig
from repro.data import make_batch
from repro.models import init_params
from repro.train import TrainConfig, adamw_init, make_train_step
from repro.util.retry import RetryPolicy, retry_call


# ---------------------------------------------------------------------- #
# Fault injection (DESIGN.md §16)
# ---------------------------------------------------------------------- #
class TransientFault(RuntimeError):
    """A recoverable step failure (the physical analogue of an ECC blip
    or a flaky interconnect): the executor retries the fused call with
    backoff. Raised by fault injectors *before* the program call —
    donated buffers are still intact, so the retry replays the exact
    same step."""

    def __init__(self, job: str, msg: str = "") -> None:
        self.job = job
        super().__init__(msg or f"transient fault on job {job!r}")


class FatalFault(RuntimeError):
    """An unrecoverable member failure (OOM-killed worker, dead host):
    not retried — the member drops from its group, survivors re-fuse,
    and the job restarts later from its last checkpoint."""

    def __init__(self, job: str, msg: str = "") -> None:
        self.job = job
        super().__init__(msg or f"fatal fault on job {job!r}")


@dataclass
class FaultSpec:
    """One scripted fault: fires when the executor's fused-call counter
    reaches ``call`` and ``job`` is a member of that call. ``times`` is
    the number of consecutive attempts it poisons — a transient spec
    with ``times < retry attempts`` is survived by the retry loop, one
    with ``times >= attempts`` exhausts it (and escalates to a drop)."""

    call: int
    job: str
    kind: str = "transient"     # "transient" | "fatal"
    times: int = 1

    def __post_init__(self) -> None:
        if self.kind not in ("transient", "fatal"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class ScriptedFaults:
    """Deterministic fault injector for the executor: a list of
    :class:`FaultSpec` consulted before every fused call. Scripted
    faults make recovery testable — the same script replays the same
    failure sequence bit-exactly."""

    def __init__(self, specs: Sequence[FaultSpec]) -> None:
        self._remaining = [(s, [s.times]) for s in specs]

    def check(self, call: int, names: Sequence[str]) -> None:
        for spec, rem in self._remaining:
            if spec.call == call and spec.job in names and rem[0] > 0:
                rem[0] -= 1
                if spec.kind == "fatal":
                    raise FatalFault(spec.job)
                raise TransientFault(spec.job)


# ---------------------------------------------------------------------- #
# Job specification and state
# ---------------------------------------------------------------------- #
@dataclass
class JobSpec:
    """One physical training job: architecture, per-step user batch, and
    the gradient-accumulation split (re-exported as
    ``repro.core.coschedule.JobSpec`` for the pair-shaped API)."""

    cfg: ArchConfig
    batch: int                  # per-step user batch
    accum_steps: int = 1        # gradient-accumulation sub-steps
    seq: int = 128
    seed: int = 0

    def train_config(self) -> TrainConfig:
        return TrainConfig(accum_steps=self.accum_steps)


def _make_state(spec: JobSpec):
    params = init_params(spec.cfg, jax.random.PRNGKey(spec.seed))
    opt = adamw_init(params)
    batch = make_batch(spec.cfg, spec.batch, spec.seq, seed=spec.seed)
    return params, opt, batch


def accum_for_sub_batch(batch: int, sub_batch: int) -> int:
    """s = ceil(B / b) — the final micro-batch absorbs the remainder
    (masked, so the effective batch is exactly B; same rule as the
    simulator's ``Engine.start_job``)."""
    if sub_batch < 1:
        raise ValueError(f"sub_batch must be >= 1, got {sub_batch}")
    return max(1, math.ceil(batch / min(sub_batch, batch)))


def make_group_step(specs: Sequence[JobSpec], *, donate: bool = False):
    """One jitted program stepping EVERY job in ``specs`` (time-
    multiplexed: member i runs its full — possibly accumulated — train
    step, then member i+1, ...). Signature is flat:

        (p0, o0, b0, p1, o1, b1, ...) -> (p0', o0', m0, p1', o1', m1, ...)

    ``donate=True`` donates all members' params/opt-states (the
    production configuration); callers must then re-bind them from the
    outputs each call."""
    steps = [make_train_step(s.cfg, s.train_config()) for s in specs]

    def group_step(*state):
        out: List[Any] = []
        for i, step in enumerate(steps):
            p, o, m = step(*state[3 * i:3 * i + 3])
            out += [p, o, m]
        return tuple(out)

    donate_argnums = (tuple(x for i in range(len(steps))
                            for x in (3 * i, 3 * i + 1)) if donate else ())
    return jax.jit(group_step, donate_argnums=donate_argnums)


@dataclass
class JobRun:
    """Live state of one job inside the executor."""

    name: str
    spec: JobSpec
    total_steps: int
    sub_batch: int = 0          # current per-step sub-batch (0 = full)
    accum_steps: int = 1        # current accumulation count
    params: Any = field(default=None, repr=False)
    opt: Any = field(default=None, repr=False)
    batch: Any = field(default=None, repr=False)
    steps_done: int = 0
    walltime: float = 0.0       # attributed execution seconds
    started: bool = False
    finished: bool = False
    failed: bool = False        # dropped by a fault; restart() clears
    restarts: int = 0
    retries: int = 0            # transient faults absorbed by backoff
    last_ckpt_step: int = -1    # steps_done at the last checkpoint
    reconfigs: List[Tuple[int, int]] = field(default_factory=list)
    last_metrics: Any = field(default=None, repr=False)

    def report(self) -> Dict[str, Any]:
        out = {
            "steps": self.steps_done,
            "walltime": self.walltime,
            "sub_batch": self.sub_batch,
            "accum_steps": self.accum_steps,
            "reconfigs": list(self.reconfigs),
            "failed": self.failed,
            "restarts": self.restarts,
            "retries": self.retries,
        }
        if self.last_metrics is not None:
            out["loss"] = float(self.last_metrics["loss"])
        return out


# ---------------------------------------------------------------------- #
# Schedule plan: events + phases
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class PlanOp:
    """Schedule event applied at a phase boundary."""

    kind: str                       # "start" | "reconfig" | "finish"
    job: str
    sub_batch: Optional[int] = None


@dataclass(frozen=True)
class PlanPhase:
    """Interval between two schedule events: ``ops`` fire at entry, then
    every sharing group advances its members' step ``quotas``
    round-robin. Each group's walltime is attributed to *all* its
    running members — a time-multiplexed tenant pays for its co-tenants'
    rounds exactly as it would on a shared GPU."""

    ops: Tuple[PlanOp, ...]
    quotas: Tuple[Tuple[str, int], ...]
    groups: Tuple[Tuple[str, ...], ...]
    sim_duration: float = 0.0       # predicted interval length (seconds)


@dataclass
class SchedulePlan:
    phases: List[PlanPhase]
    predicted: Dict[str, Dict[str, float]]   # name -> {exec_seconds, ...}


# ---------------------------------------------------------------------- #
class ScheduleExecutor:
    """Executes a schedule of N-way shared training groups on this host.

    ``rules`` optionally carries a ``repro.sharding.rules.ShardingRules``
    bundle; fused programs are then traced and run under its activation
    partitioning context (a no-op on a single-device host)."""

    def __init__(self, *, donate: bool = True, rules=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 checkpoint_tag: str = "",
                 program_cache: Optional[Dict[tuple, Any]] = None,
                 fault_injector: Optional[ScriptedFaults] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 retry_seed: int = 0,
                 sleep=time.sleep) -> None:
        self.runs: Dict[str, JobRun] = {}
        self.rules = rules
        self.donate = donate
        # ``program_cache`` may be a shared dict: a fleet agent keeps one
        # cache across the per-lease executors it creates, so a recurring
        # group composition compiles once per process, not once per lease
        self._programs: Dict[tuple, Any] = (
            program_cache if program_cache is not None else {})
        self.compiles = 0
        self.calls = 0
        # fault tolerance (DESIGN.md §16): periodic async checkpoints,
        # bounded-backoff retry of transient step faults, and a degrade
        # path dropping fatally-failed members from their fused group
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        # tag lands between the job name and ".npz": the fleet layer
        # writes per-lease-epoch files (``job.e0003.npz``) so a fenced
        # zombie epoch can never clobber the authoritative state
        self.checkpoint_tag = checkpoint_tag
        self.fault_injector = fault_injector
        self.retry_policy = retry_policy or RetryPolicy()
        self._retry_rng = random.Random(retry_seed)
        self._sleep = sleep
        self.retries_total = 0
        self.drops_total = 0
        self.checkpoints_written = 0
        self._ckpt_queue: Optional[queue.Queue] = None
        self._ckpt_thread: Optional[threading.Thread] = None
        self._ckpt_errors: List[BaseException] = []

    # -- job lifecycle ------------------------------------------------- #
    def submit(self, name: str, spec: JobSpec, steps: int) -> JobRun:
        if name in self.runs:
            raise ValueError(f"job {name!r} already submitted")
        run = JobRun(name=name, spec=spec, total_steps=int(steps),
                     sub_batch=spec.batch,
                     accum_steps=spec.accum_steps)
        self.runs[name] = run
        return run

    def start(self, name: str, *, sub_batch: Optional[int] = None,
              state: Optional[tuple] = None) -> JobRun:
        """Materialize the job's params/opt/batch and (optionally) apply
        the sub-batch Algorithm 2 chose at the sharing time point.
        ``state`` accepts prebuilt (params, opt, batch) — the calibration
        pipeline passes copies of a pristine master state instead of
        re-initializing the model for every measurement."""
        run = self.runs[name]
        if run.started:
            raise RuntimeError(f"job {name!r} already started")
        if sub_batch is not None:
            run.sub_batch = int(sub_batch)
            run.accum_steps = accum_for_sub_batch(run.spec.batch,
                                                  run.sub_batch)
        run.params, run.opt, run.batch = (state if state is not None
                                          else _make_state(run.spec))
        run.started = True
        return run

    def reconfigure(self, name: str, sub_batch: int) -> JobRun:
        """Mid-run (τ, sub-batch) reconfiguration: the job's next fused
        program accumulates at the new sub-batch; params/opt state carry
        through untouched (bit-exact) and the effective batch is
        unchanged."""
        run = self.runs[name]
        if not run.started or run.finished:
            raise RuntimeError(f"job {name!r} not running")
        run.sub_batch = int(sub_batch)
        run.accum_steps = accum_for_sub_batch(run.spec.batch, run.sub_batch)
        run.reconfigs.append((run.steps_done, run.sub_batch))
        return run

    def finish(self, name: str) -> JobRun:
        run = self.runs[name]
        if run.steps_done != run.total_steps:
            raise RuntimeError(
                f"job {name!r} finished at {run.steps_done}/"
                f"{run.total_steps} steps")
        run.finished = True
        return run

    # -- fused programs ------------------------------------------------ #
    def _ctx(self):
        if self.rules is None:
            import contextlib
            return contextlib.nullcontext()
        from repro.sharding.hooks import activation_rules
        return activation_rules(self.rules.activation_table(),
                                self.rules.mesh)

    def _program_key(self, runs: Sequence[JobRun]) -> tuple:
        return (self.donate,) + tuple(
            (r.spec.cfg, r.accum_steps, r.spec.batch, r.spec.seq)
            for r in runs)

    def _program(self, runs: Sequence[JobRun]):
        key = self._program_key(runs)
        prog = self._programs.get(key)
        if prog is None:
            specs = [dataclasses.replace(r.spec, accum_steps=r.accum_steps)
                     for r in runs]
            fused = make_group_step(specs, donate=self.donate)
            args = self._flat_args(runs)
            with self._ctx():
                prog = fused.lower(*args).compile()
                # warm the executable on throwaway zero states so the
                # first measured call pays no first-touch cost (the real
                # states are untouched — a warmup on them would advance
                # training)
                dummy = jax.tree.map(jnp.zeros_like, args)
                jax.block_until_ready(prog(*dummy))
            self._programs[key] = prog
            self.compiles += 1
        return prog

    @staticmethod
    def _flat_args(runs: Sequence[JobRun]) -> tuple:
        args: List[Any] = []
        for r in runs:
            args += [r.params, r.opt, r.batch]
        return tuple(args)

    # -- checkpoint / restart (DESIGN.md §16) -------------------------- #
    def _ckpt_path(self, name: str) -> str:
        assert self.checkpoint_dir is not None
        return os.path.join(self.checkpoint_dir,
                            f"{name}{self.checkpoint_tag}.npz")

    def _ckpt_worker(self) -> None:
        q = self._ckpt_queue
        while True:
            item = q.get()
            if item is None:
                q.task_done()
                return
            path, tree = item
            try:
                _ckpt.save_pytree(path, tree)
                self.checkpoints_written += 1
            except BaseException as exc:   # surfaced at the next flush
                self._ckpt_errors.append(exc)
            finally:
                q.task_done()

    def checkpoint(self, name: str) -> str:
        """Snapshot ``name``'s params/opt/step to its checkpoint file.
        The device->host copy happens here (so later donated-buffer
        rebinds cannot corrupt it); the npz write runs on a background
        worker thread — training does not stall on disk. The write
        itself is atomic (tmp + fsync + rename, ``repro.checkpoint``)."""
        if self.checkpoint_dir is None:
            raise RuntimeError("executor has no checkpoint_dir")
        run = self.runs[name]
        if not run.started:
            raise RuntimeError(f"job {name!r} not started")
        tree = {"params": run.params, "step": jnp.asarray(run.steps_done)}
        if run.opt is not None:
            tree["opt"] = run.opt
        snap = jax.device_get(tree)
        if self._ckpt_queue is None:
            self._ckpt_queue = queue.Queue()
            self._ckpt_thread = threading.Thread(
                target=self._ckpt_worker, daemon=True)
            self._ckpt_thread.start()
        path = self._ckpt_path(name)
        self._ckpt_queue.put((path, snap))
        run.last_ckpt_step = run.steps_done
        return path

    def flush_checkpoints(self) -> None:
        """Block until every queued checkpoint write has landed; re-raise
        the first background write error, if any."""
        if self._ckpt_queue is not None:
            self._ckpt_queue.join()
        if self._ckpt_errors:
            raise self._ckpt_errors[0]

    def close(self) -> None:
        """Drain and join the background checkpoint writer. The happy
        path only ever ``flush``-ed — which leaves the worker thread
        parked on its queue — so agent teardown (and any other process
        exit path) must call this to guarantee every queued write landed
        before the interpreter goes away. Idempotent; re-raises the
        first background write error like :meth:`flush_checkpoints`."""
        q, t = self._ckpt_queue, self._ckpt_thread
        self._ckpt_queue = None
        self._ckpt_thread = None
        if q is not None:
            q.join()                 # all queued writes landed
            q.put(None)              # stop sentinel
            if t is not None:
                t.join()
        if self._ckpt_errors:
            raise self._ckpt_errors[0]

    def __enter__(self) -> "ScheduleExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight exception with a flush error
        try:
            self.close()
        except BaseException:
            if exc_type is None:
                raise

    def restore_run(self, name: str, path: str) -> JobRun:
        """Load params/opt/step from an explicit checkpoint file into a
        started run (the fleet agent's lease-resume path: the master
        names which epoch's file is authoritative). CRC-verified by the
        checkpoint layer; raises CheckpointError on bit-rot."""
        run = self.runs[name]
        if not run.started:
            raise RuntimeError(f"job {name!r} not started")
        params, opt, step = _ckpt.restore(
            path, params_like=run.params, opt_like=run.opt)
        run.params, run.opt = params, opt
        run.steps_done = int(step)
        run.last_ckpt_step = run.steps_done
        return run

    def restart(self, name: str) -> JobRun:
        """Recover a failed (or stopped) job: pending checkpoint writes
        are flushed, then params/opt/step restore from the job's last
        checkpoint — or from a fresh init when it never checkpointed.
        The training data stream is a fixed per-job batch, so a restart
        replays the remaining steps bit-exactly (test-pinned)."""
        run = self.runs[name]
        if not run.started:
            raise RuntimeError(f"job {name!r} not started")
        self.flush_checkpoints()
        params, opt, batch = _make_state(run.spec)
        path = (self._ckpt_path(name)
                if self.checkpoint_dir is not None else None)
        if path is not None and os.path.exists(path):
            params, opt, step = _ckpt.restore(
                path, params_like=params, opt_like=opt)
            run.steps_done = int(step)
        else:
            run.steps_done = 0
        run.params, run.opt, run.batch = params, opt, batch
        run.failed = False
        run.restarts += 1
        return run

    # -- execution ----------------------------------------------------- #
    def step_group(self, names: Sequence[str]) -> Dict[str, Any]:
        """One fused call advancing every named job one step. Returns the
        call's walltime (compile excluded — programs are AOT-compiled on
        first use) and per-job losses.

        Fault path: the injector (if any) is consulted *before* the
        program call — donation means a completed call has already
        consumed the input buffers, so faults must strike pre-call for a
        retry to be possible. Transient faults retry with bounded
        backoff; a fatal fault (or an exhausted retry budget) marks the
        faulting member ``failed`` and returns ``{"dropped": name}`` —
        the caller drops it and keeps stepping the survivors (the next
        fused call re-fuses automatically: programs are cached by group
        composition)."""
        runs = [self.runs[n] for n in names]
        for r in runs:
            if not r.started or r.finished or r.failed:
                raise RuntimeError(f"job {r.name!r} not running")
        prog = self._program(runs)

        def attempt():
            if self.fault_injector is not None:
                self.fault_injector.check(self.calls, names)
            args = self._flat_args(runs)
            with self._ctx():
                t0 = time.perf_counter()
                out = prog(*args)
                jax.block_until_ready(out)
                return out, time.perf_counter() - t0

        def note_retry(attempt_i, exc, delay):
            self.retries_total += 1
            self.runs[exc.job].retries += 1

        try:
            out, dt = retry_call(attempt, policy=self.retry_policy,
                                 retry_on=(TransientFault,),
                                 rng=self._retry_rng, sleep=self._sleep,
                                 on_retry=note_retry)
        except (TransientFault, FatalFault) as exc:
            run = self.runs[exc.job]
            run.failed = True
            self.drops_total += 1
            return {"walltime": 0.0, "losses": {}, "dropped": exc.job}
        losses = {}
        for i, r in enumerate(runs):
            r.params, r.opt, r.last_metrics = out[3 * i:3 * i + 3]
            r.steps_done += 1
            losses[r.name] = float(r.last_metrics["loss"])
            if (self.checkpoint_dir is not None and self.checkpoint_every
                    and r.steps_done % self.checkpoint_every == 0):
                self.checkpoint(r.name)
        self.calls += 1
        return {"walltime": dt, "losses": losses}

    def _apply(self, op: PlanOp) -> None:
        if op.kind == "start":
            self.start(op.job, sub_batch=op.sub_batch)
        elif op.kind == "reconfig":
            self.reconfigure(op.job, op.sub_batch)
        elif op.kind == "finish":
            self.finish(op.job)
        else:
            raise ValueError(f"unknown plan op {op.kind!r}")

    def execute(self, plan: "SchedulePlan | Sequence[PlanPhase]",
                ) -> Dict[str, Dict[str, Any]]:
        """Run a schedule plan to completion and return the per-job
        report: measured execution seconds (each group phase's walltime
        attributed to every running member), steps, final sub-batch, and
        — when the plan carries simulator predictions — the
        predicted-vs-measured error."""
        phases = plan.phases if isinstance(plan, SchedulePlan) else plan
        for phase in phases:
            for op in phase.ops:
                self._apply(op)
            quotas = dict(phase.quotas)
            for group in phase.groups:
                left = {n: quotas.get(n, 0) for n in group
                        if quotas.get(n, 0) > 0 and not self.runs[n].failed}
                t_group = 0.0
                while left:
                    members = sorted(left)
                    res = self.step_group(members)
                    dropped = res.get("dropped")
                    if dropped is not None:
                        # degraded mode: the failed member leaves, the
                        # survivors keep their quotas (the next call
                        # re-fuses the smaller group from the cache)
                        del left[dropped]
                        continue
                    t_group += res["walltime"]
                    for n in members:
                        left[n] -= 1
                        if left[n] == 0:
                            del left[n]
                for n in group:
                    run = self.runs[n]
                    if run.started and not run.finished and not run.failed:
                        run.walltime += t_group
        report = {name: run.report() for name, run in self.runs.items()}
        if isinstance(plan, SchedulePlan):
            for name, pred in plan.predicted.items():
                rep = report.get(name)
                if rep is None:
                    continue
                rep["predicted_exec"] = pred["exec_seconds"]
                rep["measured_exec"] = rep["walltime"]
                if pred["exec_seconds"] > 0:
                    rep["error"] = (rep["walltime"] - pred["exec_seconds"]
                                    ) / pred["exec_seconds"]
        return report


# ---------------------------------------------------------------------- #
# Simulator-log replay: schedule -> executable plan
# ---------------------------------------------------------------------- #
def _components(placements: Mapping[int, frozenset]) -> List[List[int]]:
    """Connected components of the sharing graph: jobs sharing any GPU
    (directly or transitively) execute as one time-multiplexed group."""
    parent: Dict[int, int] = {j: j for j in placements}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    by_gpu: Dict[int, List[int]] = {}
    for jid, gpus in placements.items():
        for g in gpus:
            by_gpu.setdefault(g, []).append(jid)
    for tenants in by_gpu.values():
        for other in tenants[1:]:
            ra, rb = find(tenants[0]), find(other)
            if ra != rb:
                parent[rb] = ra
    comps: Dict[int, List[int]] = {}
    for j in placements:
        comps.setdefault(find(j), []).append(j)
    return [sorted(c) for c in comps.values()]


def plan_from_sim(log: Sequence[tuple], jobs: Mapping[int, Any],
                  interference, gpu_capacity_bytes: float,
                  *, names: Optional[Mapping[int, str]] = None,
                  ) -> SchedulePlan:
    """Translate a ``Simulator`` event log into an executable
    :class:`SchedulePlan`.

    The log's ``start``/``config``/``reconfig``/``finish`` entries become
    plan ops; between events, each running job's simulated progress
    (rate x interval, with the rate re-derived from its PerfParams
    sub-batch timing and the max-xi-over-co-runners rule the engines
    use) accrues fractionally and is emitted as integer step quotas by
    cumulative rounding, so every job executes exactly ``job.iters``
    host steps by its finish event. Sharing groups are the connected
    components of GPU co-tenancy. ``jobs`` maps jid -> the simulated
    ``repro.core.Job``; ``names`` optionally renames jobs for the
    executor (default ``job<jid>``)."""
    names = names or {}

    def name_of(jid: int) -> str:
        return names.get(jid, f"job{jid}")

    placements: Dict[int, frozenset] = {}
    sub_batch: Dict[int, int] = {}
    cum: Dict[int, float] = {}
    emitted: Dict[int, int] = {}

    def rate(jid: int) -> float:
        job = jobs[jid]
        base = job.perf.t_iter_sub(job.batch, sub_batch[jid])
        xi = 1.0
        others = set()
        for g in placements[jid]:
            for other in by_gpu.get(g, ()):
                if other != jid:
                    others.add(other)
        for other in others:
            oj = jobs[other]
            mem = (job.perf.mem_bytes(sub_batch[jid])
                   + oj.perf.mem_bytes(sub_batch[other]))
            xi = max(xi, interference.xi(
                job.model, oj.model, t_me=base,
                t_other=oj.perf.t_iter_sub(oj.batch, sub_batch[other]),
                mem_frac=mem / gpu_capacity_bytes))
        return 1.0 / (base * xi)

    # group log entries by timestamp (the log is time-ordered)
    times: List[float] = []
    grouped: List[List[tuple]] = []
    for entry in log:
        if not times or entry[0] > times[-1] + 1e-12:
            times.append(entry[0])
            grouped.append([entry])
        else:
            grouped[-1].append(entry)

    phases: List[PlanPhase] = []
    predicted: Dict[str, Dict[str, float]] = {}
    by_gpu: Dict[int, set] = {}

    for k, (t, entries) in enumerate(zip(times, grouped)):
        ops: List[PlanOp] = []
        # finishes first (they free GPUs), then starts/reconfigs — the
        # engines order completions before the scheduling pass too
        for entry in sorted(entries, key=lambda e: e[1] != "finish"):
            kind, jid = entry[1], entry[2]
            if kind == "finish":
                job = jobs[jid]
                ops.append(PlanOp("finish", name_of(jid)))
                predicted[name_of(jid)] = {
                    "exec_seconds": job.finish_time - job.start_time,
                    "jct": job.jct(),
                }
                for g in placements.pop(jid, ()):
                    by_gpu[g].discard(jid)
            elif kind == "start":
                placements[jid] = frozenset(entry[3])
                for g in entry[3]:
                    by_gpu.setdefault(g, set()).add(jid)
                cum.setdefault(jid, 0.0)
                emitted.setdefault(jid, 0)
            elif kind == "config":
                sub_batch[jid] = int(entry[3])
                ops.append(PlanOp("start", name_of(jid),
                                  sub_batch=int(entry[3])))
            elif kind == "reconfig":
                sub_batch[jid] = int(entry[3])
                ops.append(PlanOp("reconfig", name_of(jid),
                                  sub_batch=int(entry[3])))
            elif kind in ("preempt", "fail_job", "fail_server",
                          "recover_server"):
                raise ValueError(
                    "plan_from_sim only replays non-preemptive, "
                    f"fault-free schedules (saw {kind!r})")
        # accrue simulated progress until the next event
        dt = (times[k + 1] - t) if k + 1 < len(times) else 0.0
        quotas: List[Tuple[str, int]] = []
        if placements and dt > 0:
            rates = {jid: rate(jid) for jid in placements}
            for jid in sorted(placements):
                job = jobs[jid]
                cum[jid] = min(float(job.iters), cum[jid] + rates[jid] * dt)
                # cumulative rounding: totals land on job.iters exactly
                # (the snap tolerance mirrors the engines' relative
                # _FINISH_TOL so a logged finish always tops up)
                target = int(round(cum[jid]))
                if cum[jid] >= job.iters - 1e-6 * max(1.0, job.iters):
                    target = int(round(job.iters))
                q = target - emitted[jid]
                emitted[jid] = target
                quotas.append((name_of(jid), q))
            groups = tuple(tuple(name_of(j) for j in comp)
                           for comp in _components(placements))
        else:
            groups = ()
        phases.append(PlanPhase(ops=tuple(ops), quotas=tuple(quotas),
                                groups=groups, sim_duration=dt))
    return SchedulePlan(phases=phases, predicted=predicted)
