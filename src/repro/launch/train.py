"""End-to-end training driver: ``python -m repro.launch.train --arch
minicpm-2b --reduced --steps 200`` trains a (reduced or full) architecture
on synthetic LM data with gradient accumulation, WSD schedule,
checkpointing and (on a real multi-chip platform) the production
sharding. On this CPU container it is exercised by examples/quickstart.py
at ~100M scale."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import ARCH_NAMES, get_config
from repro.data import SyntheticLM
from repro.models import init_params, param_count
from repro.sharding.hooks import activation_rules
from repro.sharding.rules import make_rules
from repro.train import (TrainConfig, adamw_init, make_jit_train_step,
                         wsd_schedule)


def build_argparser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--distributed", action="store_true",
                    help="use the production mesh + sharding rules")
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype=args.dtype)

    sched = wsd_schedule(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                         stable_steps=int(args.steps * 0.7),
                         decay_steps=max(int(args.steps * 0.25), 1))
    tc = TrainConfig(accum_steps=args.accum_steps, schedule=sched)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"accum={args.accum_steps}")

    ctx = None
    if args.distributed:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        rules = make_rules(mesh)
        ctx = activation_rules(rules.activation_table(), mesh)
        ctx.__enter__()
    # params/opt-state are donated (in-place update; the training loop
    # below re-binds both from the outputs every step)
    step = make_jit_train_step(cfg, tc)

    data = SyntheticLM(cfg, args.batch, args.seq)
    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        params, opt, m = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            print(f"step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.3f}s/step)", flush=True)
    if ctx is not None:
        ctx.__exit__(None, None, None)
    if args.checkpoint:
        save(args.checkpoint, params=params, opt_state=opt, step=args.steps)
        print(f"saved {args.checkpoint}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
