"""Trip-count-aware FLOP / HBM-byte accounting from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE —
with scan-over-layers and scanned gradient accumulation that undercounts
FLOPs/bytes by orders of magnitude (confirmed empirically: llama3-405b
train_4k reported ~700x fewer FLOPs than 6*N*D). This module recomputes
both terms from the HLO:

  * FLOPs: every ``dot`` contributes 2 * prod(output dims) *
    prod(lhs contracting dims), recursing through fusions / calls, and
    multiplying ``while`` bodies by their trip count (recovered from the
    loop condition's ``compare(counter, constant)``).
  * Bytes: per top-level instruction (fusion internals excluded — a
    fused op reads its operands and writes its output once), operand +
    output buffer sizes, with the same while-trip multiplication.

Elementwise FLOPs are ignored (they ride along with the bytes term);
convolutions are absent from this framework's HLO (the conv frontends
are stubs, Mamba's depthwise conv lowers to shifted multiplies).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from .hlo_stats import _DTYPE_BYTES

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^;]*?\))?\s*->.*\{\s*$")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}


def _dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _fusion_traffic(i, defs, comps, order) -> float:
    """HBM traffic of one fusion instruction, body-aware.

    Scan bodies read per-layer slices of stacked (L, ...) weight arrays
    and write per-layer slices of stacked gradient accumulators; XLA
    fuses those dynamic-slice / dynamic-update-slice ops into consumers.
    Counting the full stacked operand would overcount by L x trip_count.

    Rules per fusion operand (matched to the body parameter's usage):
      * consumed ONLY by dynamic-slice -> count each slice output once;
      * aliased by a dynamic-update-slice (operand 0) -> count 2x the
        update instead of the buffer, and the fusion output (same full
        shape) contributes nothing extra;
      * otherwise -> full operand bytes.
    Output: full bytes unless aliased by a DUS as above.
    """
    m = re.search(r"calls=%?([\w\.\-]+)", i.line)
    body = m.group(1) if m else None
    out_b = _bytes(i.shape)
    if body is None or body not in order:
        t = out_b
        for opnd in i.operands:
            d = defs.get(opnd)
            if d is not None and d.op != "constant":
                t += _bytes(d.shape)
        return float(t)

    bdefs = comps[body]
    binstrs = order[body]
    # parameter index -> instr name
    params = {}
    for bi in binstrs:
        pm = re.search(r"parameter\((\d+)\)", bi.line)
        if bi.op == "parameter" and pm:
            params[int(pm.group(1))] = bi.name
    # consumers of each body instruction
    consumers = {}
    for bi in binstrs:
        for o in bi.operands:
            consumers.setdefault(o, []).append(bi)

    total = 0.0
    output_aliased = False
    for idx, opnd in enumerate(i.operands):
        d = defs.get(opnd)
        if d is None or d.op == "constant":
            continue
        pname = params.get(idx)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.op == "dynamic-slice" for c in cons):
            total += sum(_bytes(c.shape) for c in cons)
        elif cons and any(c.op == "dynamic-update-slice"
                          and c.operands and c.operands[0] == pname
                          for c in cons):
            for c in cons:
                if c.op == "dynamic-update-slice" and c.operands \
                        and c.operands[0] == pname:
                    upd = bdefs.get(c.operands[1]) \
                        if len(c.operands) > 1 else None
                    total += 2.0 * (_bytes(upd.shape) if upd else 0)
                    if d.shape.split("{")[0] == i.shape.split("{")[0]:
                        output_aliased = True
        else:
            total += _bytes(d.shape)
    if not output_aliased:
        total += out_b
    return float(total)


class _Instr:
    __slots__ = ("name", "shape", "op", "line", "operands")

    def __init__(self, name, shape, op, line):
        self.name, self.shape, self.op, self.line = name, shape, op, line
        # operand names: %refs inside the first (...) after the op
        m = re.search(rf"{re.escape(op)}\((.*)", line)
        body = m.group(1) if m else ""
        # cut at the matching close paren level-0 comma-split is overkill;
        # names are enough:
        self.operands = re.findall(r"%([\w\.\-]+)", body.split("),")[0])


def _parse(hlo: str):
    comps: Dict[str, Dict[str, _Instr]] = {}
    order: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped \
                and "= " not in stripped.split("->")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = {}
                order[cur] = []
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if dm:
            ins = _Instr(dm.group(1), dm.group(2), dm.group(3), line)
            comps[cur][ins.name] = ins
            order[cur].append(ins)
    return comps, order


def _trip(cond_instrs: List[_Instr]) -> int:
    consts = []
    for i in cond_instrs:
        for m in re.finditer(r"constant\((\d+)\)", i.line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def hlo_flops_bytes(hlo: str) -> Dict[str, float]:
    comps, order = _parse(hlo)

    # while body -> trip count
    trips: Dict[str, int] = {}
    for cname, instrs in order.items():
        for i in instrs:
            if i.op == "while":
                b = re.search(r"body=%?([\w\.\-]+)", i.line)
                c = re.search(r"condition=%?([\w\.\-]+)", i.line)
                if b and c and c.group(1) in order:
                    trips[b.group(1)] = _trip(order[c.group(1)])

    fusion_bodies = set()
    for instrs in order.values():
        for i in instrs:
            m = re.search(r"calls=%?([\w\.\-]+)", i.line)
            if m:
                fusion_bodies.add(m.group(1))

    fmemo: Dict[str, float] = {}
    bmemo: Dict[str, float] = {}

    def flops_of(comp: str, stack=()) -> float:
        if comp in fmemo:
            return fmemo[comp]
        if comp in stack or comp not in order:
            return 0.0
        total = 0.0
        defs = comps[comp]
        for i in order[comp]:
            if i.op == "dot":
                out_elems = 1
                for _, dims in _dims(i.shape):
                    for d in dims:
                        out_elems *= d
                lhs = defs.get(i.operands[0]) if i.operands else None
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.line)
                k = 1
                if lhs is not None and cd:
                    ldims = _dims(lhs.shape)
                    if ldims:
                        _, dims = ldims[0]
                        for idx in cd.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                k *= dims[int(idx)]
                total += 2.0 * out_elems * k
            for ref, weighted in (("calls", False), ("body", True),
                                  ("to_apply", False)):
                m = re.search(rf"{ref}=%?([\w\.\-]+)", i.line)
                if m:
                    sub = flops_of(m.group(1), stack + (comp,))
                    total += sub * (trips.get(m.group(1), 1)
                                    if weighted else 1)
            m = re.search(r"(?:true_computation|false_computation)="
                          r"%?([\w\.\-]+)", i.line)
            if m:
                total += flops_of(m.group(1), stack + (comp,))
        fmemo[comp] = total
        return total

    def bytes_of(comp: str, stack=()) -> float:
        if comp in bmemo:
            return bmemo[comp]
        if comp in stack or comp not in order or comp in fusion_bodies:
            return 0.0
        total = 0.0
        defs = comps[comp]

        def opnd_bytes(i, idx):
            if idx >= len(i.operands):
                return 0
            d = defs.get(i.operands[idx])
            return _bytes(d.shape) if d is not None else 0

        for i in order[comp]:
            if i.op in _NO_TRAFFIC or i.op == "while":
                pass
            elif i.op == "dynamic-update-slice":
                # in-place update: traffic ~= 2x the written slice, not
                # the full carried buffer (XLA aliases the operand)
                total += 2.0 * opnd_bytes(i, 1)
            elif i.op == "dynamic-slice":
                total += 2.0 * _bytes(i.shape)
            elif i.op == "gather":
                # reads only the gathered rows (~= output) + indices
                total += 2.0 * _bytes(i.shape) + opnd_bytes(i, 1)
            elif i.op == "scatter":
                total += 2.0 * opnd_bytes(i, 2) + opnd_bytes(i, 1)
            elif i.op in ("broadcast", "iota", "reshape"):
                total += _bytes(i.shape)       # write-only (no big read)
            elif i.op == "fusion":
                total += _fusion_traffic(i, defs, comps, order)
            else:
                total += _bytes(i.shape)
                for opnd in i.operands:
                    d = defs.get(opnd)
                    if d is not None and d.op not in ("constant",):
                        total += _bytes(d.shape)
            for ref, weighted in (("body", True),):
                m = re.search(rf"{ref}=%?([\w\.\-]+)", i.line)
                if m and i.op == "while":
                    total += bytes_of(m.group(1), stack + (comp,)) \
                        * trips.get(m.group(1), 1)
            if i.op == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", i.line)
                if m:
                    total += bytes_of(m.group(1), stack + (comp,))
            m = re.search(r"(?:true_computation|false_computation)="
                          r"%?([\w\.\-]+)", i.line)
            if m:
                total += bytes_of(m.group(1), stack + (comp,))
        bmemo[comp] = total
        return total

    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    entry = m.group(1) if m else None
    if entry is None or entry not in order:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}
    return {"flops": flops_of(entry), "bytes": bytes_of(entry),
            "collectives": _collectives(comps, order, trips, entry)}


# -------------------------------------------------------------------- #
# collective wire-bytes (tuple-shape and operand aware)
# -------------------------------------------------------------------- #
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _collectives(comps, order, trips, entry: str) -> Dict[str, float]:
    """Per-device wire-bytes proxy by collective type, trip-aware.

    Proxy per instruction: max(output bytes, largest operand bytes) —
    within 2x of ring-algorithm wire traffic for all five ops and robust
    to XLA choosing all-reduce (full-size out) vs reduce-scatter (shard
    out, full-size operand). Tuple-typed variadic collectives sum all
    element shapes."""
    from collections import defaultdict
    memo: Dict[str, Dict[str, float]] = {}

    def walk(comp: str, stack=()) -> Dict[str, float]:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in order:
            return {}
        acc: Dict[str, float] = defaultdict(float)
        defs = comps[comp]
        for i in order[comp]:
            base = i.op.replace("-start", "")
            if base in _COLL_OPS and not i.op.endswith("-done"):
                out_b = _bytes(i.shape)
                op_b = max((_bytes(defs[o].shape) for o in i.operands
                            if o in defs), default=0)
                acc[base] += float(max(out_b, op_b))
            for ref, weighted in (("calls", False), ("body", True),
                                  ("to_apply", False)):
                mm = re.search(rf"{ref}=%?([\w\.\-]+)", i.line)
                if mm:
                    sub = walk(mm.group(1), stack + (comp,))
                    mult = trips.get(mm.group(1), 1) if weighted else 1
                    for k, v in sub.items():
                        acc[k] += v * mult
        memo[comp] = dict(acc)
        return memo[comp]

    out = {k: float(v) for k, v in walk(entry).items()}
    out["total"] = float(sum(out.values()))
    return out
