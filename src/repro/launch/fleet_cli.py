"""``repro-fleet``: mgpu_server-shaped CLI for the fleet runtime.

Subcommands::

    repro-fleet serve  --agents 2 --ckpt-dir /tmp/fleet [--port-file F]
    repro-fleet submit --port P --arch minicpm-2b --steps 50 [--wait]
    repro-fleet queue  --port P
    repro-fleet status --port P [--json]
    repro-fleet cancel --port P JOB
    repro-fleet shutdown --port P

``serve`` runs a master in job-service mode: clients submit jobs, the
master leases them onto idle agents, dead agents' jobs requeue and
resume from their last checkpoint. All other subcommands are one-shot
RPCs against a running master (``repro.launch.wire.request``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import List, Optional

from repro.launch.wire import WireError, request

__all__ = ["main"]


def _cmd_serve(args: argparse.Namespace) -> int:
    from repro.launch.fleet import FleetConfig, FleetMaster
    cfg = FleetConfig(heartbeat_interval=args.heartbeat,
                      suspect_after=args.heartbeat * 3,
                      dead_after=args.heartbeat * 6,
                      checkpoint_every=args.checkpoint_every,
                      respawn=args.respawn)
    with FleetMaster(args.ckpt_dir, config=cfg) as master:
        master.start(n_agents=args.agents)
        if args.port_file:
            with open(args.port_file, "w") as f:
                f.write(str(master.port))
        print(f"repro-fleet master on 127.0.0.1:{master.port} "
              f"({args.agents} agents, ckpt_dir={args.ckpt_dir})",
              flush=True)
        try:
            while not master._closing:
                time.sleep(0.2)
        except KeyboardInterrupt:
            pass
    return 0


def _wire_spec(args: argparse.Namespace) -> dict:
    from repro.configs import get_config
    from repro.launch.cluster import JobSpec
    from repro.launch.wire import spec_to_wire
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    return spec_to_wire(JobSpec(cfg, batch=args.batch, seq=args.seq,
                                accum_steps=args.accum_steps,
                                seed=args.seed))


def _cmd_submit(args: argparse.Namespace) -> int:
    resp = request(args.host, args.port,
                   {"type": "submit", "spec": _wire_spec(args),
                    "steps": args.steps, "name": args.name,
                    "sub_batch": args.sub_batch})
    if not resp.get("ok"):
        print(f"error: {resp.get('error')}", file=sys.stderr)
        return 1
    name = resp["job"]
    print(f"submitted {name}")
    if not args.wait:
        return 0
    while True:
        time.sleep(args.poll)
        status = request(args.host, args.port, {"type": "status"})
        job = status.get("jobs", {}).get(name)
        if job is None:
            print(f"error: job {name!r} vanished", file=sys.stderr)
            return 1
        if job["finished"] or job["failed"] or job["cancelled"]:
            print(json.dumps({name: job}, indent=2))
            return 0 if job["finished"] else 1
        print(f"  {name}: {job['steps']}/{job['total_steps']} steps",
              flush=True)


def _cmd_queue(args: argparse.Namespace) -> int:
    resp = request(args.host, args.port, {"type": "queue"})
    print(json.dumps({"queue": resp.get("queue", []),
                      "jobs": {n: j["steps"]
                               for n, j in resp.get("jobs", {}).items()
                               if not j["finished"]}}, indent=2))
    return 0


def _cmd_status(args: argparse.Namespace) -> int:
    resp = request(args.host, args.port, {"type": "status"})
    resp.pop("ok", None)
    if args.json:
        print(json.dumps(resp, indent=2))
        return 0
    print(f"master 127.0.0.1:{resp.get('port')}")
    for aid, a in sorted(resp.get("agents", {}).items()):
        print(f"  agent {aid}: {a['state']}, leases={a['leases']}, "
              f"watermark={a['watermark']}")
    for name, j in sorted(resp.get("jobs", {}).items()):
        state = ("finished" if j["finished"] else
                 "failed" if j["failed"] else
                 "cancelled" if j["cancelled"] else "running")
        print(f"  job {name}: {j['steps']}/{j['total_steps']} {state} "
              f"(redispatches={j['redispatches']})")
    print(f"  queue: {resp.get('queue', [])}")
    print(f"  stats: {resp.get('stats', {})}")
    return 0


def _cmd_cancel(args: argparse.Namespace) -> int:
    resp = request(args.host, args.port,
                   {"type": "cancel", "job": args.job})
    print("cancelled" if resp.get("ok") else "no such running job")
    return 0 if resp.get("ok") else 1


def _cmd_shutdown(args: argparse.Namespace) -> int:
    resp = request(args.host, args.port, {"type": "shutdown"})
    print("shutdown requested" if resp.get("ok") else "refused")
    return 0 if resp.get("ok") else 1


def _add_client_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-fleet",
        description="master/agent fleet runtime for schedule replay")
    sub = ap.add_subparsers(dest="cmd", required=True)

    serve = sub.add_parser("serve", help="run a master + N agents")
    serve.add_argument("--agents", type=int, default=2)
    serve.add_argument("--ckpt-dir", required=True)
    serve.add_argument("--port-file", default=None,
                       help="write the bound port here once listening")
    serve.add_argument("--heartbeat", type=float, default=0.25)
    serve.add_argument("--checkpoint-every", type=int, default=5)
    serve.add_argument("--respawn", action="store_true",
                       help="replace agents the fleet declares dead")
    serve.set_defaults(fn=_cmd_serve)

    submit = sub.add_parser("submit", help="submit a training job")
    _add_client_args(submit)
    submit.add_argument("--arch", default="minicpm-2b")
    submit.add_argument("--steps", type=int, required=True)
    submit.add_argument("--batch", type=int, default=2)
    submit.add_argument("--seq", type=int, default=32)
    submit.add_argument("--accum-steps", type=int, default=1)
    submit.add_argument("--seed", type=int, default=0)
    submit.add_argument("--dtype", default="float32")
    submit.add_argument("--sub-batch", type=int, default=None)
    submit.add_argument("--name", default=None)
    submit.add_argument("--reduced", action="store_true",
                        help="use the test-sized model config")
    submit.add_argument("--wait", action="store_true",
                        help="poll until the job reaches a terminal state")
    submit.add_argument("--poll", type=float, default=1.0)
    submit.set_defaults(fn=_cmd_submit)

    for name, fn, hlp in (("queue", _cmd_queue, "show pending jobs"),
                          ("status", _cmd_status, "fleet status"),
                          ("shutdown", _cmd_shutdown, "stop the master")):
        p = sub.add_parser(name, help=hlp)
        _add_client_args(p)
        if name == "status":
            p.add_argument("--json", action="store_true")
        p.set_defaults(fn=fn)

    cancel = sub.add_parser("cancel", help="cancel a job")
    _add_client_args(cancel)
    cancel.add_argument("job")
    cancel.set_defaults(fn=_cmd_cancel)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (WireError, ConnectionRefusedError, OSError) as exc:
        print(f"error: cannot reach master: {exc}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
