"""Production meshes (DESIGN.md §7).

Single pod: 256 chips as ('data'=16, 'model'=16).
Multi-pod:  2 pods = 512 chips as ('pod'=2, 'data'=16, 'model'=16); the
'pod' axis extends data parallelism (one cross-pod gradient all-reduce
per step — the DCN-class axis stays outermost).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int = 0):
    """Tiny mesh over whatever devices exist (tests: 1 CPU device ->
    (1, 1); an 8-device forced-host run -> (4, 2))."""
    n = n_devices or len(jax.devices())
    data = max(1, n // 2)
    model = n // data
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (§Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_CAPACITY = 16 * 2**30       # bytes per chip
