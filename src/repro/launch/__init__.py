"""Launchers: production mesh, multi-pod dry-run, train/serve drivers,
and the schedule-driven multi-job executor (`launch.cluster`)."""
