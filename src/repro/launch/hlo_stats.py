"""Post-SPMD HLO text analysis: collective bytes per device.

``compiled.cost_analysis()`` has no collective traffic term, so we parse
the optimized HLO (``compiled.as_text()``): every ``all-gather`` /
``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction contributes its OUTPUT shape bytes
(per-device, since post-SPMD shapes are per-device).

Collectives inside ``while`` bodies (scan-over-layers, gradient
accumulation) execute once per trip; we recover trip counts from the loop
condition's ``compare(counter, constant)`` and multiply, recursing through
nested loops, calls and fusions.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every array shape in a (possibly tuple) shape str."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines.

    A computation header is any line ending in '{' that contains '->'
    (robust to nested tuple-typed parameter lists, which defeat
    paren-matching regexes)."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped \
                and "= " not in stripped.split("->")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _instr_output_shape(line: str) -> str:
    """The shape between '=' and the op name."""
    try:
        rhs = line.split("= ", 1)[1]
    except IndexError:
        return ""
    return rhs


def _trip_count(cond_lines: List[str]) -> int:
    """Loop trip count from the condition computation (counter < C)."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def collective_stats(hlo: str) -> Dict[str, float]:
    """Per-device collective bytes by type + total, trip-count aware."""
    comps = _split_computations(hlo)
    cond_of: Dict[str, str] = {}
    body_trip: Dict[str, int] = {}

    # map while bodies to their condition trip counts
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                b = re.search(r"body=%?([\w\.\-]+)", ln)
                c = re.search(r"condition=%?([\w\.\-]+)", ln)
                if b and c and c.group(1) in comps:
                    body_trip[b.group(1)] = _trip_count(comps[c.group(1)])

    memo: Dict[str, Dict[str, float]] = {}

    def bytes_of(comp: str, stack=()) -> Dict[str, float]:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in comps:
            return {}
        acc: Dict[str, float] = defaultdict(float)
        for ln in comps[comp]:
            rhs = _instr_output_shape(ln)
            op = None
            for cop in COLLECTIVES:
                if re.search(rf"\b{cop}(-start|-done)?\(", rhs):
                    op = cop
                    break
            if op and "-done(" not in rhs:
                acc[op] += _shape_bytes(rhs.split("(")[0])
            # recurse into referenced computations
            for ref_kind, mult_by_trip in (
                    ("body", True), ("to_apply", False), ("calls", False)):
                m = re.search(rf"{ref_kind}=%?([\w\.\-]+)", rhs)
                if m:
                    sub = bytes_of(m.group(1), stack + (comp,))
                    mult = body_trip.get(m.group(1), 1) if mult_by_trip else 1
                    for k, v in sub.items():
                        acc[k] += v * mult
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations)=\{?%?([\w\.\-, %]+)",
                                 rhs):
                for sub_name in re.split(r"[,\s%]+", m.group(1)):
                    if sub_name:
                        sub = bytes_of(sub_name, stack + (comp,))
                        for k, v in sub.items():
                            acc[k] += v
        memo[comp] = dict(acc)
        return memo[comp]

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: sum everything flat (no trip counts)
        acc: Dict[str, float] = defaultdict(float)
        for lines in comps.values():
            for ln in lines:
                for cop in COLLECTIVES:
                    if re.search(rf"\b{cop}(-start)?\(", ln):
                        acc[cop] += _shape_bytes(ln.split("(")[0])
        out = dict(acc)
    else:
        out = bytes_of(entry)
    out = {k: float(v) for k, v in out.items()}
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return out


def count_op(hlo: str, opname: str) -> int:
    return len(re.findall(rf"\b{opname}\(", hlo))
