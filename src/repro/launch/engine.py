"""Slot-based continuous batching: the serving twin of the simulator's
sharing scheduler.

A ``DecodeEngine`` owns a fixed number of decode *slots* (the batch
dimension of one shared cache pytree) and a FIFO queue of requests.
Decoding advances all slots together in fused ``lax.scan`` segments (one
dispatch per ``segment`` tokens, per-slot absolute positions carried in
the cache's ``index`` vector); between segments, finished slots are
freed and queued requests are admitted into them — each admission runs
the single-shot prefill for that request alone and scatters the
resulting cache rows into the slot, so a reused slot never observes the
previous occupant's state.

Inactive slots keep stepping (their compute is masked out only by
discarding the emitted tokens) — exactly the fixed-shape trade the
paper's GPU-sharing scheduler makes: pay a bounded, predictable cost per
step in exchange for never re-compiling and never stalling the batch.

Whisper-style encoder-decoder configs are not supported here (each
request would carry its own encoder pass; use ``serve.generate``).
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cluster import ScriptedFaults, TransientFault
from repro.launch.prefix import PrefixTrie
from repro.launch.serve import _make_scan_generate, prefill_extend_cached
from repro.models import init_cache, init_paged_cache, prefill
from repro.util.retry import RetryPolicy, retry_call


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) i32
    max_new_tokens: int
    deadline: Optional[float] = None   # absolute clock time; None = none
    priority: int = 0                  # higher = more important
    submitted_at: float = 0.0


class DecodeEngine:
    """Continuous-batching decode engine over ``n_slots`` fixed slots.

    ``paged=True`` (DESIGN.md §15) swaps the dense per-slot KV cache for
    a shared page pool plus per-slot block tables: a slot holds only the
    pages its request actually occupies, so ``n_slots`` can far exceed
    what ``n_slots x max_len`` dense rows would allow at the same cache
    memory.  Admission is bounded by a page *reservation* — a request is
    admitted only when its worst-case page count (prompt + all decode
    segments) is available — while physical pages are assigned lazily,
    one segment ahead of the decode index, and reclaimed the moment the
    slot frees.  Tokens are bitwise identical to the dense engine.

    ``prefix_share=True`` (DESIGN.md §18) adds copy-on-write prefix
    sharing on top of paging: a radix trie over token IDs maps each
    incoming prompt to its longest cached prefix, whose pages are mapped
    read-only into the new slot (per-page refcounts; a page is writable
    only at refcount 1).  Admission charges reservation credit only for
    the request's *unique* pages, prefill computes only the un-cached
    suffix, and the first decode write into a still-shared boundary page
    forks just that page.  Zero-ref cached prefixes are reclaimed LRU
    under the ``retain_pages`` watermark — and eagerly under brown-out,
    so cache memory sheds before queued requests do."""

    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 256,
                 segment: int = 8, use_kernels: bool = False,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 prefix_share: bool = False,
                 retain_pages: Optional[int] = None,
                 debug: bool = False,
                 clock=time.monotonic,
                 brownout_depth: int = 0,
                 fault_injector: Optional[ScriptedFaults] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 retry_seed: int = 0,
                 sleep=time.sleep):
        assert not cfg.is_encoder_decoder, \
            "encoder-decoder configs are served via serve.generate"
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len, self.segment = n_slots, max_len, segment
        self.use_kernels = use_kernels
        self.paged = paged
        self.prefix_share = prefix_share
        self.debug = debug

        if prefix_share:
            if not paged:
                raise ValueError("prefix_share requires paged=True")
            # bitwise contract: suffix prefill (prefill_extend) must
            # reproduce the full prefill's rows exactly.  Proven for
            # dense/vlm attention and for MoE under the per-token
            # "dense" dispatch; the einsum/scatter MoE dispatches shape
            # their capacity buffers by sequence length, and SSM/hybrid
            # state is not page-addressable at all.
            ok = cfg.family in ("dense", "vlm") or (
                cfg.family == "moe" and cfg.moe_dispatch == "dense")
            if not ok:
                raise ValueError(
                    f"prefix_share needs a bitwise-stable suffix prefill; "
                    f"family {cfg.family!r} (moe_dispatch "
                    f"{getattr(cfg, 'moe_dispatch', None)!r}) has none")

        if paged:
            if not _has_linear_kv(cfg):
                raise ValueError(
                    f"paged KV requires a linear-layout KV cache; family "
                    f"{cfg.family!r} (window {cfg.sliding_window}) has none")
            if n_pages is None:     # dense-equivalent memory by default
                n_pages = n_slots * (max_len // page_size)
            # leaf classification below is by shape: the pool must not
            # coincide with the dense (n_slots, max_len) allocation
            assert not (n_pages == n_slots and page_size == max_len), \
                "degenerate paging (one max_len page per slot)"
            self.page_size, self.n_pages = page_size, n_pages
            cache = init_paged_cache(cfg, n_slots, max_len,
                                     page_size=page_size, n_pages=n_pages)
            dense_shapes = jax.eval_shape(
                lambda: init_cache(cfg, n_slots, max_len)["units"])
            self._is_pool = jax.tree.map(
                lambda pg, dn: pg.shape != dn.shape,
                cache["units"], dense_shapes)
            # host-side paging state
            self._free_pages: List[int] = list(range(n_pages))
            self._pages_np = np.full((n_slots, max_len // page_size), -1,
                                     np.int32)
            self._slot_npages = np.zeros(n_slots, np.int64)  # assigned
            self._slot_reserve = np.zeros(n_slots, np.int64)  # total credit
            self._slot_unique = np.zeros(n_slots, np.int64)  # non-shared
            self._index_np = np.zeros(n_slots, np.int64)     # decode pos
            # per-page refcounts: one per mapped block-table entry plus
            # one per trie node.  Free <=> 0; writable by a slot <=> 1.
            self._page_refs = np.zeros(n_pages, np.int32)
            # outstanding credit: sum over slots of (reserve - unique),
            # i.e. pages promised but not yet physically taken
            self._committed = 0
            self._trie = PrefixTrie(page_size) if prefix_share else None
            self.retain_pages = (n_pages if retain_pages is None
                                 else int(retain_pages))
        else:
            cache = init_cache(cfg, n_slots, max_len)
        cache["index"] = jnp.zeros((n_slots,), jnp.int32)  # per-slot position
        self.cache = cache
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)      # next input token
        self.active = np.zeros(n_slots, bool)
        self.remaining = np.zeros(n_slots, np.int64)
        self.slot_rid: List[int] = [-1] * n_slots

        self.queue: deque = deque()
        self.outputs: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._prefill_fns: Dict[int, Any] = {}
        self._segment_fn = jax.jit(self._make_segment_fn())
        # degraded-mode serving (DESIGN.md §16): per-request deadlines
        # with timeout-shedding, admission brown-out under overload, and
        # bounded retry of transient segment faults. All off by default.
        self._clock = clock
        self.brownout_depth = int(brownout_depth)
        self.fault_injector = fault_injector
        self.retry_policy = retry_policy or RetryPolicy()
        self._retry_rng = random.Random(retry_seed)
        self._sleep = sleep
        self.slot_deadline: List[Optional[float]] = [None] * n_slots
        self.shed: Dict[int, str] = {}        # rid -> shed reason
        self.retry_after: Dict[int, float] = {}   # rid -> backoff hint (s)
        self._seg_ewma = 0.0                  # EWMA segment walltime (s)
        self.stats = {"segments": 0, "admitted": 0, "wasted_slot_steps": 0,
                      "peak_active_slots": 0, "shed_deadline": 0,
                      "shed_brownout": 0, "deadline_miss": 0, "retries": 0}
        if paged:
            self.stats.update({
                "pages_total": n_pages, "pages_in_use": 0,
                "peak_pages_in_use": 0, "page_occupancy": 0.0,
                "page_fragmentation": 0.0, "admission_deferred_pages": 0})
        if prefix_share:
            self.stats.update({
                "prefix_hits": 0, "prefix_misses": 0,
                "prefix_hit_rate": 0.0, "prefill_tokens_saved": 0,
                "prompt_tokens_total": 0, "cow_forks": 0,
                "prefix_evictions": 0, "brownout_prefix_evictions": 0,
                "shared_pages": 0, "unique_pages": 0, "trie_pages": 0})

    # -- page credit / refcounts (DESIGN.md §15, §18) ------------------- #
    @property
    def _avail_pages(self) -> int:
        """Admission credit: physically free pages minus outstanding
        reservations, plus pages reclaimable from zero-ref cached
        prefixes (the trie yields under admission pressure).  Without
        prefix sharing this equals ``n_pages - sum(reservations)``."""
        avail = len(self._free_pages) - self._committed
        if self.prefix_share:
            avail += self._trie.evictable_pages(self._page_refs)
        return avail

    def _take_page(self) -> int:
        """Pop a physically free page (refcount 0 -> 1), evicting the
        LRU zero-ref cached prefix page first if the free list is dry.
        An IndexError here means the reservation credit was violated."""
        if not self._free_pages and self.prefix_share:
            page = self._trie.evict_lru(self._page_refs)
            if page is not None:
                self._page_refs[page] -= 1
                self._free_pages.append(page)
                self.stats["prefix_evictions"] += 1
        page = self._free_pages.pop()
        self._page_refs[page] = 1
        return page

    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new_tokens: int = 16, *,
               deadline: Optional[float] = None,
               priority: int = 0) -> int:
        """Queue a request; returns its id (key into ``outputs``).

        ``deadline`` is relative (seconds from now on the engine clock):
        a request that has not *completed* by then is shed — from the
        queue or mid-decode — with its rid recorded in ``shed`` and a
        ``retry_after`` hint. ``priority`` orders brown-out shedding
        under overload (lower priorities shed first); admission itself
        stays FIFO."""
        prompt = np.asarray(prompt, np.int32)
        if _has_linear_kv(self.cfg):
            # a linear KV cache holds one row per prompt + generated
            # token, and a slot keeps stepping to the end of its last
            # segment — writes past max_len would be clamped/dropped
            # silently while the validity mask still trusts them
            segs = -(-max_new_tokens // self.segment)
            need = prompt.shape[0] + segs * self.segment
            assert need <= self.max_len, (
                f"request needs {need} cache rows (prompt "
                f"{prompt.shape[0]} + {segs}x{self.segment}-step "
                f"segments) but max_len is {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        now = self._clock()
        self.queue.append(Request(
            rid, prompt, max_new_tokens,
            deadline=(now + deadline) if deadline is not None else None,
            priority=int(priority), submitted_at=now))
        self.outputs[rid] = []
        return rid

    # -- degraded mode (DESIGN.md §16) --------------------------------- #
    def _retry_after_hint(self) -> float:
        """Coarse back-pressure hint for a shed request: the EWMA
        segment walltime times the current queue depth — roughly when
        the backlog ahead of it will have drained a slot."""
        return self._seg_ewma * (1 + len(self.queue))

    def _shed_request(self, req: Request, reason: str) -> None:
        self.shed[req.rid] = reason
        self.retry_after[req.rid] = self._retry_after_hint()
        self.stats["shed_" + reason] += 1

    def _free_slot(self, slot: int) -> None:
        self.active[slot] = False
        self.slot_rid[slot] = -1
        self.slot_deadline[slot] = None
        self.remaining[slot] = 0
        if self.paged:
            self._free_slot_pages(slot)

    def _shed_expired(self, now: float) -> None:
        """Timeout-shedding: queued requests past their deadline never
        admit; active slots past theirs free immediately (the partial
        output stays in ``outputs`` — the caller sees what was decoded
        before the deadline)."""
        kept = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                self._shed_request(req, "deadline")
            else:
                kept.append(req)
        self.queue = kept
        for slot in range(self.n_slots):
            dl = self.slot_deadline[slot]
            if self.active[slot] and dl is not None and now > dl:
                rid = self.slot_rid[slot]
                self.shed[rid] = "deadline"
                self.retry_after[rid] = self._retry_after_hint()
                self.stats["shed_deadline"] += 1
                self._free_slot(slot)

    def _admissible_now(self) -> int:
        """How many queued requests (FIFO prefix of the queue) could be
        admitted right now into free slots with the current page credit
        — the brown-out pass sheds only beyond this."""
        free_slots = int((~self.active).sum())
        avail, n = self._avail_pages, 0
        for req in self.queue:
            if n >= free_slots:
                break
            reserve, _ = self._plan_admission(req, touch=False)
            if reserve > avail:
                break
            avail -= reserve
            n += 1
        return n

    def _brownout(self) -> None:
        """Overload graceful degradation: when the queue is deeper than
        ``brownout_depth``, shed the lowest-priority (then youngest)
        queued requests until it fits — load sheds before latency
        collapses, and paying tiers degrade last.

        With prefix sharing the engine sheds *cache memory* first:
        every zero-ref cached prefix is evicted (counted separately in
        ``brownout_prefix_evictions``, not as shed requests), and only
        requests beyond what the freed pages can admit are dropped —
        the fewer-shed accounting of DESIGN.md §18."""
        if self.brownout_depth <= 0 or len(self.queue) <= self.brownout_depth:
            return
        if self.prefix_share:
            while True:
                page = self._trie.evict_lru(self._page_refs)
                if page is None:
                    break
                self._page_refs[page] -= 1
                self._free_pages.append(page)
                self.stats["brownout_prefix_evictions"] += 1
            excess = (len(self.queue) - self._admissible_now()
                      - self.brownout_depth)
            if excess <= 0:
                return
        else:
            excess = len(self.queue) - self.brownout_depth
        order = sorted(self.queue,
                       key=lambda r: (r.priority, -r.submitted_at))
        drop = {r.rid for r in order[:excess]}
        kept = deque()
        for req in self.queue:
            if req.rid in drop:
                self._shed_request(req, "brownout")
            else:
                kept.append(req)
        self.queue = kept

    # ------------------------------------------------------------------ #
    def _make_segment_fn(self):
        """One fused greedy scan segment — serve's scan body with the
        PRNG key pinned (greedy ignores it), continuing the carry."""
        run = _make_scan_generate(self.cfg, self.segment, True,
                                  self.use_kernels)
        key = jax.random.PRNGKey(0)

        def seg(params, cache, tok):
            toks, cache, tok, _ = run(params, cache, tok, key)
            return toks, cache, tok
        return seg

    def _prefill_fn(self, plen: int):
        # prefix sharing pins prefill to the jnp path: the suffix-extend
        # prefill has no kernel variant (the flash kernel assumes query
        # row 0 is cache row 0), and hit/miss admissions must stay
        # bitwise-consistent with each other
        uk = self.use_kernels and not self.prefix_share
        key = (plen, uk)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def run(params, tokens):
                cache = init_cache(cfg, 1, max_len)
                return prefill(cfg, params, cache, tokens, use_kernels=uk)
            fn = self._prefill_fns[key] = jax.jit(run)
        return fn

    def _gather_fn(self, n_pg: int):
        """Jitted pool->dense gather: copy ``n_pg`` pool pages into rows
        ``[0, n_pg*page_size)`` of a fresh batch-1 dense cache, the
        launchpad for the suffix-extend prefill."""
        key = ("gather", n_pg)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, max_len, ps = self.cfg, self.max_len, self.page_size
            is_pool = self._is_pool

            def run(units, pids):
                cache = init_cache(cfg, 1, max_len)

                def take(dn, pool, pl):
                    if not pl:
                        return dn
                    u = pool.shape[0]      # pool: (U, n_pages, ps, H, D)
                    rows = pool[:, pids].reshape(
                        (u, 1, n_pg * ps) + pool.shape[3:])
                    return dn.at[:, :, :n_pg * ps].set(rows.astype(dn.dtype))
                cache["units"] = jax.tree.map(
                    take, cache["units"], units, is_pool)
                return cache
            fn = self._prefill_fns[key] = jax.jit(run)
        return fn

    # ------------------------------------------------------------------ #
    def _pages_needed(self, req: Request) -> int:
        """Worst-case page count for a request: one row per prompt token
        plus every position its slot will step through (the slot runs
        whole segments, so the last partial segment still writes rows)."""
        segs = -(-req.max_new_tokens // self.segment)
        rows = req.prompt.shape[0] + segs * self.segment
        return -(-rows // self.page_size)

    def _plan_admission(self, req: Request, *, touch: bool = True):
        """Reservation and prefix plan for one request.

        Returns ``(reserve, match)``.  Without prefix sharing,
        ``reserve`` is the worst-case page count and ``match`` is None.
        With it, the trie is consulted: ``match = (pages_m, L, f)``
        where ``L`` is the usable matched prefix length and ``f`` the
        fully-shared page count.  ``reserve`` charges only unique pages
        — the total minus the ``f`` shared ones — plus a one-page
        *boundary-fork allowance* whenever the prompt ends mid-page:
        publishing the tail page into the trie leaves it shared, and
        the first decode write must fork it.

        ``L`` is capped at ``plen - 2``: a one-row suffix matmul takes a
        different XLA accumulation path than the same row of the full
        prefill, so the bitwise contract needs >= 2 recomputed rows."""
        total = self._pages_needed(req)
        if not self.prefix_share:
            return total, None
        ps = self.page_size
        plen = req.prompt.shape[0]
        pages_m, matched = self._trie.match(req.prompt, touch=touch)
        L = max(0, min(matched, plen - 2))
        f = L // ps
        reserve = total - f + (1 if plen % ps else 0)
        return reserve, (pages_m, L, f)

    def _admit(self) -> None:
        """Fill every free slot from the queue: solo single-shot prefill,
        then scatter the request's cache rows into the slot (dense) or
        into freshly assigned pool pages (paged).  Paged admission is
        credit-gated: the request's worst-case *unique* page count is
        reserved up front (FIFO — an oversized head blocks the queue
        rather than being bypassed), so ``_grow`` can never run out of
        pages mid-flight."""
        for slot in range(self.n_slots):
            if self.active[slot] or not self.queue:
                continue
            if self.paged:
                req = self.queue[0]
                reserve, match = self._plan_admission(req)
                if reserve > self._avail_pages:
                    self.stats["admission_deferred_pages"] += 1
                    break
                self.queue.popleft()
                logits = self._admit_paged(slot, req, reserve, match)
            else:
                req = self.queue.popleft()
                plen = req.prompt.shape[0]
                assert plen <= self.max_len
                logits, pcache = self._prefill_fn(plen)(
                    self.params, jnp.asarray(req.prompt)[None, :])
                self.cache["units"] = _scatter_slot(
                    self.cache["units"], pcache["units"], slot)
            plen = req.prompt.shape[0]
            self.cache["index"] = self.cache["index"].at[slot].set(plen)
            first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            self.tok = self.tok.at[slot, 0].set(first)
            self.active[slot] = True
            self.remaining[slot] = req.max_new_tokens
            self.slot_rid[slot] = req.rid
            self.slot_deadline[slot] = req.deadline
            self.stats["admitted"] += 1

    def _admit_paged(self, slot: int, req: Request, reserve: int, match):
        """Paged admission: map the fully-matched shared prefix pages
        read-only (refcount +1, no credit), allocate unique pages for
        the rest, prefill only the un-cached suffix (gathered through a
        fresh dense cache), scatter the suffix rows, and publish the
        prompt's pages into the trie."""
        ps = self.page_size
        plen = req.prompt.shape[0]
        assert plen <= self.max_len
        npf = -(-plen // ps)
        pages_m, L, f = match if match is not None else ([], 0, 0)

        self._pages_np[slot, :] = -1
        for j in range(f):                      # shared prefix, read-only
            p = int(pages_m[j])
            self._pages_np[slot, j] = p
            self._page_refs[p] += 1
        for j in range(f, npf):                 # private suffix pages
            self._pages_np[slot, j] = self._take_page()
        self._slot_npages[slot] = npf
        self._slot_reserve[slot] = reserve
        self._slot_unique[slot] = npf - f
        self._committed += reserve - (npf - f)
        self._index_np[slot] = plen

        if L > 0:
            # gather every page with matched rows — including a
            # partially-matched boundary page, used as a read source
            # only (never mapped) — then extend from row L
            n_m = -(-L // ps)
            pids_m = jnp.asarray([int(p) for p in pages_m[:n_m]], jnp.int32)
            gathered = self._gather_fn(n_m)(self.cache["units"], pids_m)
            logits, pcache = prefill_extend_cached(
                self.cfg, self.params, gathered,
                jnp.asarray(req.prompt)[None, L:], start=L)
            self.stats["prefix_hits"] += 1
            self.stats["prefill_tokens_saved"] += L
        else:
            logits, pcache = self._prefill_fn(plen)(
                self.params, jnp.asarray(req.prompt)[None, :])
            if self.prefix_share:
                self.stats["prefix_misses"] += 1
        if self.prefix_share:
            self.stats["prompt_tokens_total"] += plen
        pids = [int(p) for p in self._pages_np[slot, f:npf]]
        self.cache["units"] = self._scatter_paged(
            pcache["units"], pids, slot, first_page=f)
        if self.prefix_share:
            for p in self._trie.insert(
                    req.prompt, [int(x) for x in self._pages_np[slot, :npf]]):
                self._page_refs[p] += 1
            self._trim_trie()
        return logits

    def _scatter_paged(self, punits, pids: List[int], slot: int, *,
                       first_page: int = 0):
        """Scatter a solo prefill cache into the paged engine cache: pool
        leaves take the prompt's rows page by page starting at prompt
        page ``first_page`` (shared prefix pages before it are already
        populated); per-slot leaves (SSM state, whisper cross K/V)
        scatter into the slot axis as in the dense engine."""
        ps = self.page_size
        n = len(pids)
        pids_a = jnp.asarray(pids, jnp.int32)
        lo = first_page * ps

        def put(dst, src, is_pool):
            if not is_pool:
                return _scatter_slot_leaf(dst, src, slot)
            u = src.shape[0]                   # src: (U, 1, max_len, H, D)
            rows = src[:, 0, lo:lo + n * ps]
            rows = rows.reshape((u, n, ps) + src.shape[3:])
            return dst.at[:, pids_a].set(rows.astype(dst.dtype))
        return jax.tree.map(put, self.cache["units"], punits, self._is_pool)

    def _fork_page(self, slot: int, j: int) -> None:
        """Copy-on-write: give ``slot`` a private copy of block-table
        entry ``j`` before it writes into a still-shared page.  Only the
        boundary page of a freshly-published prompt can hit this, and
        its admission pre-charged the fork allowance."""
        old = int(self._pages_np[slot, j])
        new = self._take_page()                 # refs[new] = 1
        self._page_refs[old] -= 1

        def cp(leaf, is_pool):
            if not is_pool:
                return leaf
            return leaf.at[:, new].set(leaf[:, old])
        self.cache["units"] = jax.tree.map(
            cp, self.cache["units"], self._is_pool)
        self._pages_np[slot, j] = new
        self._slot_unique[slot] += 1
        self._committed -= 1
        self.stats["cow_forks"] += 1

    def _grow(self) -> None:
        """Assign pool pages covering the upcoming segment for every
        active slot — lazy assignment against the admission reservation,
        so a slot only ever holds pages for rows it is about to write.
        With prefix sharing, any still-shared page the segment will
        write into is copy-on-write forked first."""
        ps = self.page_size
        for slot in range(self.n_slots):
            if not self.active[slot]:
                continue
            idx = int(self._index_np[slot])
            pend = -(-(idx + self.segment) // ps)
            if self.prefix_share:
                for j in range(idx // ps, min(pend,
                                              int(self._slot_npages[slot]))):
                    if self._page_refs[int(self._pages_np[slot, j])] > 1:
                        self._fork_page(slot, j)
            while self._slot_npages[slot] < pend:
                self._pages_np[slot, self._slot_npages[slot]] = \
                    self._take_page()
                self._slot_npages[slot] += 1
                self._slot_unique[slot] += 1
                self._committed -= 1

    def step_segment(self) -> None:
        """One fused scan segment + post-segment bookkeeping/admission.
        Degraded-mode pre-pass: expired requests shed (queued and
        active) and the queue brown-outs before admission refills the
        freed slots."""
        self._shed_expired(self._clock())
        self._brownout()
        self._admit()
        if self.paged:
            self._grow()
            # one host->device push of the (n_slots, P) block table per
            # segment covers admissions, growth, and last-segment frees
            self.cache["pages"] = jnp.asarray(self._pages_np)
            in_use = int(self._slot_npages.sum())
            self.stats["pages_in_use"] = in_use
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], in_use)
            rows = int((self._index_np[self.active] + self.segment).sum())
            occ = rows / (in_use * self.page_size) if in_use else 0.0
            self.stats["page_occupancy"] = occ
            self.stats["page_fragmentation"] = 1.0 - occ
            if self.prefix_share:
                refs = self._page_refs
                self.stats["shared_pages"] = int((refs > 1).sum())
                self.stats["unique_pages"] = int((refs == 1).sum())
                self.stats["trie_pages"] = self._trie.page_count()
                h, m = self.stats["prefix_hits"], self.stats["prefix_misses"]
                self.stats["prefix_hit_rate"] = h / (h + m) if h + m else 0.0
            if self.debug:
                self._check_invariants()
        self.stats["peak_active_slots"] = max(
            self.stats["peak_active_slots"], int(self.active.sum()))

        def attempt():
            # faults strike before the call (inputs are not donated, so
            # a retried segment replays the identical computation)
            if self.fault_injector is not None:
                self.fault_injector.check(self.stats["segments"],
                                          ("segment",))
            return self._segment_fn(self.params, self.cache, self.tok)

        t0 = time.perf_counter()
        toks, self.cache, self.tok = retry_call(
            attempt, policy=self.retry_policy, retry_on=(TransientFault,),
            rng=self._retry_rng, sleep=self._sleep,
            on_retry=lambda *_: self.stats.__setitem__(
                "retries", self.stats["retries"] + 1))
        dt = time.perf_counter() - t0
        self._seg_ewma = (dt if self._seg_ewma == 0.0
                          else 0.2 * dt + 0.8 * self._seg_ewma)
        toks = np.asarray(toks)                     # (n_slots, segment)
        self.stats["segments"] += 1
        self.stats["wasted_slot_steps"] += int(
            (~self.active).sum()) * self.segment
        for slot in range(self.n_slots):
            if not self.active[slot]:
                continue
            if self.paged:
                self._index_np[slot] += self.segment
            take = int(min(self.segment, self.remaining[slot]))
            self.outputs[self.slot_rid[slot]].extend(
                int(t) for t in toks[slot, :take])
            self.remaining[slot] -= take
            self.stats["wasted_slot_steps"] += self.segment - take
            if self.remaining[slot] == 0:
                dl = self.slot_deadline[slot]
                if dl is not None and self._clock() > dl:
                    # completed, delivered — but late
                    self.stats["deadline_miss"] += 1
                self._free_slot(slot)               # slot freed for reuse

    def _free_slot_pages(self, slot: int) -> None:
        """Reclaim a freed slot's pages and reservation.  Each mapped
        page is dereferenced and returns to the free list only at
        refcount 0 — shared prefix pages outlive the slot through their
        other holders (the trie, sibling slots).  The block table row is
        cleared to the -1 sentinel immediately (pushed to the device
        before the next segment), so the stale slot's continued writes
        drop instead of corrupting whoever gets the pages next."""
        npg = int(self._slot_npages[slot])
        for p in self._pages_np[slot, :npg]:
            p = int(p)
            self._page_refs[p] -= 1
            if self._page_refs[p] == 0:
                self._free_pages.append(p)
        self._pages_np[slot, :] = -1
        self._slot_npages[slot] = 0
        self._committed -= (int(self._slot_reserve[slot])
                            - int(self._slot_unique[slot]))
        self._slot_reserve[slot] = 0
        self._slot_unique[slot] = 0
        self._index_np[slot] = 0
        if self.prefix_share:
            self._trim_trie()

    def _trim_trie(self) -> None:
        """LRU-trim cached prefixes down to the ``retain_pages``
        watermark: pages held only by the trie are evicted oldest-first
        until the evictable set fits."""
        while self._trie.evictable_pages(self._page_refs) > self.retain_pages:
            page = self._trie.evict_lru(self._page_refs)
            if page is None:
                break
            self._page_refs[page] -= 1
            self._free_pages.append(page)
            self.stats["prefix_evictions"] += 1

    def _check_invariants(self) -> None:
        """Debug-mode structural audit of the paging state (the
        refcount/free-list/credit contract of DESIGN.md §15/§18)."""
        refs = self._page_refs
        mapped = 0
        for slot in range(self.n_slots):
            npg = int(self._slot_npages[slot])
            row = self._pages_np[slot]
            assert (row[npg:] == -1).all(), \
                f"slot {slot}: mapped entries past npages"
            assert (row[:npg] >= 0).all(), \
                f"slot {slot}: -1 sentinel read inside mapped range"
            mapped += npg
            if self.active[slot]:
                need = -(-(int(self._index_np[slot]) + self.segment)
                         // self.page_size)
                assert npg >= need, f"slot {slot}: segment pages unmapped"
                for p in row[:npg]:
                    assert refs[int(p)] >= 1, f"slot {slot}: freed page {p}"
        trie_pages = self._trie.page_count() if self.prefix_share else 0
        assert int(refs.sum()) == mapped + trie_pages, \
            "refcounts out of sync with block tables + trie"
        assert len(set(self._free_pages)) == len(self._free_pages), \
            "duplicate page on free list"
        for p in self._free_pages:
            assert refs[p] == 0, f"page {p} both free and referenced"
        assert (refs >= 0).all(), "negative refcount"
        assert len(self._free_pages) + int((refs > 0).sum()) == self.n_pages, \
            "page leak: free + referenced != total"
        assert self._committed == int(
            (self._slot_reserve - self._slot_unique).sum()) >= 0, \
            "reservation credit out of sync"

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue and all active slots; returns {rid: tokens}."""
        while self.queue or self.active.any():
            self.step_segment()
        return self.outputs


# ---------------------------------------------------------------------- #
def _has_linear_kv(cfg) -> bool:
    """True if decode writes one linear KV-cache row per absolute
    position (so prompt + generation must fit in max_len).  Ring buffers
    (sliding window) wrap and SSM/xLSTM state is O(1)."""
    if cfg.sliding_window > 0:
        return False
    return cfg.family in ("dense", "vlm", "moe", "audio") or (
        cfg.family == "hybrid" and cfg.attn_every > 0)


def _scatter_slot_leaf(dst, src, slot: int):
    """Write one batch-1 cache leaf into slot ``slot`` of a
    batch-``n_slots`` leaf.  The slot (batch) axis position varies per
    leaf ((U, B, ...) for KV, (U, u, B, ...) for stacked SSM layers), so
    it is identified as the one axis where the shapes differ."""
    ax = None
    for i, (a, b) in enumerate(zip(dst.shape, src.shape)):
        if a != b:
            ax = i
            break
    if ax is None:                  # n_slots == 1: plain replacement
        return src.astype(dst.dtype)
    idx = (slice(None),) * ax + (slot,)
    return dst.at[idx].set(jnp.squeeze(src, axis=ax).astype(dst.dtype))


def _scatter_slot(dst_tree, src_tree, slot: int):
    """Tree-wide ``_scatter_slot_leaf``."""
    return jax.tree.map(lambda d, s: _scatter_slot_leaf(d, s, slot),
                        dst_tree, src_tree)
