"""Slot-based continuous batching: the serving twin of the simulator's
sharing scheduler.

A ``DecodeEngine`` owns a fixed number of decode *slots* (the batch
dimension of one shared cache pytree) and a FIFO queue of requests.
Decoding advances all slots together in fused ``lax.scan`` segments (one
dispatch per ``segment`` tokens, per-slot absolute positions carried in
the cache's ``index`` vector); between segments, finished slots are
freed and queued requests are admitted into them — each admission runs
the single-shot prefill for that request alone and scatters the
resulting cache rows into the slot, so a reused slot never observes the
previous occupant's state.

Inactive slots keep stepping (their compute is masked out only by
discarding the emitted tokens) — exactly the fixed-shape trade the
paper's GPU-sharing scheduler makes: pay a bounded, predictable cost per
step in exchange for never re-compiling and never stalling the batch.

Whisper-style encoder-decoder configs are not supported here (each
request would carry its own encoder pass; use ``serve.generate``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import _make_scan_generate
from repro.models import init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) i32
    max_new_tokens: int


class DecodeEngine:
    """Continuous-batching decode engine over ``n_slots`` fixed slots."""

    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 256,
                 segment: int = 8, use_kernels: bool = False):
        assert not cfg.is_encoder_decoder, \
            "encoder-decoder configs are served via serve.generate"
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len, self.segment = n_slots, max_len, segment
        self.use_kernels = use_kernels

        cache = init_cache(cfg, n_slots, max_len)
        cache["index"] = jnp.zeros((n_slots,), jnp.int32)  # per-slot position
        self.cache = cache
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)      # next input token
        self.active = np.zeros(n_slots, bool)
        self.remaining = np.zeros(n_slots, np.int64)
        self.slot_rid: List[int] = [-1] * n_slots

        self.queue: deque = deque()
        self.outputs: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._prefill_fns: Dict[int, Any] = {}
        self._segment_fn = jax.jit(self._make_segment_fn())
        self.stats = {"segments": 0, "admitted": 0, "wasted_slot_steps": 0}

    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue a request; returns its id (key into ``outputs``)."""
        prompt = np.asarray(prompt, np.int32)
        if _has_linear_kv(self.cfg):
            # a linear KV cache holds one row per prompt + generated
            # token, and a slot keeps stepping to the end of its last
            # segment — writes past max_len would be clamped/dropped
            # silently while the validity mask still trusts them
            segs = -(-max_new_tokens // self.segment)
            need = prompt.shape[0] + segs * self.segment
            assert need <= self.max_len, (
                f"request needs {need} cache rows (prompt "
                f"{prompt.shape[0]} + {segs}x{self.segment}-step "
                f"segments) but max_len is {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new_tokens))
        self.outputs[rid] = []
        return rid

    # ------------------------------------------------------------------ #
    def _make_segment_fn(self):
        """One fused greedy scan segment — serve's scan body with the
        PRNG key pinned (greedy ignores it), continuing the carry."""
        run = _make_scan_generate(self.cfg, self.segment, True,
                                  self.use_kernels)
        key = jax.random.PRNGKey(0)

        def seg(params, cache, tok):
            toks, cache, tok, _ = run(params, cache, tok, key)
            return toks, cache, tok
        return seg

    def _prefill_fn(self, plen: int):
        fn = self._prefill_fns.get(plen)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def run(params, tokens):
                cache = init_cache(cfg, 1, max_len)
                return prefill(cfg, params, cache, tokens,
                               use_kernels=self.use_kernels)
            fn = self._prefill_fns[plen] = jax.jit(run)
        return fn

    # ------------------------------------------------------------------ #
    def _admit(self) -> None:
        """Fill every free slot from the queue: solo single-shot prefill,
        then scatter the request's cache rows into the slot."""
        for slot in range(self.n_slots):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.popleft()
            assert req.prompt.shape[0] <= self.max_len
            logits, pcache = self._prefill_fn(req.prompt.shape[0])(
                self.params, jnp.asarray(req.prompt)[None, :])
            self.cache["units"] = _scatter_slot(
                self.cache["units"], pcache["units"], slot)
            self.cache["index"] = self.cache["index"].at[slot].set(
                req.prompt.shape[0])
            first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            self.tok = self.tok.at[slot, 0].set(first)
            self.active[slot] = True
            self.remaining[slot] = req.max_new_tokens
            self.slot_rid[slot] = req.rid
            self.stats["admitted"] += 1

    def step_segment(self) -> None:
        """One fused scan segment + post-segment bookkeeping/admission."""
        self._admit()
        toks, self.cache, self.tok = self._segment_fn(
            self.params, self.cache, self.tok)
        toks = np.asarray(toks)                     # (n_slots, segment)
        self.stats["segments"] += 1
        self.stats["wasted_slot_steps"] += int(
            (~self.active).sum()) * self.segment
        for slot in range(self.n_slots):
            if not self.active[slot]:
                continue
            take = int(min(self.segment, self.remaining[slot]))
            self.outputs[self.slot_rid[slot]].extend(
                int(t) for t in toks[slot, :take])
            self.remaining[slot] -= take
            self.stats["wasted_slot_steps"] += self.segment - take
            if self.remaining[slot] == 0:
                self.active[slot] = False           # slot freed for reuse
                self.slot_rid[slot] = -1

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue and all active slots; returns {rid: tokens}."""
        while self.queue or self.active.any():
            self.step_segment()
        return self.outputs


# ---------------------------------------------------------------------- #
def _has_linear_kv(cfg) -> bool:
    """True if decode writes one linear KV-cache row per absolute
    position (so prompt + generation must fit in max_len).  Ring buffers
    (sliding window) wrap and SSM/xLSTM state is O(1)."""
    if cfg.sliding_window > 0:
        return False
    return cfg.family in ("dense", "vlm", "moe", "audio") or (
        cfg.family == "hybrid" and cfg.attn_every > 0)


def _scatter_slot(dst_tree, src_tree, slot: int):
    """Write a batch-1 cache pytree into slot ``slot`` of the engine's
    batch-``n_slots`` cache.  The slot (batch) axis position varies per
    leaf ((U, B, ...) for KV, (U, u, B, ...) for stacked SSM layers), so
    it is identified as the one axis where the shapes differ."""
    def put(dst, src):
        ax = None
        for i, (a, b) in enumerate(zip(dst.shape, src.shape)):
            if a != b:
                ax = i
                break
        if ax is None:                  # n_slots == 1: plain replacement
            return src.astype(dst.dtype)
        idx = (slice(None),) * ax + (slot,)
        return dst.at[idx].set(jnp.squeeze(src, axis=ax).astype(dst.dtype))
    return jax.tree.map(put, dst_tree, src_tree)
