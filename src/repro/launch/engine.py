"""Slot-based continuous batching: the serving twin of the simulator's
sharing scheduler.

A ``DecodeEngine`` owns a fixed number of decode *slots* (the batch
dimension of one shared cache pytree) and a FIFO queue of requests.
Decoding advances all slots together in fused ``lax.scan`` segments (one
dispatch per ``segment`` tokens, per-slot absolute positions carried in
the cache's ``index`` vector); between segments, finished slots are
freed and queued requests are admitted into them — each admission runs
the single-shot prefill for that request alone and scatters the
resulting cache rows into the slot, so a reused slot never observes the
previous occupant's state.

Inactive slots keep stepping (their compute is masked out only by
discarding the emitted tokens) — exactly the fixed-shape trade the
paper's GPU-sharing scheduler makes: pay a bounded, predictable cost per
step in exchange for never re-compiling and never stalling the batch.

Whisper-style encoder-decoder configs are not supported here (each
request would carry its own encoder pass; use ``serve.generate``).
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cluster import ScriptedFaults, TransientFault
from repro.launch.serve import _make_scan_generate
from repro.models import init_cache, init_paged_cache, prefill
from repro.util.retry import RetryPolicy, retry_call


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) i32
    max_new_tokens: int
    deadline: Optional[float] = None   # absolute clock time; None = none
    priority: int = 0                  # higher = more important
    submitted_at: float = 0.0


class DecodeEngine:
    """Continuous-batching decode engine over ``n_slots`` fixed slots.

    ``paged=True`` (DESIGN.md §15) swaps the dense per-slot KV cache for
    a shared page pool plus per-slot block tables: a slot holds only the
    pages its request actually occupies, so ``n_slots`` can far exceed
    what ``n_slots x max_len`` dense rows would allow at the same cache
    memory.  Admission is bounded by a page *reservation* — a request is
    admitted only when its worst-case page count (prompt + all decode
    segments) is available — while physical pages are assigned lazily,
    one segment ahead of the decode index, and reclaimed the moment the
    slot frees.  Tokens are bitwise identical to the dense engine."""

    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 256,
                 segment: int = 8, use_kernels: bool = False,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 clock=time.monotonic,
                 brownout_depth: int = 0,
                 fault_injector: Optional[ScriptedFaults] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 retry_seed: int = 0,
                 sleep=time.sleep):
        assert not cfg.is_encoder_decoder, \
            "encoder-decoder configs are served via serve.generate"
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len, self.segment = n_slots, max_len, segment
        self.use_kernels = use_kernels
        self.paged = paged

        if paged:
            if not _has_linear_kv(cfg):
                raise ValueError(
                    f"paged KV requires a linear-layout KV cache; family "
                    f"{cfg.family!r} (window {cfg.sliding_window}) has none")
            if n_pages is None:     # dense-equivalent memory by default
                n_pages = n_slots * (max_len // page_size)
            # leaf classification below is by shape: the pool must not
            # coincide with the dense (n_slots, max_len) allocation
            assert not (n_pages == n_slots and page_size == max_len), \
                "degenerate paging (one max_len page per slot)"
            self.page_size, self.n_pages = page_size, n_pages
            cache = init_paged_cache(cfg, n_slots, max_len,
                                     page_size=page_size, n_pages=n_pages)
            dense_shapes = jax.eval_shape(
                lambda: init_cache(cfg, n_slots, max_len)["units"])
            self._is_pool = jax.tree.map(
                lambda pg, dn: pg.shape != dn.shape,
                cache["units"], dense_shapes)
            # host-side paging state
            self._free_pages: List[int] = list(range(n_pages))
            self._avail_pages = n_pages          # un-reserved credit
            self._pages_np = np.full((n_slots, max_len // page_size), -1,
                                     np.int32)
            self._slot_npages = np.zeros(n_slots, np.int64)  # assigned
            self._slot_reserve = np.zeros(n_slots, np.int64)  # total credit
            self._index_np = np.zeros(n_slots, np.int64)     # decode pos
        else:
            cache = init_cache(cfg, n_slots, max_len)
        cache["index"] = jnp.zeros((n_slots,), jnp.int32)  # per-slot position
        self.cache = cache
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)      # next input token
        self.active = np.zeros(n_slots, bool)
        self.remaining = np.zeros(n_slots, np.int64)
        self.slot_rid: List[int] = [-1] * n_slots

        self.queue: deque = deque()
        self.outputs: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._prefill_fns: Dict[int, Any] = {}
        self._segment_fn = jax.jit(self._make_segment_fn())
        # degraded-mode serving (DESIGN.md §16): per-request deadlines
        # with timeout-shedding, admission brown-out under overload, and
        # bounded retry of transient segment faults. All off by default.
        self._clock = clock
        self.brownout_depth = int(brownout_depth)
        self.fault_injector = fault_injector
        self.retry_policy = retry_policy or RetryPolicy()
        self._retry_rng = random.Random(retry_seed)
        self._sleep = sleep
        self.slot_deadline: List[Optional[float]] = [None] * n_slots
        self.shed: Dict[int, str] = {}        # rid -> shed reason
        self.retry_after: Dict[int, float] = {}   # rid -> backoff hint (s)
        self._seg_ewma = 0.0                  # EWMA segment walltime (s)
        self.stats = {"segments": 0, "admitted": 0, "wasted_slot_steps": 0,
                      "peak_active_slots": 0, "shed_deadline": 0,
                      "shed_brownout": 0, "deadline_miss": 0, "retries": 0}
        if paged:
            self.stats.update({
                "pages_total": n_pages, "pages_in_use": 0,
                "peak_pages_in_use": 0, "page_occupancy": 0.0,
                "page_fragmentation": 0.0, "admission_deferred_pages": 0})

    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new_tokens: int = 16, *,
               deadline: Optional[float] = None,
               priority: int = 0) -> int:
        """Queue a request; returns its id (key into ``outputs``).

        ``deadline`` is relative (seconds from now on the engine clock):
        a request that has not *completed* by then is shed — from the
        queue or mid-decode — with its rid recorded in ``shed`` and a
        ``retry_after`` hint. ``priority`` orders brown-out shedding
        under overload (lower priorities shed first); admission itself
        stays FIFO."""
        prompt = np.asarray(prompt, np.int32)
        if _has_linear_kv(self.cfg):
            # a linear KV cache holds one row per prompt + generated
            # token, and a slot keeps stepping to the end of its last
            # segment — writes past max_len would be clamped/dropped
            # silently while the validity mask still trusts them
            segs = -(-max_new_tokens // self.segment)
            need = prompt.shape[0] + segs * self.segment
            assert need <= self.max_len, (
                f"request needs {need} cache rows (prompt "
                f"{prompt.shape[0]} + {segs}x{self.segment}-step "
                f"segments) but max_len is {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        now = self._clock()
        self.queue.append(Request(
            rid, prompt, max_new_tokens,
            deadline=(now + deadline) if deadline is not None else None,
            priority=int(priority), submitted_at=now))
        self.outputs[rid] = []
        return rid

    # -- degraded mode (DESIGN.md §16) --------------------------------- #
    def _retry_after_hint(self) -> float:
        """Coarse back-pressure hint for a shed request: the EWMA
        segment walltime times the current queue depth — roughly when
        the backlog ahead of it will have drained a slot."""
        return self._seg_ewma * (1 + len(self.queue))

    def _shed_request(self, req: Request, reason: str) -> None:
        self.shed[req.rid] = reason
        self.retry_after[req.rid] = self._retry_after_hint()
        self.stats["shed_" + reason] += 1

    def _free_slot(self, slot: int) -> None:
        self.active[slot] = False
        self.slot_rid[slot] = -1
        self.slot_deadline[slot] = None
        self.remaining[slot] = 0
        if self.paged:
            self._free_slot_pages(slot)

    def _shed_expired(self, now: float) -> None:
        """Timeout-shedding: queued requests past their deadline never
        admit; active slots past theirs free immediately (the partial
        output stays in ``outputs`` — the caller sees what was decoded
        before the deadline)."""
        kept = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                self._shed_request(req, "deadline")
            else:
                kept.append(req)
        self.queue = kept
        for slot in range(self.n_slots):
            dl = self.slot_deadline[slot]
            if self.active[slot] and dl is not None and now > dl:
                rid = self.slot_rid[slot]
                self.shed[rid] = "deadline"
                self.retry_after[rid] = self._retry_after_hint()
                self.stats["shed_deadline"] += 1
                self._free_slot(slot)

    def _brownout(self) -> None:
        """Overload graceful degradation: when the queue is deeper than
        ``brownout_depth``, shed the lowest-priority (then youngest)
        queued requests until it fits — load sheds before latency
        collapses, and paying tiers degrade last."""
        if self.brownout_depth <= 0 or len(self.queue) <= self.brownout_depth:
            return
        order = sorted(self.queue,
                       key=lambda r: (r.priority, -r.submitted_at))
        drop = {r.rid for r in
                order[:len(self.queue) - self.brownout_depth]}
        kept = deque()
        for req in self.queue:
            if req.rid in drop:
                self._shed_request(req, "brownout")
            else:
                kept.append(req)
        self.queue = kept

    # ------------------------------------------------------------------ #
    def _make_segment_fn(self):
        """One fused greedy scan segment — serve's scan body with the
        PRNG key pinned (greedy ignores it), continuing the carry."""
        run = _make_scan_generate(self.cfg, self.segment, True,
                                  self.use_kernels)
        key = jax.random.PRNGKey(0)

        def seg(params, cache, tok):
            toks, cache, tok, _ = run(params, cache, tok, key)
            return toks, cache, tok
        return seg

    def _prefill_fn(self, plen: int):
        fn = self._prefill_fns.get(plen)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def run(params, tokens):
                cache = init_cache(cfg, 1, max_len)
                return prefill(cfg, params, cache, tokens,
                               use_kernels=self.use_kernels)
            fn = self._prefill_fns[plen] = jax.jit(run)
        return fn

    # ------------------------------------------------------------------ #
    def _pages_needed(self, req: Request) -> int:
        """Worst-case page count for a request: one row per prompt token
        plus every position its slot will step through (the slot runs
        whole segments, so the last partial segment still writes rows)."""
        segs = -(-req.max_new_tokens // self.segment)
        rows = req.prompt.shape[0] + segs * self.segment
        return -(-rows // self.page_size)

    def _admit(self) -> None:
        """Fill every free slot from the queue: solo single-shot prefill,
        then scatter the request's cache rows into the slot (dense) or
        into freshly assigned pool pages (paged).  Paged admission is
        credit-gated: the request's worst-case page count is reserved up
        front (FIFO — an oversized head blocks the queue rather than
        being bypassed), so ``_grow`` can never run out of pages
        mid-flight."""
        for slot in range(self.n_slots):
            if self.active[slot] or not self.queue:
                continue
            if self.paged:
                req = self.queue[0]
                reserve = self._pages_needed(req)
                if reserve > self._avail_pages:
                    self.stats["admission_deferred_pages"] += 1
                    break
                self.queue.popleft()
            else:
                req = self.queue.popleft()
            plen = req.prompt.shape[0]
            assert plen <= self.max_len
            logits, pcache = self._prefill_fn(plen)(
                self.params, jnp.asarray(req.prompt)[None, :])
            if self.paged:
                ps = self.page_size
                self._avail_pages -= reserve
                self._slot_reserve[slot] = reserve
                npf = -(-plen // ps)
                pids = [self._free_pages.pop() for _ in range(npf)]
                self._pages_np[slot, :] = -1
                self._pages_np[slot, :npf] = pids
                self._slot_npages[slot] = npf
                self._index_np[slot] = plen
                self.cache["units"] = self._scatter_paged(
                    pcache["units"], pids, slot)
            else:
                self.cache["units"] = _scatter_slot(
                    self.cache["units"], pcache["units"], slot)
            self.cache["index"] = self.cache["index"].at[slot].set(plen)
            first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            self.tok = self.tok.at[slot, 0].set(first)
            self.active[slot] = True
            self.remaining[slot] = req.max_new_tokens
            self.slot_rid[slot] = req.rid
            self.slot_deadline[slot] = req.deadline
            self.stats["admitted"] += 1

    def _scatter_paged(self, punits, pids: List[int], slot: int):
        """Scatter a solo prefill cache into the paged engine cache: pool
        leaves take the prompt's rows page by page; per-slot leaves (SSM
        state, whisper cross K/V) scatter into the slot axis as in the
        dense engine."""
        ps = self.page_size
        npf = len(pids)
        pids_a = jnp.asarray(pids, jnp.int32)

        def put(dst, src, is_pool):
            if not is_pool:
                return _scatter_slot_leaf(dst, src, slot)
            u = src.shape[0]                   # src: (U, 1, max_len, H, D)
            rows = src[:, 0, :npf * ps]
            rows = rows.reshape((u, npf, ps) + src.shape[3:])
            return dst.at[:, pids_a].set(rows.astype(dst.dtype))
        return jax.tree.map(put, self.cache["units"], punits, self._is_pool)

    def _grow(self) -> None:
        """Assign pool pages covering the upcoming segment for every
        active slot — lazy assignment against the admission reservation,
        so a slot only ever holds pages for rows it is about to write."""
        ps = self.page_size
        for slot in range(self.n_slots):
            if not self.active[slot]:
                continue
            pend = -(-(int(self._index_np[slot]) + self.segment) // ps)
            while self._slot_npages[slot] < pend:
                self._pages_np[slot, self._slot_npages[slot]] = \
                    self._free_pages.pop()
                self._slot_npages[slot] += 1

    def step_segment(self) -> None:
        """One fused scan segment + post-segment bookkeeping/admission.
        Degraded-mode pre-pass: expired requests shed (queued and
        active) and the queue brown-outs before admission refills the
        freed slots."""
        self._shed_expired(self._clock())
        self._brownout()
        self._admit()
        if self.paged:
            self._grow()
            # one host->device push of the (n_slots, P) block table per
            # segment covers admissions, growth, and last-segment frees
            self.cache["pages"] = jnp.asarray(self._pages_np)
            in_use = int(self._slot_npages.sum())
            self.stats["pages_in_use"] = in_use
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], in_use)
            rows = int((self._index_np[self.active] + self.segment).sum())
            occ = rows / (in_use * self.page_size) if in_use else 0.0
            self.stats["page_occupancy"] = occ
            self.stats["page_fragmentation"] = 1.0 - occ
        self.stats["peak_active_slots"] = max(
            self.stats["peak_active_slots"], int(self.active.sum()))

        def attempt():
            # faults strike before the call (inputs are not donated, so
            # a retried segment replays the identical computation)
            if self.fault_injector is not None:
                self.fault_injector.check(self.stats["segments"],
                                          ("segment",))
            return self._segment_fn(self.params, self.cache, self.tok)

        t0 = time.perf_counter()
        toks, self.cache, self.tok = retry_call(
            attempt, policy=self.retry_policy, retry_on=(TransientFault,),
            rng=self._retry_rng, sleep=self._sleep,
            on_retry=lambda *_: self.stats.__setitem__(
                "retries", self.stats["retries"] + 1))
        dt = time.perf_counter() - t0
        self._seg_ewma = (dt if self._seg_ewma == 0.0
                          else 0.2 * dt + 0.8 * self._seg_ewma)
        toks = np.asarray(toks)                     # (n_slots, segment)
        self.stats["segments"] += 1
        self.stats["wasted_slot_steps"] += int(
            (~self.active).sum()) * self.segment
        for slot in range(self.n_slots):
            if not self.active[slot]:
                continue
            if self.paged:
                self._index_np[slot] += self.segment
            take = int(min(self.segment, self.remaining[slot]))
            self.outputs[self.slot_rid[slot]].extend(
                int(t) for t in toks[slot, :take])
            self.remaining[slot] -= take
            self.stats["wasted_slot_steps"] += self.segment - take
            if self.remaining[slot] == 0:
                dl = self.slot_deadline[slot]
                if dl is not None and self._clock() > dl:
                    # completed, delivered — but late
                    self.stats["deadline_miss"] += 1
                self._free_slot(slot)               # slot freed for reuse

    def _free_slot_pages(self, slot: int) -> None:
        """Reclaim a freed slot's pages and reservation.  The block table
        row is cleared immediately (pushed to the device before the next
        segment), so the stale slot's continued writes drop instead of
        corrupting whoever gets the pages next."""
        npg = int(self._slot_npages[slot])
        self._free_pages.extend(int(p) for p in self._pages_np[slot, :npg])
        self._pages_np[slot, :] = -1
        self._slot_npages[slot] = 0
        self._avail_pages += int(self._slot_reserve[slot])
        self._slot_reserve[slot] = 0
        self._index_np[slot] = 0

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue and all active slots; returns {rid: tokens}."""
        while self.queue or self.active.any():
            self.step_segment()
        return self.outputs


# ---------------------------------------------------------------------- #
def _has_linear_kv(cfg) -> bool:
    """True if decode writes one linear KV-cache row per absolute
    position (so prompt + generation must fit in max_len).  Ring buffers
    (sliding window) wrap and SSM/xLSTM state is O(1)."""
    if cfg.sliding_window > 0:
        return False
    return cfg.family in ("dense", "vlm", "moe", "audio") or (
        cfg.family == "hybrid" and cfg.attn_every > 0)


def _scatter_slot_leaf(dst, src, slot: int):
    """Write one batch-1 cache leaf into slot ``slot`` of a
    batch-``n_slots`` leaf.  The slot (batch) axis position varies per
    leaf ((U, B, ...) for KV, (U, u, B, ...) for stacked SSM layers), so
    it is identified as the one axis where the shapes differ."""
    ax = None
    for i, (a, b) in enumerate(zip(dst.shape, src.shape)):
        if a != b:
            ax = i
            break
    if ax is None:                  # n_slots == 1: plain replacement
        return src.astype(dst.dtype)
    idx = (slice(None),) * ax + (slot,)
    return dst.at[idx].set(jnp.squeeze(src, axis=ax).astype(dst.dtype))


def _scatter_slot(dst_tree, src_tree, slot: int):
    """Tree-wide ``_scatter_slot_leaf``."""
    return jax.tree.map(lambda d, s: _scatter_slot_leaf(d, s, slot),
                        dst_tree, src_tree)
