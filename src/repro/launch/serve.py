"""Batched serving driver: prefill a batch of prompts, then step the
decode loop with the per-family cache (KV / ring-buffer / SSM state).

``python -m repro.launch.serve --arch xlstm-1.3b --reduced --tokens 32``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill_cache_whisper)


def prefill(cfg, params, tokens, cache):
    """Teacher-forced prefill: feed prompt tokens through decode_step to
    populate the cache (portable across all cache families)."""
    for t in range(tokens.shape[1]):
        logits, cache = decode_step(cfg, params, cache, tokens[:, t:t + 1])
    return logits, cache


def generate(cfg, params, prompt, *, max_new_tokens=16, max_len=256,
             greedy=True, frames=None, key=None):
    b = prompt.shape[0]
    if cfg.is_encoder_decoder:
        assert frames is not None
        cache = prefill_cache_whisper(cfg, params, frames, b, max_len)
    else:
        cache = init_cache(cfg, b, max_len)
    logits, cache = prefill(cfg, params, prompt, cache)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(max_new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok)
        if greedy:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1])[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-1.3b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.time()
    toks = generate(cfg, params, prompt, max_new_tokens=args.tokens,
                    frames=frames)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(np.asarray(toks[0]))
    return toks


if __name__ == "__main__":
    main()
