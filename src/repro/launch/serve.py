"""Fused-decode serving driver.

Prefill populates the per-family cache (KV / ring-buffer / SSM state)
with ONE full-sequence jitted call (``models.prefill``), and generation
runs the whole token loop inside one jitted ``lax.scan`` program —
an N-token generation is one dispatch instead of N, with the cache
buffers donated to the scan.  The seed's per-token paths are kept as
``prefill_mode="per_token"`` / ``engine="eager"`` benchmark baselines.

Jitted callables are cached at module level across ``generate()`` calls,
keyed by config identity + batch/sequence shape, so repeated calls (a
serving loop, the benchmark) never re-trace.

``python -m repro.launch.serve --arch xlstm-1.3b --reduced --tokens 32``
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import (decode_step, init_cache, init_params, prefill,
                          prefill_cache_whisper, prefill_extend)

# jitted decode/prefill callables, reused across generate() calls
_JIT_CACHE: Dict[tuple, Callable] = {}


def _cached(key: tuple, make: Callable[[], Callable]) -> Callable:
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = _JIT_CACHE[key] = make()
    return fn


def jit_cache_size() -> int:
    return len(_JIT_CACHE)


def clear_jit_cache() -> None:
    _JIT_CACHE.clear()


# ---------------------------------------------------------------------- #
# prefill
# ---------------------------------------------------------------------- #
def _decode_step_fn(cfg, use_kernels: bool) -> Callable:
    """Cache keys hold only trace-affecting Python values; jax.jit keys
    the input shapes itself, so the dict stays bounded per config."""
    return _cached(("step", cfg, use_kernels), lambda: jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, use_kernels=use_kernels)))


def prefill_one_shot(cfg, params, tokens, cache, *,
                     use_kernels: bool = False):
    """Single-shot prefill: one jitted call populates the whole cache.
    Returns (last-position logits (B, 1, V), cache)."""
    fn = _cached(("prefill", cfg, use_kernels),
                 lambda: jax.jit(lambda p, c, t: prefill(
                     cfg, p, c, t, use_kernels=use_kernels)))
    logits, cache = fn(params, cache, tokens)
    return logits[:, -1:], cache


def prefill_extend_cached(cfg, params, cache, tokens, *, start: int):
    """Suffix prefill (prefix-shared serving, DESIGN.md §18): one jitted
    call computes rows ``[start, start+S)`` into a cache whose prefix
    rows are already populated.  ``start`` is a static Python int — it
    keys the cache entry (and the trace) so the sliced attention extent
    stays exact, which the bitwise-identity contract requires.  Returns
    (logits (B, S, V), cache)."""
    fn = _cached(("prefill_extend", cfg, start),
                 lambda: jax.jit(lambda p, c, t: prefill_extend(
                     cfg, p, c, t, start=start)))
    return fn(params, cache, tokens)


def prefill_per_token(cfg, params, tokens, cache, *,
                      use_kernels: bool = False):
    """Seed-style teacher-forced prefill: T sequential ``decode_step``
    dispatches (kept as the benchmark baseline)."""
    step = _decode_step_fn(cfg, use_kernels)
    for t in range(tokens.shape[1]):
        logits, cache = step(params, cache, tokens[:, t:t + 1])
    return logits[:, -1:], cache


# ---------------------------------------------------------------------- #
# generation
# ---------------------------------------------------------------------- #
def _make_scan_generate(cfg, steps: int, greedy: bool, use_kernels: bool):
    """The fused loop: token scan inside one jitted program.  Emits the
    carried token each step and samples the next from its logits — the
    exact op/key order of the eager loop, so outputs are bit-identical.
    Returns (tokens (B, steps), cache, next token, key) so callers that
    segment generation (``launch/engine.py``) can continue the carry."""
    def run(params, cache, tok, key):
        def body(carry, _):
            cache, tok, key = carry
            logits, cache = decode_step(cfg, params, cache, tok,
                                        use_kernels=use_kernels)
            if greedy:
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, -1])[:, None].astype(jnp.int32)
            return (cache, nxt, key), tok
        (cache, tok, key), toks = jax.lax.scan(
            body, (cache, tok, key), length=steps)
        return jnp.moveaxis(toks[:, :, 0], 0, 1), cache, tok, key
    return run


def generate(cfg, params, prompt, *, max_new_tokens=16, max_len=256,
             greedy=True, frames=None, key=None, engine="scan",
             prefill_mode="one_shot", use_kernels=False):
    """Generate ``max_new_tokens`` tokens for a (B, S) prompt batch.

    engine: "scan" (fused lax.scan loop, one dispatch) or "eager"
    (per-token dispatches, the seed path).  prefill_mode: "one_shot"
    (one jitted call) or "per_token".  Both pairs produce identical
    tokens, with one caveat: one-shot prefill routes MoE prompts through
    the batched ``forward`` capacity semantics, so at tight
    ``moe_capacity_factor`` a saturated expert may drop prompt tokens
    the per-token path would route — pass ``prefill_mode="per_token"``
    or raise the capacity factor for exact parity on MoE archs."""
    b = prompt.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if cfg.is_encoder_decoder:
        assert frames is not None
        cache = prefill_cache_whisper(cfg, params, frames, b, max_len)
    else:
        cache = init_cache(cfg, b, max_len)

    if prefill_mode == "one_shot":
        logits, cache = prefill_one_shot(cfg, params, prompt, cache,
                                         use_kernels=use_kernels)
    elif prefill_mode == "per_token":
        logits, cache = prefill_per_token(cfg, params, prompt, cache,
                                          use_kernels=use_kernels)
    else:
        raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    if engine == "scan":
        run = _cached(
            ("generate", cfg, max_new_tokens, greedy, use_kernels),
            lambda: jax.jit(_make_scan_generate(
                cfg, max_new_tokens, greedy, use_kernels),
                donate_argnums=(1,)))          # cache buffers are donated
        toks = run(params, cache, tok, key)[0]
        return toks
    if engine == "eager":
        step = _decode_step_fn(cfg, use_kernels)
        out = []
        for _ in range(max_new_tokens):
            out.append(tok)
            logits, cache = step(params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1])[:, None].astype(jnp.int32)
        return jnp.concatenate(out, axis=1)
    raise ValueError(f"unknown engine {engine!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-1.3b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--engine", default="scan", choices=("scan", "eager"))
    ap.add_argument("--prefill", default="one_shot",
                    choices=("one_shot", "per_token"))
    ap.add_argument("--kernels", action="store_true",
                    help="Pallas flash-decode path (interpret mode on CPU)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.perf_counter()
    toks = generate(cfg, params, prompt, max_new_tokens=args.tokens,
                    frames=frames, engine=args.engine,
                    prefill_mode=args.prefill, use_kernels=args.kernels)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} engine={args.engine} generated {toks.shape} "
          f"in {dt:.2f}s ({args.batch * args.tokens / dt:.1f} tok/s)")
    print(np.asarray(toks[0]))
    return toks


if __name__ == "__main__":
    main()
