"""Re-derive FLOPs/bytes/collective stats for existing dry-run records
from their kept HLO files (no recompilation) — used when the HLO
analyzers improve. Updates artifacts/dryrun/dryrun.json in place."""
from __future__ import annotations

import json
import os
import sys

from repro.launch.hlo_flops import hlo_flops_bytes
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def main() -> int:
    path = os.path.join(ART, "dryrun.json")
    with open(path) as f:
        records = json.load(f)
    n = 0
    for rec in records:
        if rec.get("status") != "ok":
            continue
        hlo_path = os.path.join(
            ART, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.hlo.txt")
        if not os.path.exists(hlo_path):
            print(f"missing HLO for {rec['arch']} {rec['shape']} "
                  f"{rec['mesh']}; skipped", file=sys.stderr)
            continue
        with open(hlo_path) as f:
            fb = hlo_flops_bytes(f.read())
        rec["hlo_flops_per_device"] = float(fb["flops"])
        rec["hlo_bytes_per_device"] = float(fb["bytes"])
        rec["collective_bytes_per_device"] = fb["collectives"]
        rec["roofline"] = {
            "compute_s": fb["flops"] / PEAK_FLOPS_BF16,
            "memory_s": fb["bytes"] / HBM_BW,
            "collective_s": fb["collectives"].get("total", 0.0) / ICI_BW,
        }
        rec["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=lambda k: rec["roofline"][k])
        n += 1
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"re-analyzed {n} records")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
