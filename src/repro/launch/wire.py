"""Fleet wire protocol: newline-delimited JSON over a local socket.

The master/agent runtime (DESIGN.md §17) emulates a multi-host cluster
as one process per server, talking over localhost TCP — deliberately the
thinnest transport that still exhibits real distributed failure modes
(half-open connections, SIGKILLed peers, late messages from fenced
zombies). Everything that crosses the wire is a small JSON dict; job
*state* never does — params/optimizer tensors travel through the shared
checkpoint directory (CRC-verified npz), exactly how a ``jax.distributed``
deployment would use a network filesystem or object store.

Also here: the :class:`JobSpec` <-> JSON codec. An ``ArchConfig`` is a
flat frozen dataclass of primitives, so it serializes losslessly; the
agent reconstructs the spec and re-derives params/opt/batch with the
same seeded initializers the single-host executor uses — which is what
makes cross-process runs bit-comparable.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import threading
from typing import Any, Dict, Optional

from repro.configs.base import ArchConfig
from repro.launch.cluster import JobSpec

# fields whose JSON list form must round-trip back to tuples
_TUPLE_FIELDS = tuple(
    f.name for f in dataclasses.fields(ArchConfig)
    if "Tuple" in str(f.type) or isinstance(f.default, tuple))


class WireError(ConnectionError):
    """The peer went away (EOF / reset) or sent an unparseable frame."""


def spec_to_wire(spec: JobSpec) -> Dict[str, Any]:
    return {
        "cfg": dataclasses.asdict(spec.cfg),
        "batch": spec.batch,
        "accum_steps": spec.accum_steps,
        "seq": spec.seq,
        "seed": spec.seed,
    }


def spec_from_wire(d: Dict[str, Any]) -> JobSpec:
    cfg_dict = dict(d["cfg"])
    for name in _TUPLE_FIELDS:
        if name in cfg_dict and isinstance(cfg_dict[name], list):
            cfg_dict[name] = tuple(cfg_dict[name])
    return JobSpec(cfg=ArchConfig(**cfg_dict), batch=int(d["batch"]),
                   accum_steps=int(d["accum_steps"]), seq=int(d["seq"]),
                   seed=int(d["seed"]))


def send_msg(sock: socket.socket, msg: Dict[str, Any],
             lock: Optional[threading.Lock] = None) -> None:
    """One JSON frame. ``lock`` serializes writers that share a socket
    (an agent's heartbeat thread vs its lease reporter)."""
    data = (json.dumps(msg, separators=(",", ":")) + "\n").encode()
    try:
        if lock is not None:
            with lock:
                sock.sendall(data)
        else:
            sock.sendall(data)
    except OSError as exc:
        raise WireError(f"send failed: {exc}") from exc


class MessageReader:
    """Buffered frame reader for one socket. ``read()`` returns the next
    decoded message or ``None`` on a clean/abrupt EOF — a SIGKILLed
    peer's socket reads as EOF (or reset), never as a hang."""

    def __init__(self, sock: socket.socket) -> None:
        self._sock = sock
        self._buf = b""

    def read(self) -> Optional[Dict[str, Any]]:
        while b"\n" not in self._buf:
            try:
                chunk = self._sock.recv(65536)
            except OSError:
                return None
            if not chunk:
                return None
            self._buf += chunk
        line, self._buf = self._buf.split(b"\n", 1)
        if not line.strip():
            return self.read()
        try:
            return json.loads(line)
        except json.JSONDecodeError as exc:
            raise WireError(f"bad frame {line[:80]!r}: {exc}") from exc


def request(host: str, port: int, msg: Dict[str, Any],
            timeout: float = 10.0) -> Dict[str, Any]:
    """One-shot client RPC: connect, send a hello + the request, return
    the single JSON response (the CLI's transport)."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        send_msg(sock, {"type": "hello", "role": "client"})
        send_msg(sock, msg)
        reader = MessageReader(sock)
        resp = reader.read()
    if resp is None:
        raise WireError("master closed the connection without replying")
    return resp
