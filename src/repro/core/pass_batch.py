"""Vectorized pending-queue scheduling pass — Algorithm 1 over flat arrays.

``repro.core.pair_batch`` vectorized Algorithm 2 for *one* pending job
against all donors; the pass around it was still a Python loop: sort the
pending queue, and per job re-derive donor state, call the decision
core, and walk the placement. At datacenter scale (10k GPUs, 100k jobs)
that per-job Python overhead dominates the schedule pass (DESIGN.md §14).

This module keeps the whole pass in preallocated NumPy arrays:

* :class:`FlatJobs` — per-job columns (progress, rate, blocked-until,
  memory footprint, solo iteration time, model code) mirrored from the
  engine's mutations, plus a swap-remove donor index fed by
  ``ClusterState._mark_single``/``_unmark_single``. Attached to the
  cluster as ``ClusterState._flat``; ``None`` means no mirror is kept
  (scalar/batched paths, numpy-less environments).
* :class:`GridPass` — an append-only flat table over the pending queue
  (sort keys, GPU wants, padded Algorithm-2 candidate tables) and the
  pass driver: it evaluates Theorem 1 for all pending jobs x all donors
  x all candidate sub-batches in one (chunked) grid and walks placements
  with a masked ``(key, jid)`` argmin instead of a sorted Python loop.

The walk reproduces the scalar pass exactly: the scalar path visits
pending jobs once in ``(expected_remaining_time, jid)`` order, jobs it
cannot act on have no side effects, and a placement never makes it
*revisit* an earlier job within the same pass — so after each placement
the argmin continues from a ``(key, jid)`` floor. A job is actionable
when it fits the free GPUs outright, or when its sharing donors' single
GPUs plus the free GPUs cover the request (the exact success predicate
of the scalar placement loop; donor order only changes *which* GPUs).
The arithmetic reuses :func:`repro.core.pair_batch._theorem1` and
``_structural_xi`` element-for-element, so grid decisions are bitwise
identical to the scalar/batched paths —
``tests/test_decision_equivalence.py`` and the differential fuzz
harness in ``tests/test_engine_equivalence.py`` pin this.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .interference import InterferenceModel
from .job import Job, JobState
from .pair_batch import _structural_xi, _theorem1, job_candidate_table

__all__ = ["FlatJobs", "GridPass"]

# max elements of a (pending-chunk x donor x candidate) grid temporary
_CHUNK_ELEMS = 2_000_000


class FlatJobs:
    """Flat per-job columns + donor index, mirrored from engine mutations.

    The engine pushes updates at every site that changes the mirrored
    fields (``_accrue``, ``start_job``, ``preempt_job``,
    ``reconfigure_job``, rate refreshes); ``ClusterState`` pushes donor
    membership/ownership counts from its single-occupancy index. Columns
    for jobs that never ran are unspecified — only donor rows (running
    jobs owning single-occupancy GPUs) are ever gathered.
    """

    __slots__ = ("row", "models", "iters", "iters_done", "last_prog",
                 "rate", "blocked", "run_mem", "t_run", "code",
                 "d_rows", "d_jids", "d_singles", "d_slot", "d_count",
                 "_xi_for", "_xi_mats")

    def __init__(self, jobs: List[Job]) -> None:
        n = len(jobs)
        self.row: Dict[int, int] = {}
        self.iters = np.zeros(n, dtype=np.float64)
        self.iters_done = np.zeros(n, dtype=np.float64)
        self.last_prog = np.zeros(n, dtype=np.float64)
        self.rate = np.zeros(n, dtype=np.float64)
        self.blocked = np.zeros(n, dtype=np.float64)
        self.run_mem = np.zeros(n, dtype=np.float64)
        self.t_run = np.zeros(n, dtype=np.float64)
        self.code = np.zeros(n, dtype=np.intp)
        model_index: Dict[str, int] = {}
        for i, job in enumerate(jobs):
            self.row[job.jid] = i
            self.iters[i] = job.iters
            c = model_index.get(job.model)
            if c is None:
                c = model_index.setdefault(job.model, len(model_index))
            self.code[i] = c
        self.models = list(model_index)       # code -> model name
        # donor index: slots [0, d_count) are live, swap-remove on exit
        self.d_rows = np.zeros(n, dtype=np.int64)
        self.d_jids = np.zeros(n, dtype=np.int64)
        self.d_singles = np.zeros(n, dtype=np.int64)
        self.d_slot: Dict[int, int] = {}
        self.d_count = 0
        self._xi_for = None
        self._xi_mats = None

    # -- engine mirror hooks ------------------------------------------- #
    def note_start(self, job: Job, blocked_until: float) -> None:
        r = self.row[job.jid]
        self.iters_done[r] = job.iters_done
        self.last_prog[r] = job.last_progress_at
        self.rate[r] = job.current_rate
        self.blocked[r] = blocked_until
        self.run_mem[r] = job.perf.mem_bytes(job.sub_batch)
        self.t_run[r] = job.solo_t_iter

    def note_progress(self, job: Job) -> None:
        r = self.row[job.jid]
        self.iters_done[r] = job.iters_done
        self.last_prog[r] = job.last_progress_at

    def note_rate(self, job: Job) -> None:
        self.rate[self.row[job.jid]] = job.current_rate

    def note_reconfig(self, job: Job) -> None:
        r = self.row[job.jid]
        self.run_mem[r] = job.perf.mem_bytes(job.sub_batch)
        self.t_run[r] = job.solo_t_iter

    def set_donor_singles(self, jid: int, count: int) -> None:
        """Maintain the donor slots from ClusterState's single-occupancy
        transitions; ``count == 0`` removes the donor (swap-remove)."""
        slot = self.d_slot.get(jid)
        if count:
            if slot is None:
                slot = self.d_count
                self.d_count = slot + 1
                self.d_slot[jid] = slot
                self.d_rows[slot] = self.row[jid]
                self.d_jids[slot] = jid
            self.d_singles[slot] = count
        elif slot is not None:
            last = self.d_count - 1
            if slot != last:
                self.d_rows[slot] = self.d_rows[last]
                self.d_jids[slot] = moved = self.d_jids[last]
                self.d_singles[slot] = self.d_singles[last]
                self.d_slot[int(moved)] = slot
            self.d_count = last
            del self.d_slot[jid]

    def backfill(self, engine) -> None:
        """Capture the engine's current state at attach time (the mirror
        hooks only cover mutations from here on)."""
        blocked = engine._blocked_until
        for job in engine.running.values():
            self.note_start(job, blocked.get(job.jid, 0.0))
        for jid, count in engine.cluster._donor_count.items():
            self.set_donor_singles(jid, count)

    # -- pass-time reads ----------------------------------------------- #
    def donor_rem(self, rows: np.ndarray, now: float) -> np.ndarray:
        """Vectorized mirror of ``EngineBase.remaining_at`` — virtual
        remaining iterations at ``now`` without materializing progress
        (same IEEE-754 expression per lane as the scalar helper)."""
        lp = self.last_prog[rows]
        begin = np.maximum(lp, self.blocked[rows])
        rate = self.rate[rows]
        done = self.iters_done[rows]
        iters = self.iters[rows]
        adv = np.minimum(iters, done + (now - begin) * rate)
        done = np.where((now > begin) & (rate > 0.0), adv, done)
        return np.maximum(0.0, iters - done)

    def xi_universe(self, interference: InterferenceModel):
        """(K x K) xi-constant matrices over the model registry, indexed
        ``[new_model_code, donor_model_code]`` — the grid gathers them
        through the per-job code column. Same lookups as
        ``DonorBatch.xi_terms`` (fixed two-way pairs, one-way table
        hits as NaN-defaulted overrides)."""
        if self._xi_for is interference:
            return self._xi_mats
        models = self.models
        k = len(models)
        fixed = np.zeros((k, k), dtype=bool)
        xi_run = np.ones((k, k), dtype=np.float64)
        xi_new = np.ones((k, k), dtype=np.float64)
        hit_run = np.full((k, k), np.nan, dtype=np.float64)
        hit_new = np.full((k, k), np.nan, dtype=np.float64)
        table = interference.table
        for cn, mn in enumerate(models):          # pending (new) job model
            for cd, md in enumerate(models):      # donor model
                f = interference.pair_fixed(md, mn)
                if f is not None:
                    fixed[cn, cd] = True
                    xi_run[cn, cd], xi_new[cn, cd] = f
                    continue
                hr = table.get((md, mn))
                if hr is not None:
                    hit_run[cn, cd] = hr[0]
                hn = table.get((mn, md))
                if hn is not None:
                    hit_new[cn, cd] = hn[0]
        self._xi_for = interference
        self._xi_mats = (fixed, xi_run, xi_new, hit_run, hit_new)
        return self._xi_mats


class GridPass:
    """Flat pending table + the vectorized Algorithm-1 pass driver.

    Owned by ``SJF_BSBF`` (one per simulation); construction attaches a
    :class:`FlatJobs` mirror to the cluster and backfills it. The table
    is append-only with lazy compaction: arrivals are ingested from the
    engine's arrival cursor, placed rows are tombstoned, and any
    preemption (detected via ``engine.preemptions_total``) rebuilds the
    table because requeued jobs carry changed sort keys.
    """

    def __init__(self, sim) -> None:
        self.sim = sim
        engine = sim.engine
        cluster = sim.cluster
        flat = cluster._flat
        if flat is None:
            flat = FlatJobs(list(sim.jobs.values()))
            cluster._flat = flat
            flat.backfill(engine)
        self.flat: FlatJobs = flat
        cap = max(16, len(sim.jobs) or 1)
        self._cap = cap
        self._cmax = 8
        self._n = 0
        self._dead = 0
        self._keys = np.zeros(cap, dtype=np.float64)
        self._jids = np.zeros(cap, dtype=np.int64)
        self._want = np.zeros(cap, dtype=np.int64)
        self._iters = np.zeros(cap, dtype=np.float64)
        self._code = np.zeros(cap, dtype=np.intp)
        self._alive = np.zeros(cap, dtype=bool)
        self._tab = np.zeros(cap, dtype=bool)   # candidate row filled?
        self._bs = np.ones((cap, self._cmax), dtype=np.int64)
        self._tn = np.ones((cap, self._cmax), dtype=np.float64)
        self._mem = np.full((cap, self._cmax), np.inf, dtype=np.float64)
        self._jobs: List = []
        self._seen = 0
        self._pstamp = -1
        self._rebuild(sim)

    # -- table maintenance --------------------------------------------- #
    def _grow_rows(self) -> None:
        cap = self._cap * 2
        for name in ("_keys", "_jids", "_want", "_iters", "_code",
                     "_alive", "_tab"):
            old = getattr(self, name)
            new = np.zeros(cap, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)
        for name, fill in (("_bs", 1), ("_tn", 1.0), ("_mem", np.inf)):
            old = getattr(self, name)
            new = np.full((cap, self._cmax), fill, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)
        self._cap = cap

    def _grow_candidates(self, need: int) -> None:
        cmax = self._cmax
        while cmax < need:
            cmax *= 2
        for name, fill in (("_bs", 1), ("_tn", 1.0), ("_mem", np.inf)):
            old = getattr(self, name)
            new = np.full((self._cap, cmax), fill, dtype=old.dtype)
            new[:, : self._cmax] = old
            setattr(self, name, new)
        self._cmax = cmax

    def _append(self, job: Job) -> None:
        if self._n == self._cap:
            self._grow_rows()
        i = self._n
        self._n = i + 1
        self._keys[i] = job.expected_remaining_time
        self._jids[i] = job.jid
        self._want[i] = job.alloc_gpus or job.gpus
        self._iters[i] = job.iters
        self._code[i] = self.flat.code[self.flat.row[job.jid]]
        self._alive[i] = True
        # candidate table built lazily on the job's first share decision
        # — most jobs start exclusively and never need one
        self._tab[i] = False
        self._jobs.append(job)

    def _fill_tables(self, rows: np.ndarray) -> None:
        for i in rows:
            bs, _ss, tn, mem = job_candidate_table(self._jobs[i])
            c = len(bs)
            if c > self._cmax:
                self._grow_candidates(c)
            self._bs[i, :c] = bs
            self._bs[i, c:] = 1
            self._tn[i, :c] = tn
            self._tn[i, c:] = 1.0
            self._mem[i, :c] = mem
            self._mem[i, c:] = np.inf
        self._tab[rows] = True

    def _kill(self, i: int) -> None:
        self._alive[i] = False
        self._jobs[i] = None
        self._dead += 1

    def _maybe_compact(self) -> None:
        # amortized: tiny tables tolerate tombstones, so only sweep once
        # enough rows are dead to halve the walk. Callers must not hold
        # row indices across this (compaction renumbers rows).
        if self._dead >= 16 and self._dead * 2 > self._n:
            self._compact()

    def _compact(self) -> None:
        n = self._n
        mask = self._alive[:n]
        live = int(mask.sum())
        for name in ("_keys", "_jids", "_want", "_iters", "_code", "_tab"):
            arr = getattr(self, name)
            arr[:live] = arr[:n][mask]
        for name in ("_bs", "_tn", "_mem"):
            arr = getattr(self, name)
            arr[:live] = arr[:n][mask]
        self._jobs = [j for j in self._jobs if j is not None]
        self._alive[:live] = True
        self._n = live
        self._dead = 0

    def _rebuild(self, sim) -> None:
        engine = sim.engine
        self._n = 0
        self._dead = 0
        self._jobs = []
        self._alive[:] = False
        for job in engine.pending:
            if job.state is JobState.PENDING:
                self._append(job)
        self._seen = engine._arrival_idx
        self._pstamp = engine.preemptions_total

    def _ingest(self, engine) -> None:
        idx = engine._arrival_idx
        if idx > self._seen:
            arrivals = engine.arrivals
            for k in range(self._seen, idx):
                job = arrivals[k]
                if job.state is JobState.PENDING:
                    self._append(job)
            self._seen = idx

    # -- grid decisions ------------------------------------------------ #
    def _decide(self, cand: np.ndarray, interference: InterferenceModel,
                cap: float, now: float):
        """Algorithm 2 / Theorem 1 for pending rows ``cand`` x all
        donors; returns ``(share, avg, sub, d_jids, d_singles)`` with
        the leading axis aligned to ``cand``. Mirrors
        ``pair_batch.best_sharing_configs`` expression-for-expression
        (the broadcasts only add a pending axis), so every row is
        bitwise identical to the per-job batched/scalar result."""
        flat = self.flat
        dn = flat.d_count
        drow = flat.d_rows[:dn]
        d_jids = flat.d_jids[:dn].copy()
        d_singles = flat.d_singles[:dn].copy()
        run_mem = flat.run_mem[drow]
        t_run = flat.t_run[drow]
        rem = flat.donor_rem(drow, now)
        codes_d = flat.code[drow]
        codes_p = self._code[cand]
        fixed_m, xi_run_m, xi_new_m, hit_run_m, hit_new_m = \
            flat.xi_universe(interference)
        fixed_pd = fixed_m[codes_p[:, None], codes_d[None, :]]
        xr = xi_run_m[codes_p[:, None], codes_d[None, :]]
        xn = xi_new_m[codes_p[:, None], codes_d[None, :]]
        p = cand.size
        cmax = self._cmax
        share = np.empty((p, dn), dtype=bool)
        avg = np.empty((p, dn), dtype=np.float64)
        sub = np.empty((p, dn), dtype=np.int64)
        all_fixed = bool(fixed_pd.all())
        step = max(1, _CHUNK_ELEMS // max(1, dn * cmax))
        for s in range(0, p, step):
            e = min(p, s + step)
            rows = cand[s:e]
            mem_rows = self._mem[rows]            # (c, C), +inf padded
            tn_rows = self._tn[rows]
            bs_rows = self._bs[rows]
            it_rows = self._iters[rows]
            feasible = (mem_rows[:, None, :] + run_mem[None, :, None]
                        <= cap)                    # (c, D, C)
            any_f = feasible.any(axis=2)
            first_idx = np.argmax(feasible, axis=2)
            if all_fixed:
                # first-feasible (largest) sub-batch is optimal when xi
                # is sub-batch independent — same shortcut as the
                # scalar sweep's break and pair_batch's fixed branch
                sel = first_idx
                tn_sel = np.take_along_axis(tn_rows, sel, axis=1)
                sh, av, _t0, _t1, _t2, _t3 = _theorem1(
                    t_run[None, :], rem[None, :], xr[s:e], tn_sel,
                    it_rows[:, None], xn[s:e])
            else:
                hr = hit_run_m[codes_p[s:e, None], codes_d[None, :]]
                hn = hit_new_m[codes_p[s:e, None], codes_d[None, :]]
                fx = fixed_pd[s:e]
                t_new_g = tn_rows[:, None, :]
                mem_frac = (run_mem[None, :, None]
                            + mem_rows[:, None, :]) / cap
                xi_run_g = _structural_xi(interference,
                                          t_run[None, :, None], t_new_g,
                                          mem_frac)
                xi_new_g = _structural_xi(interference, t_new_g,
                                          t_run[None, :, None], mem_frac)
                run_const = fx | ~np.isnan(hr)
                new_const = fx | ~np.isnan(hn)
                run_val = np.where(fx, xr[s:e], hr)
                new_val = np.where(fx, xn[s:e], hn)
                xi_run_g = np.where(run_const[:, :, None],
                                    run_val[:, :, None], xi_run_g)
                xi_new_g = np.where(new_const[:, :, None],
                                    new_val[:, :, None], xi_new_g)
                sh_g, av_g, _t0, _t1, _t2, _t3 = _theorem1(
                    t_run[None, :, None], rem[None, :, None], xi_run_g,
                    t_new_g, it_rows[:, None, None], xi_new_g)
                av_m = np.where(feasible, av_g, np.inf)
                sel = np.where(fx, first_idx, np.argmin(av_m, axis=2))
                sh = np.take_along_axis(sh_g, sel[:, :, None],
                                        axis=2)[:, :, 0]
                av = np.take_along_axis(av_g, sel[:, :, None],
                                        axis=2)[:, :, 0]
            # quench donors with no feasible candidate (scalar sentinel)
            share[s:e] = sh & any_f
            avg[s:e] = np.where(any_f, av, np.inf)
            sub[s:e] = np.take_along_axis(bs_rows, sel, axis=1)
        return share, avg, sub, d_jids, d_singles

    def _start_shared(self, sim, job, want_i: int, share_row, avg_row,
                      sub_row, d_jids) -> None:
        """Place ``job`` on its benefit donors' single-occupancy GPUs —
        the exact placement loop of the scalar path (Algorithm 1 lines
        14-17): donors by pair-JCT ascending (ties by jid), shared GPUs
        first, smallest free ids fill the remainder."""
        cluster = sim.cluster
        jobs_by_id = sim.jobs
        occupancy = cluster.occupancy
        sidx = np.flatnonzero(share_row)
        order = sidx[np.lexsort((d_jids[sidx], avg_row[sidx]))]
        chosen: List[int] = []
        sub_b = job.batch
        for t in order:
            if len(chosen) >= want_i:
                break
            run = jobs_by_id[int(d_jids[t])]
            for gg in sorted(run.placement):
                if len(occupancy[gg]) == 1:
                    chosen.append(gg)
                    if len(chosen) >= want_i:
                        break
            sub_b = min(sub_b, int(sub_row[t]))
        if len(chosen) < want_i:
            chosen.extend(cluster.smallest_free(want_i - len(chosen)))
        sim.start_job(job, chosen[:want_i], sub_batch=sub_b)

    def _schedule_small(self, sim, start_exclusive) -> None:
        """Scalar mirror of the masked-argmin walk for tiny queues: a
        sorted (key, jid) walk visiting each row once is exactly what
        the floor-protected argmin produces, and per-row decisions go
        through the same :meth:`_decide` grid — so the schedules are
        bit-identical while skipping ~10 array ops per placement."""
        engine = sim.engine
        cluster = sim.cluster
        interference = sim.interference
        cap = cluster.gpu_capacity_bytes
        flat = self.flat
        keys = self._keys
        jids = self._jids
        rows = [i for i in range(self._n) if self._alive[i]]
        rows.sort(key=lambda i: (keys[i], jids[i]))
        for i in rows:
            job = self._jobs[i]
            if job is None or job.state is not JobState.PENDING:
                self._kill(i)           # defensive: stale row
                continue
            want_i = int(self._want[i])
            n_free = cluster.n_free
            if want_i <= n_free:
                started = start_exclusive(sim, job)
                assert started
                self._kill(i)
                continue
            n_single = cluster.n_single
            if (not n_single or not flat.d_count
                    or want_i > n_free + n_single):
                continue                 # Line 9 fails: stay pending
            ci = np.array([i], dtype=np.intp)
            if not self._tab[i]:
                self._fill_tables(ci)
            share, avg, sub, d_jids, d_singles = self._decide(
                ci, interference, cap, engine.time)
            share_row = share[0]
            if int((share_row * d_singles).sum()) + n_free < want_i:
                continue                 # SF False / not enough singles
            self._start_shared(sim, job, want_i, share_row, avg[0],
                               sub[0], d_jids)
            self._kill(i)
        self._maybe_compact()

    # -- the pass ------------------------------------------------------ #
    def schedule(self, sim, start_exclusive) -> None:
        engine = sim.engine
        if engine.preemptions_total != self._pstamp:
            self._rebuild(sim)
        else:
            self._ingest(engine)
        if self._n == self._dead:
            return
        if self._n - self._dead <= 8:
            self._schedule_small(sim, start_exclusive)
            return
        cluster = sim.cluster
        interference = sim.interference
        cap = cluster.gpu_capacity_bytes
        flat = self.flat
        floor_key = -np.inf
        floor_jid = -1
        while True:
            n = self._n
            alive = self._alive[:n]
            keys = self._keys[:n]
            jids = self._jids[:n]
            want = self._want[:n]
            beyond = alive & ((keys > floor_key)
                              | ((keys == floor_key) & (jids > floor_jid)))
            if not beyond.any():
                return
            n_free = cluster.n_free
            n_single = cluster.n_single
            actionable = beyond & (want <= n_free)
            grid = None
            cand = None
            if n_single and flat.d_count:
                cand = np.flatnonzero(beyond & (want > n_free)
                                      & (want <= n_free + n_single))
                if cand.size:
                    need = cand[~self._tab[cand]]
                    if need.size:
                        self._fill_tables(need)
                    grid = self._decide(cand, interference, cap,
                                        engine.time)
                    share = grid[0]
                    d_singles = grid[4]
                    gain = (share * d_singles[None, :]).sum(axis=1)
                    ok = gain + n_free >= want[cand]
                    if ok.any():
                        actionable = actionable.copy()
                        actionable[cand[ok]] = True
            idx = np.flatnonzero(actionable)
            if idx.size == 0:
                return
            k = keys[idx]
            m = k.min()
            ties = idx[k == m]
            i = int(ties[np.argmin(jids[ties])]) if ties.size > 1 \
                else int(ties[0])
            job = self._jobs[i]
            if job is None or job.state is not JobState.PENDING:
                self._kill(i)           # defensive: stale row
                continue
            floor_key = float(keys[i])
            floor_jid = int(jids[i])
            if int(want[i]) <= n_free:
                started = start_exclusive(sim, job)
                assert started
            else:
                share, avg, sub, d_jids, _sing = grid
                g = int(np.searchsorted(cand, i))
                self._start_shared(sim, job, int(want[i]), share[g],
                                   avg[g], sub[g], d_jids)
            self._kill(i)
            self._maybe_compact()
