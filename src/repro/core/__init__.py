"""Core reproduction of 'Scheduling Deep Learning Jobs in Multi-Tenant GPU
Clusters via Wise Resource Sharing' (SJF-BSBF)."""
from .batch_scaling import (DonorScaledConfig, SharingConfig,
                            best_sharing_config,
                            best_sharing_config_donor_scaled)
from .calibration import (CALIBRATION_VERSION, MeasuredTaskProfile,
                          load_artifact, perf_params_from_artifact,
                          profiles_from_artifact, run_calibration,
                          save_artifact)
from .faults import FaultModel
from .interference import (InterferenceModel, paper_interference_model,
                           structural_xi)
from .job import ClusterState, Job, JobState
from .pair import PairDecision, PairJob, best_pair_schedule, pair_timeline
try:   # the vectorized decision core needs numpy; scalar core does not
    from .pair_batch import (DonorBatch, DonorDecisions,
                             best_sharing_config_batched,
                             best_sharing_configs, job_candidate_table)
    _PAIR_BATCH_ALL = [
        "DonorBatch", "DonorDecisions", "best_sharing_config_batched",
        "best_sharing_configs", "job_candidate_table",
    ]
except ModuleNotFoundError:   # pragma: no cover - numpy-less env
    _PAIR_BATCH_ALL = []
from .perf_model import (GPU_2080TI, TPU_V5E, HardwareSpec, PerfParams,
                         derive_perf_params, fit_comp_params, infer_xi,
                         ring_allreduce_bytes, t_iter_at_workers)
from .engine import ENGINES, HeapEngine, ScanEngine
from .schedulers import (ALL_POLICIES, FIFO, SJF, SJF_BSBF, SJF_FFS, SRSF,
                         PolluxLike, Tiresias, make_scheduler)
from .simulator import SchedulerBase, SimResults, Simulator
from .sweep import (ScenarioSpec, grid, run_scenario, run_sweep,
                    rows_by_policy, summary_table, write_csv, write_json)
from .tasks import PAPER_TASK_PROFILES, TaskProfile, profile_from_arch
from .trace import (TraceConfig, calibrated_trace, datacenter_trace,
                    generate_trace, philly_trace, physical_trace,
                    simulation_trace)

__all__ = [
    "ALL_POLICIES", "CALIBRATION_VERSION", "ClusterState",
    "DonorScaledConfig",
    "ENGINES", "FIFO", "FaultModel", "GPU_2080TI",
    "HardwareSpec", "HeapEngine", "InterferenceModel", "Job", "JobState",
    "MeasuredTaskProfile", "PAPER_TASK_PROFILES",
    "PairDecision", "PairJob", "PerfParams", "PolluxLike", "SJF", "SJF_BSBF", "SRSF",
    "SJF_FFS", "ScanEngine", "ScenarioSpec", "SchedulerBase",
    "SharingConfig", "SimResults", "Simulator",
    "TPU_V5E", "TaskProfile", "Tiresias", "TraceConfig",
    "best_pair_schedule", "best_sharing_config",
    "best_sharing_config_donor_scaled", "calibrated_trace",
    "datacenter_trace", "derive_perf_params",
    "fit_comp_params", "generate_trace", "grid", "infer_xi",
    "load_artifact", "make_scheduler",
    "pair_timeline", "paper_interference_model",
    "perf_params_from_artifact", "philly_trace", "physical_trace",
    "profile_from_arch", "profiles_from_artifact", "ring_allreduce_bytes",
    "rows_by_policy",
    "run_calibration", "run_scenario", "run_sweep", "save_artifact",
    "simulation_trace", "structural_xi", "summary_table",
    "t_iter_at_workers", "write_csv", "write_json",
] + _PAIR_BATCH_ALL
