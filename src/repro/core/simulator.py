"""Policy-facing facade over the trace-driven discrete-event simulator
(Section VI). Jobs progress in continuous iterations; every event
(arrival, completion, scheduler tick, preemption) re-derives the affected
jobs' effective rate 1 / (t_iter * max xi over co-runners) — gang
scheduling means the slowest (most-contended) GPU paces the whole job.

The event loop itself lives in :mod:`repro.core.engine` (DESIGN.md §9):
``engine="heap"`` (default) is the indexed event-heap engine with
dirty-set interference refresh; ``engine="scan"`` is the pre-refactor
reference loop kept for equivalence tests and the
``benchmarks/sim_throughput.py`` before/after microbench. Schedulers
only ever see this facade: ``pending``/``running``/``time``/``log`` and
the ``start_job``/``preempt_job`` mutations proxy to the active engine.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

from .engine import ENGINES, SimResults, make_engine
from .faults import FaultModel
from .interference import InterferenceModel
from .job import ClusterState, Job

try:   # the vectorized decision core needs numpy
    from . import pair_batch as _pair_batch   # noqa: F401
    HAS_BATCHED_DECISIONS = True
except ModuleNotFoundError:   # pragma: no cover - numpy-less env
    HAS_BATCHED_DECISIONS = False

__all__ = ["SchedulerBase", "SimResults", "Simulator"]


class Simulator:
    def __init__(
        self,
        cluster: ClusterState,
        jobs: Sequence[Job],
        scheduler: "SchedulerBase",
        interference: Optional[InterferenceModel] = None,
        restart_penalty: float = 30.0,
        max_events: int = 2_000_000,
        engine: Optional[str] = None,
        decision: Optional[str] = None,
        reconfig_on_release: bool = False,
        fault_model: Optional["FaultModel"] = None,
    ) -> None:
        self.cluster = cluster
        self.jobs: Dict[int, Job] = {j.jid: j for j in jobs}
        self.arrivals = sorted(jobs, key=lambda j: (j.arrival, j.jid))
        self.scheduler = scheduler
        self.interference = interference or InterferenceModel()
        self.restart_penalty = restart_penalty
        self.max_events = max_events
        # DESIGN.md §16: the fault timeline is precomputed here, from
        # the model's seed alone, so every engine and decision path
        # replays the identical fault sequence.
        self.fault_model = fault_model
        self.fault_events = (
            fault_model.timeline(cluster.n_servers, sorted(self.jobs))
            if fault_model is not None else [])
        # DESIGN.md §13: when a sharer departs, surviving co-tenants are
        # restored to the largest sub-batch that fits again (a mid-run
        # reconfiguration, logged as a "reconfig" event). Default off —
        # the paper's Algorithm 1 never retunes a running job.
        self.reconfig_on_release = reconfig_on_release
        self.engine_name = (engine or os.environ.get("REPRO_SIM_ENGINE")
                            or "heap")
        # sharing-decision path: "grid" (the default — one vectorized
        # pass over all pending jobs x all donors, DESIGN.md §14),
        # "batched" (vectorized Algorithm 2 per pending job), or
        # "scalar" (the per-pair reference). All three produce
        # bit-identical schedules (tests/test_decision_equivalence.py).
        self.decision_path = (decision
                              or os.environ.get("REPRO_SIM_DECISION")
                              or "grid")
        if self.decision_path not in ("grid", "batched", "scalar"):
            raise ValueError(
                f"unknown decision path {self.decision_path!r}; "
                f"choose from ['batched', 'grid', 'scalar']")
        if (self.decision_path in ("grid", "batched")
                and not HAS_BATCHED_DECISIONS):
            # resolve to what will actually run, so sweep rows and bench
            # artifacts never claim a vectorized path for a scalar run
            self.decision_path = "scalar"
        self.engine = make_engine(self.engine_name, self)

    # ------------------------------------------------------------------ #
    # State proxied from the engine (read-side of the scheduler API)
    # ------------------------------------------------------------------ #
    @property
    def time(self) -> float:
        return self.engine.time

    @property
    def pending(self) -> List[Job]:
        return self.engine.pending

    @property
    def running(self) -> Dict[int, Job]:
        return self.engine.running

    @property
    def log(self) -> List[tuple]:
        return self.engine.log

    # ------------------------------------------------------------------ #
    # Scheduler-facing API
    # ------------------------------------------------------------------ #
    def start_job(self, job: Job, gpus: Sequence[int],
                  sub_batch: Optional[int] = None) -> None:
        self.engine.start_job(job, gpus, sub_batch=sub_batch)

    def preempt_job(self, job: Job) -> None:
        self.engine.preempt_job(job)

    def reconfigure_job(self, job: Job, sub_batch: int) -> None:
        self.engine.reconfigure_job(job, sub_batch)

    def fail_job(self, job: Job) -> None:
        """Inject a failure into a running job (DESIGN.md §16): its
        progress truncates to the last checkpoint, it re-queues, and
        surviving sharing peers are rescaled."""
        self.engine.fail_job(job)

    def fail_server(self, sid: int,
                    repair_after: Optional[float] = None) -> bool:
        return self.engine.fail_server(sid, repair_after=repair_after)

    def recover_server(self, sid: int) -> bool:
        return self.engine.recover_server(sid)

    def effective_t_iter(self, job: Job) -> float:
        return self.engine.effective_t_iter(job)

    def remaining_at(self, job: Job) -> float:
        """Remaining iterations of ``job`` at the current event time —
        a virtual read (no progress materialization); see
        :meth:`repro.core.engine.EngineBase.remaining_at`."""
        return self.engine.remaining_at(job)

    def run(self) -> SimResults:
        return self.engine.run()


class SchedulerBase:
    """Interface; implementations in ``repro.core.schedulers``."""

    name: str = "base"
    preemptive: bool = False
    tick_interval: Optional[float] = None
    tick_only: bool = False   # act only on ticks (interval schedulers)
    # Does schedule() read running jobs' progress (iters_done /
    # attained_service / remaining_iters)? Policies that only look at
    # static job fields and the pending queue can set this False so the
    # heap engine skips the per-event accrual sweep (DESIGN.md §9).
    reads_running_progress: bool = True
    # Which running jobs the pre-schedule accrual must cover: "all"
    # (Tiresias/SRSF read every job's attained/remaining service) or
    # "donors" (Algorithm 1 only reads the remaining work of jobs owning
    # single-occupancy GPUs). Progress accrual is order-insensitive, so
    # narrowing the sweep leaves results unchanged (DESIGN.md §10).
    progress_scope: str = "all"

    def reset(self) -> None:
        """Called by the engine when a run starts. Stateful schedulers
        (incremental queues, per-job caches) clear per-run state here so
        one instance can drive several simulations."""

    def schedule(self, sim: Simulator) -> None:  # pragma: no cover
        raise NotImplementedError
