"""Scheduling policies of Section VI-A.

* FIFO           — strict arrival order, exclusive GPUs, head-of-line blocks.
* SJF            — shortest-remaining-solo-time first, exclusive GPUs.
* Tiresias       — preemptive discretized-2Q LAS (attained service =
                   gpus x seconds), restart penalty on resume.
* PolluxLike     — preemptive elastic baseline: periodic marginal-gain GPU
                   reallocation on each job's speedup curve (user batch kept
                   fixed; see DESIGN.md §8).
* SJF-FFS        — SJF + aggressive first-fit GPU sharing (no benefit check).
* SJF-BSBF       — the paper's Algorithm 1 (+ Algorithm 2 / Theorem 1).
"""
from __future__ import annotations

from bisect import insort
from typing import Dict, List, Optional, Tuple

from .batch_scaling import (best_sharing_config,
                            best_sharing_config_donor_scaled,
                            candidate_sub_batches)
from .job import ClusterState, Job, JobState
from .perf_model import t_iter_at_workers
from .simulator import HAS_BATCHED_DECISIONS, SchedulerBase, Simulator

if HAS_BATCHED_DECISIONS:               # vectorized decision core (numpy)
    import numpy as np
    from .pair_batch import DonorBatch, best_sharing_configs
    from .pass_batch import GridPass


# ---------------------------------------------------------------------- #
# helpers
# ---------------------------------------------------------------------- #
def solo_sub_batch(job: Job, capacity: float) -> Optional[int]:
    """Largest candidate sub-batch that fits device memory alone
    (gradient accumulation supplies the rest). Memoized per (job,
    capacity): ``_start_exclusive`` re-asks for the same head-of-line
    job on every scheduling pass."""
    memo = job._solo_sub_memo
    try:
        return memo[capacity]
    except KeyError:
        pass
    sub = None
    for b in candidate_sub_batches(job.batch):
        if job.perf.fits(b, capacity):
            sub = b
            break
    memo[capacity] = sub
    return sub


def shared_sub_batch(job: Job, capacity: float, other_mem: float) -> Optional[int]:
    for b in candidate_sub_batches(job.batch):
        if job.perf.fits(b, capacity, other_mem=other_mem):
            return b
    return None


class _StaticOrder:
    """Incrementally maintained sorted view over the scheduler's job
    queue, for policies whose per-job sort key is *static* while the job
    sits in that queue (non-preemptive SJF variants: a pending job's
    remaining work is frozen; Tiresias/FIFO arrival order never
    changes). Jobs are inserted once with their key via ``bisect``;
    departed jobs are skipped lazily and only *terminal* (finished)
    entries are compacted away. Produces exactly the order
    ``sorted(queue, key)`` would (keys are tie-broken by jid, so
    comparison never reaches the Job object).

    A job re-entering the queue after a preemption may carry a changed
    key; each entry therefore remembers the job's preemption count and
    the view re-keys itself when they disagree. Policies whose key
    cannot change across requeues (arrival order) pass
    ``requeue_safe=True`` to skip that check.

    ``terminal_states`` controls which entries compaction may drop.
    Policies that only ever order the *pending* queue can include
    ``RUNNING`` (the entry is removed from tracking, so a preempted job
    re-enters via ``insort`` with a fresh key) — at datacenter scale the
    running population dwarfs the queue, and keeping those entries makes
    every ``order()`` call O(running)."""

    def __init__(self, key_fn, live_states=(JobState.PENDING,),
                 requeue_safe=False,
                 terminal_states=(JobState.FINISHED,)):
        self._key_fn = key_fn
        self._live = live_states
        self._requeue_safe = requeue_safe
        self._terminal = terminal_states
        self._entries: List[tuple] = []   # (key, jid, job, preemptions)
        self._tracked: set = set()
        self._compact_backoff = 0   # calls to skip after a no-op compaction

    def reset(self) -> None:
        self._entries.clear()
        self._tracked.clear()
        self._compact_backoff = 0

    def _rekey(self) -> List[tuple]:
        key_fn = self._key_fn
        terminal = self._terminal
        alive = [e[2] for e in self._entries
                 if e[2].state not in terminal]
        self._entries = sorted(
            (key_fn(j), j.jid, j, j.preemptions) for j in alive)
        self._tracked = {j.jid for j in alive}
        return self._entries

    def order(self, *queues) -> List[Job]:
        entries, tracked, key_fn = self._entries, self._tracked, self._key_fn
        if not entries and not any(queues):
            return []   # idle pass (most events at datacenter scale)
        for queue in queues:
            for job in queue:
                jid = job.jid
                if jid not in tracked:
                    tracked.add(jid)
                    insort(entries, (key_fn(job), jid, job,
                                     job.preemptions))
        live = self._live
        if self._requeue_safe:
            out = [e[2] for e in entries if e[2].state in live]
        else:
            out = []
            for e in entries:
                job = e[2]
                if job.state in live:
                    if job.preemptions != e[3]:
                        # re-queued since insertion: key may be stale
                        entries = self._rekey()
                        out = [e[2] for e in entries
                               if e[2].state in live]
                        break
                    out.append(job)
        if 2 * len(out) < len(entries):
            if self._compact_backoff > 0:
                self._compact_backoff -= 1
            else:
                terminal = self._terminal
                keep = [e for e in entries
                        if e[2].state not in terminal]
                if len(keep) < len(entries):
                    self._entries = keep
                    self._tracked = {e[1] for e in keep}
                else:
                    # nothing terminal to drop (entries are mostly
                    # RUNNING); back off so the no-op rescan amortizes
                    # to O(1) per call instead of O(entries)
                    self._compact_backoff = max(8, len(entries) >> 3)
        return out


def _start_exclusive(sim: Simulator, job: Job) -> bool:
    cluster = sim.cluster
    want = job.alloc_gpus or job.gpus
    if cluster.n_free < want:
        return False
    sub = solo_sub_batch(job, cluster.gpu_capacity_bytes)
    if sub is None:
        raise RuntimeError(f"job {job.jid} cannot fit memory even at b=1")
    gpus = cluster.consolidated_pick_free(want)
    sim.start_job(job, gpus, sub_batch=sub)
    return True


# ---------------------------------------------------------------------- #
class FIFO(SchedulerBase):
    name = "fifo"
    reads_running_progress = False

    def schedule(self, sim: Simulator) -> None:
        # pending is already in (arrival, jid) order: arrivals append in
        # that order and nothing re-enters the queue
        for job in list(sim.pending):
            if not _start_exclusive(sim, job):
                break  # strict FIFO: head-of-line blocks the queue


class SJF(SchedulerBase):
    """Shortest-job-first, exclusive GPUs, strict priority order: if the
    currently-shortest job cannot be placed, later jobs wait (no backfill —
    matching the queueing structure the paper reports for SJF)."""

    name = "sjf"
    reads_running_progress = False

    def __init__(self) -> None:
        # orders only the pending queue, so started jobs are compactable
        self._order = _StaticOrder(
            lambda j: j.expected_remaining_time,
            terminal_states=(JobState.RUNNING, JobState.FINISHED))

    def reset(self) -> None:
        self._order.reset()

    def schedule(self, sim: Simulator) -> None:
        # every PENDING job is in sim.pending, so an empty queue means
        # nothing to place (most finish events at datacenter scale)
        if not sim.pending:
            return
        for job in self._order.order(sim.pending):
            if not _start_exclusive(sim, job):
                break


# ---------------------------------------------------------------------- #
class Tiresias(SchedulerBase):
    """Discretized two-queue least-attained-service, preemptive."""

    name = "tiresias"
    preemptive = True

    def __init__(self, threshold_gpu_seconds: float = 3600.0,
                 tick_interval: float = 60.0) -> None:
        self.threshold = threshold_gpu_seconds
        self.tick_interval = tick_interval
        self._active = _StaticOrder(
            lambda j: (j.arrival, j.jid),
            live_states=(JobState.PENDING, JobState.RUNNING),
            requeue_safe=True)   # arrival order survives preemption

    def reset(self) -> None:
        self._active.reset()

    def schedule(self, sim: Simulator) -> None:
        # every job enters via the pending queue, so tracking it is
        # enough to enumerate all active jobs in (arrival, jid) order
        active = self._active.order(sim.pending)
        if not active:
            return
        # == sorted(active, key=(queue, arrival, jid)): the threshold
        # partition preserves the static arrival order within each queue
        thr = self.threshold
        order = ([j for j in active if j.attained_service < thr]
                 + [j for j in active if j.attained_service >= thr])
        total = sim.cluster.n_gpus
        chosen: List[Job] = []
        cap = total
        for j in order:
            if j.gpus <= cap:
                chosen.append(j)
                cap -= j.gpus
        chosen_ids = {j.jid for j in chosen}
        for j in list(sim.running.values()):
            if j.jid not in chosen_ids:
                sim.preempt_job(j)
        for j in chosen:
            if j.state == JobState.PENDING:
                _start_exclusive(sim, j)


# ---------------------------------------------------------------------- #
class SRSF(SchedulerBase):
    """Clairvoyant shortest-remaining-service-first (the policy Tiresias
    approximates without duration knowledge; Tiresias paper shows SRSF is
    near-optimal when durations are known). Preemptive: whenever a job
    with smaller remaining service (gpus x remaining seconds) arrives, it
    may evict enough larger jobs to run."""

    name = "srsf"
    preemptive = True

    def schedule(self, sim: Simulator) -> None:
        active: List[Job] = list(sim.running.values()) + list(sim.pending)
        if not active:
            return
        service = lambda j: j.gpus * j.expected_remaining_time
        order = sorted(active, key=lambda j: (service(j), j.jid))
        cap = sim.cluster.n_gpus
        chosen: List[Job] = []
        for j in order:
            if j.gpus <= cap:
                chosen.append(j)
                cap -= j.gpus
        chosen_ids = {j.jid for j in chosen}
        for j in list(sim.running.values()):
            if j.jid not in chosen_ids:
                sim.preempt_job(j)
        for j in chosen:
            if j.state == JobState.PENDING:
                _start_exclusive(sim, j)


# ---------------------------------------------------------------------- #
class PolluxLike(SchedulerBase):
    """Elastic preemptive baseline: every tick, reassign GPU counts by
    greedy marginal goodput gain, capped at each job's requested G_k
    (the real Pollux can also overshoot and retune batch size; we keep the
    user batch to mirror the accuracy-preserving comparison in the paper)."""

    name = "pollux"
    preemptive = True
    tick_only = True   # real Pollux acts on a fixed optimization interval
    reads_running_progress = False   # _rate() uses static perf fields only

    def __init__(self, tick_interval: float = 60.0,
                 min_gpus: int = 1) -> None:
        self.tick_interval = tick_interval
        self.min_gpus = min_gpus
        self._rate_cache: Dict[Tuple[int, int, int], float] = {}
        self._levels_cache: Dict[int, List[int]] = {}
        # (jid, accum_steps, cur_level) -> (marginal gain, next level),
        # or None when the job is already at its top level
        self._gain_cache: Dict[Tuple[int, int, int], object] = {}

    def reset(self) -> None:
        self._rate_cache.clear()   # jids are only unique within one run
        self._gain_cache.clear()

    def _rate(self, job: Job, n: int) -> float:
        """User-iterations/sec at allocation n (weak scaling). Memoized:
        the greedy upgrade loop re-evaluates the same (job, n) points
        thousands of times per tick on large traces."""
        key = (job.jid, job.accum_steps, n)
        cached = self._rate_cache.get(key)
        if cached is not None:
            return cached
        if n <= 0:
            val = 0.0
        else:
            t_phys = t_iter_at_workers(job.perf, job.batch,
                                       job.accum_steps, n)
            val = (n / job.gpus) / t_phys
        self._rate_cache[key] = val
        return val

    def _levels(self, job: Job) -> List[int]:
        levels = self._levels_cache.get(job.gpus)
        if levels is None:
            levels = [n for n in (1, 2, 4, 8, 12, 16, 24, 32)
                      if n <= job.gpus] or [job.gpus]
            self._levels_cache[job.gpus] = levels
        return levels

    def _gain(self, job: Job, cur: int):
        """(marginal goodput gain, next level) above ``cur`` — pure in
        (job, accum_steps, cur), so cached across upgrade rounds and
        ticks; None when no higher level exists."""
        key = (job.jid, job.accum_steps, cur)
        try:
            return self._gain_cache[key]
        except KeyError:
            pass
        nxt = None
        for n in self._levels(job):
            if n > cur:
                nxt = n
                break
        if nxt is None:
            val = None
        else:
            val = ((self._rate(job, nxt) - self._rate(job, cur))
                   / (nxt - cur), nxt)
        self._gain_cache[key] = val
        return val

    def schedule(self, sim: Simulator) -> None:
        active: List[Job] = list(sim.running.values()) + list(sim.pending)
        if not active:
            return
        total = sim.cluster.n_gpus
        # Fair-share allocation in powers of two up to G_k (Pollux optimizes
        # goodput *subject to fairness*; fair shares, then goodput-aware
        # upgrades for whoever is furthest below its request).
        alloc: Dict[int, int] = {j.jid: 0 for j in active}
        levels = self._levels
        budget = total
        order = sorted(active, key=lambda j: (j.arrival, j.jid))
        for j in order:
            first = levels(j)[0]
            if budget >= first:
                alloc[j.jid] = first
                budget -= first
        # Greedy upgrades: furthest below fair share first; break ties by
        # marginal rate, then jid (same selection as sorting all
        # candidates and taking the head). A job whose next level does
        # not exist or exceeds the remaining budget can never become
        # upgradeable again this tick (budget only shrinks and its
        # allocation is frozen until upgraded), so it is pruned from the
        # scan instead of being re-evaluated every round.
        gain_of = self._gain
        live = [j for j in active if alloc[j.jid] > 0]
        while budget > 0 and live:
            best = None
            still = []
            for j in live:
                cur = alloc[j.jid]
                g = gain_of(j, cur)
                if g is None or g[1] - cur > budget:
                    continue
                still.append(j)
                key = (cur / j.gpus, -g[0], j.jid)
                if best is None or key < best[0]:
                    best = (key, j, g[1])
            live = still
            if best is None:
                break
            _, j, nxt = best
            budget -= nxt - alloc[j.jid]
            alloc[j.jid] = nxt

        # Apply: preempt mismatched running jobs, then start.
        for j in list(sim.running.values()):
            if alloc.get(j.jid, 0) != (j.alloc_gpus or j.gpus):
                sim.preempt_job(j)
        for j in sorted(sim.pending, key=lambda x: (x.arrival, x.jid)):
            n = alloc.get(j.jid, 0)
            if n <= 0:
                continue
            if sim.cluster.n_free < n:
                continue
            j.alloc_gpus = n
            sub = solo_sub_batch(j, sim.cluster.gpu_capacity_bytes)
            gpus = sim.cluster.consolidated_pick_free(n)
            sim.start_job(j, gpus, sub_batch=sub)


# ---------------------------------------------------------------------- #
class SJF_FFS(SchedulerBase):
    """SJF + first-fit sharing: when free GPUs are insufficient, greedily
    take single-occupancy GPUs (no Theorem-1 benefit check) — the paper's
    comparison baseline showing that *wise* sharing matters."""

    name = "sjf-ffs"
    reads_running_progress = False   # pairs on static mem/perf fields only

    def __init__(self) -> None:
        self._order = _StaticOrder(
            lambda j: j.expected_remaining_time,
            terminal_states=(JobState.RUNNING, JobState.FINISHED))

    def reset(self) -> None:
        self._order.reset()

    def schedule(self, sim: Simulator) -> None:
        if not sim.pending:   # nothing to place (see SJF.schedule)
            return
        cap = sim.cluster.gpu_capacity_bytes
        for job in self._order.order(sim.pending):
            if _start_exclusive(sim, job):
                continue
            free = sim.cluster.free_gpus()
            singles = sim.cluster.single_occupancy_gpus()
            if len(free) + len(singles) < job.gpus:
                continue
            # first fit: free GPUs first, then single-occupancy in id order
            chosen = list(free)
            max_other_mem = 0.0
            for g in singles:
                if len(chosen) >= job.gpus:
                    break
                other = sim.jobs[sim.cluster.occupancy[g][0]]
                max_other_mem = max(
                    max_other_mem, other.perf.mem_bytes(other.sub_batch))
                chosen.append(g)
            if len(chosen) < job.gpus:
                continue
            chosen = chosen[:job.gpus]
            sub = shared_sub_batch(job, cap, max_other_mem)
            if sub is None:
                continue  # does not fit next to the co-runners
            sim.start_job(job, chosen, sub_batch=sub)


# ---------------------------------------------------------------------- #
class SJF_BSBF(SchedulerBase):
    """Algorithm 1 — Shortest Job First with Best Sharing Benefit First.

    Three decision paths with identical outcomes (pinned by
    ``tests/test_decision_equivalence.py`` and the differential fuzz
    harness in ``tests/test_engine_equivalence.py``):

    * ``grid`` (default) — one vectorized pass over the whole pending
      queue (:class:`repro.core.pass_batch.GridPass`): Algorithm 2 /
      Theorem 1 evaluated for all pending jobs x all donors in one
      NumPy grid over flat preallocated tables, placements walked with
      a masked ``(key, jid)`` argmin (DESIGN.md §14).
    * ``batched`` — one :func:`repro.core.pair_batch.
      best_sharing_configs` call per pending job evaluates Algorithm 2
      against every donor as NumPy array ops; the donor batch is reused
      across the pending queue until a placement changes the donor set.
    * ``scalar`` — the original per-(pending, donor)
      :func:`best_sharing_config` loop, kept as the reference.

    The path comes from the constructor, else the Simulator's
    ``decision_path`` (``REPRO_SIM_DECISION`` env, default grid).
    All paths read donor progress *virtually* via
    ``Simulator.remaining_at`` (no pre-pass accrual sweep), hence
    ``reads_running_progress = False``.

    ``donor_reconfig=True`` enables the Algorithm-2 extension of
    DESIGN.md §13: when no donor admits the new job at its current
    footprint, the donor's own sub-batch is swept down too
    (:func:`repro.core.batch_scaling.best_sharing_config_donor_scaled`)
    and, when the benefit survives the donor's slowdown, the donor is
    reconfigured mid-run via ``Simulator.reconfigure_job`` at the
    sharing time point. Forces the scalar decision path; default off —
    the paper's Algorithm 1 never retunes a running job.
    """

    name = "sjf-bsbf"
    # donor remaining work is read virtually (Simulator.remaining_at),
    # so the engine's pre-schedule accrual sweep is skipped entirely
    reads_running_progress = False
    progress_scope = "donors"   # schedule() only reads donors' progress

    def __init__(self, decision: Optional[str] = None,
                 donor_reconfig: bool = False) -> None:
        self._order = _StaticOrder(
            lambda j: j.expected_remaining_time,
            terminal_states=(JobState.RUNNING, JobState.FINISHED))
        if decision not in (None, "grid", "batched", "scalar"):
            raise ValueError(
                f"unknown decision path {decision!r}; "
                f"choose from ['batched', 'grid', 'scalar']")
        if decision in ("grid", "batched") and not HAS_BATCHED_DECISIONS:
            raise ValueError(
                f"decision={decision!r} requires numpy "
                f"(repro.core.pair_batch)")
        self.donor_reconfig = donor_reconfig
        if donor_reconfig and decision is None:
            decision = "scalar"   # extension lives on the scalar path
        if donor_reconfig and decision in ("grid", "batched"):
            raise ValueError("donor_reconfig requires decision='scalar'")
        self.decision = decision
        # (cluster version, DonorBatch): donor membership / memory /
        # iteration times only change with placements, so the batch (and
        # its per-model xi cache) survives across scheduling passes
        self._donor_cache: Optional[tuple] = None
        self._grid: Optional[object] = None   # per-sim GridPass

    def reset(self) -> None:
        self._order.reset()
        self._donor_cache = None
        self._grid = None

    def schedule(self, sim: Simulator) -> None:
        # every PENDING job is in sim.pending, so an empty queue means
        # nothing to place (most finish events at datacenter scale);
        # arrivals never skip the queue, so no ingest can be missed
        if not sim.pending:
            return
        # sim.decision_path is already availability-resolved; a bare sim
        # without the attribute falls back to whatever can actually run
        path = self.decision or getattr(
            sim, "decision_path",
            "grid" if HAS_BATCHED_DECISIONS else "scalar")
        if path == "grid":
            self._schedule_grid(sim)
        elif path == "batched":
            self._schedule_batched(sim)
        else:
            self._schedule_scalar(sim)

    # -- vectorized whole-pass path (DESIGN.md §14) --------------------- #
    def _schedule_grid(self, sim: Simulator) -> None:
        state = self._grid
        if state is None or state.sim is not sim:
            state = self._grid = GridPass(sim)
        state.schedule(sim, _start_exclusive)

    # -- batched decision path ----------------------------------------- #
    def _schedule_batched(self, sim: Simulator) -> None:
        cluster = sim.cluster
        cap = cluster.gpu_capacity_bytes
        jobs = sim.jobs
        occupancy = cluster.occupancy
        # virtual read of donor remaining work — no accrual sweep needed
        rem_of = getattr(sim, "remaining_at", None)
        donor_batch = None   # rebuilt after any placement changes donors
        for job in self._order.order(sim.pending):
            # Lines 6-8: enough free GPUs -> exclusive consolidated pick.
            if _start_exclusive(sim, job):
                donor_batch = None
                continue
            free = cluster.free_gpus()
            if len(free) + cluster.n_single < job.gpus:
                continue  # Line 9 fails: stay pending
            # Lines 10-13: Algorithm 2 against every donor in one shot.
            if donor_batch is None:
                cached = self._donor_cache
                if cached is not None and cached[0] == cluster.version:
                    donor_batch = cached[1]
                    donor_batch.refresh_progress(rem_of)
                else:
                    donor_batch = DonorBatch(
                        [jobs[j] for j in sorted(cluster.donor_jids())],
                        rem_fn=rem_of)
                    self._donor_cache = (cluster.version, donor_batch)
            res = best_sharing_configs(job, donor_batch,
                                       sim.interference, cap)
            idx = np.flatnonzero(res.share)
            if idx.size == 0:
                continue  # SF False for all pairs: defer (stay in pool)
            # Line 14: donors by pair-JCT ascending, ties by jid (the
            # scalar sort key).
            order = idx[np.lexsort((donor_batch.jids[idx],
                                    res.avg_jct[idx]))]
            # Lines 15-17: take donors' GPUs until the request is met
            # (shared GPUs first — they pace the job — then free ones).
            chosen: List[int] = []
            sub = job.batch
            for i in order:
                if len(chosen) >= job.gpus:
                    break
                run = donor_batch.donors[i]
                for g in sorted(run.placement):
                    if len(occupancy[g]) == 1:
                        chosen.append(g)
                        if len(chosen) >= job.gpus:
                            break
                sub = min(sub, int(res.sub_batch[i]))
            if len(chosen) < job.gpus:
                chosen.extend(free[: job.gpus - len(chosen)])
            if len(chosen) < job.gpus:
                continue
            chosen = chosen[:job.gpus]
            sim.start_job(job, chosen, sub_batch=sub)
            donor_batch = None

    # -- scalar reference path ----------------------------------------- #
    def _schedule_scalar(self, sim: Simulator) -> None:
        cap = sim.cluster.gpu_capacity_bytes
        # virtual read of donor remaining work — no accrual sweep needed
        rem_of = getattr(sim, "remaining_at", None)
        for job in self._order.order(sim.pending):
            # Lines 6-8: enough free GPUs -> exclusive consolidated pick.
            if _start_exclusive(sim, job):
                continue
            free = sim.cluster.free_gpus()
            singles = sim.cluster.single_occupancy_gpus()
            if len(free) + len(singles) < job.gpus:
                continue  # Line 9 fails: stay pending
            # Lines 10-13: evaluate every running job owning single-occupancy
            # GPUs with Algorithm 2; keep those with sharing benefit.
            donor_jids = {sim.cluster.occupancy[g][0] for g in singles}
            donors = []
            blocked = []   # donors with NO memory-feasible sub-batch
            for jid in donor_jids:
                run = sim.jobs[jid]
                cfg = best_sharing_config(
                    run, job, sim.interference, cap,
                    rem_run=(rem_of(run) if rem_of is not None else None))
                if cfg.share:
                    donors.append((cfg, run))
                elif cfg.decision is None:
                    blocked.append(jid)
            if not donors and blocked and self.donor_reconfig:
                # only memory-blocked donors are worth the double sweep:
                # a donor that already fit but lost Theorem 1 can only
                # get slower by shrinking its own sub-batch
                if self._share_with_donor_reconfig(sim, job, blocked,
                                                   cap, free):
                    continue
            if not donors:
                continue  # SF False for all pairs: defer (put back in pool)
            # Line 14: sort candidate pairs by pair-JCT ascending.
            donors.sort(key=lambda t: (t[0].avg_jct, t[1].jid))
            # Lines 15-17: take donors' GPUs until the request is met
            # (shared GPUs first — they pace the job — then free ones).
            chosen: List[int] = []
            sub = job.batch
            for cfg, run in donors:
                if len(chosen) >= job.gpus:
                    break
                for g in sorted(run.placement):
                    if len(sim.cluster.occupancy[g]) == 1:
                        chosen.append(g)
                        if len(chosen) >= job.gpus:
                            break
                sub = min(sub, cfg.sub_batch)
            if len(chosen) < job.gpus:
                chosen.extend(free[: job.gpus - len(chosen)])
            if len(chosen) < job.gpus:
                continue
            chosen = chosen[:job.gpus]
            sim.start_job(job, chosen, sub_batch=sub)

    # -- donor-rescaling extension (DESIGN.md §13) ---------------------- #
    def _share_with_donor_reconfig(self, sim: Simulator, job: Job,
                                   donor_jids, cap: float,
                                   free: List[int]) -> bool:
        """No donor admits ``job`` at its current footprint: retry each
        donor with its own sub-batch swept down, pick the best benefit,
        place the new job on that donor's single-occupancy GPUs (plus
        free ones) and reconfigure the donor mid-run. Single-donor only:
        a request spanning several reconfigured donors is deferred."""
        best = None
        rem_of = getattr(sim, "remaining_at", None)
        for jid in sorted(donor_jids):
            run = sim.jobs[jid]
            cfg = best_sharing_config_donor_scaled(
                run, job, sim.interference, cap,
                rem_run=(rem_of(run) if rem_of is not None else None))
            if cfg.share and (best is None or cfg.avg_jct < best[0].avg_jct):
                best = (cfg, run)
        if best is None:
            return False
        cfg, run = best
        chosen = [g for g in sorted(run.placement)
                  if len(sim.cluster.occupancy[g]) == 1][:job.gpus]
        if len(chosen) < job.gpus:
            chosen.extend(free[: job.gpus - len(chosen)])
        if len(chosen) < job.gpus:
            return False
        sim.reconfigure_job(run, cfg.donor_sub_batch)
        sim.start_job(job, chosen[:job.gpus], sub_batch=cfg.sub_batch)
        return True


ALL_POLICIES = {
    "fifo": FIFO,
    "sjf": SJF,
    "srsf": SRSF,
    "tiresias": Tiresias,
    "pollux": PolluxLike,
    "sjf-ffs": SJF_FFS,
    "sjf-bsbf": SJF_BSBF,
}


def make_scheduler(name: str, **kwargs) -> SchedulerBase:
    try:
        return ALL_POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; "
                         f"choose from {sorted(ALL_POLICIES)}") from None
