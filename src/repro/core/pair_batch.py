"""Vectorized sharing-decision core — batched Algorithm 2 + Theorem 1.

``repro.core.batch_scaling.best_sharing_config`` evaluates one
(pending, donor) pair at a time: a Python sweep over candidate
sub-batches with a scalar Theorem-1 timeline per candidate. Algorithm 1
calls it once per donor per pending job per scheduling pass, which makes
the *decision layer* the dominant cost at datacenter trace sizes now
that the event loop itself is heap-indexed (DESIGN.md §9-§10).

This module evaluates one pending job against *all* donors at once:

* per-job candidate tables — sub-batches, accumulation counts,
  iteration times, and memory footprints over the Algorithm-2 candidate
  list — are precomputed once and cached on the :class:`Job`
  (:func:`job_candidate_table`);
* per-donor scalars (memory, solo iteration time, remaining work) are
  packed into a :class:`DonorBatch`, built once per scheduling pass and
  reused across the pending queue until a placement changes the donor
  set;
* the memory-feasibility mask, both Theorem-1 endpoints (the kappa=0
  ``pair_timeline`` and the sequential closed form), and the per-donor
  argmin run as NumPy array ops over the (donor × candidate) grid.

The arithmetic mirrors the scalar reference expression-for-expression
(same IEEE-754 operation order), so decisions and pair-JCT values are
bitwise identical, not merely close — ``tests/test_pair_batch.py``
asserts the per-pair equivalence and
``tests/test_decision_equivalence.py`` pins full-trace summaries for
every policy under both decision paths.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .batch_scaling import SharingConfig, candidate_sub_batches
from .interference import InterferenceModel
from .job import Job
from .pair import PairDecision

__all__ = [
    "DonorBatch", "DonorDecisions", "best_sharing_config_batched",
    "best_sharing_configs", "job_candidate_table",
]


def job_candidate_table(job: Job) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]:
    """``(sub_batches, accum_steps, t_iter, mem_bytes)`` arrays over the
    Algorithm-2 candidate list of ``job``, cached on the job (the table
    is a pure function of its batch and perf params). Iteration times
    come from the job's scalar memo so both decision paths share the
    exact same floats."""
    tab = job._pair_table
    if tab is None:
        bs = candidate_sub_batches(job.batch)
        ss = [max(1, math.ceil(job.batch / b)) for b in bs]
        tab = (
            np.array(bs, dtype=np.int64),
            np.array(ss, dtype=np.int64),
            np.array([job.t_iter_sub(b) for b in bs], dtype=np.float64),
            np.array([job.perf.mem_bytes(b) for b in bs], dtype=np.float64),
        )
        job._pair_table = tab
    return tab


class DonorBatch:
    """Array view over a set of donor (running) jobs: memory footprint at
    the current sub-batch, solo iteration time, and remaining
    iterations. Built once per scheduling pass; per-(new-model) xi terms
    are cached on the batch because every pending job of the same model
    sees the same donor-side interference constants."""

    __slots__ = ("donors", "jids", "run_mem", "t_run", "rem_run",
                 "_models", "_codes", "_xi_cache")

    def __init__(self, donors: Sequence[Job], rem_fn=None) -> None:
        self.donors: List[Job] = list(donors)
        jids = []
        run_mem = []
        t_run = []
        rem_run = []
        model_index: dict = {}
        codes = []
        for d in self.donors:
            jids.append(d.jid)
            run_mem.append(d.perf.mem_bytes(d.sub_batch))
            t_run.append(d.solo_t_iter)
            rem_run.append(d.remaining_iters if rem_fn is None
                           else rem_fn(d))
            code = model_index.get(d.model)
            if code is None:
                code = model_index.setdefault(d.model, len(model_index))
            codes.append(code)
        self.jids = np.array(jids, dtype=np.int64)
        self.run_mem = np.array(run_mem, dtype=np.float64)
        self.t_run = np.array(t_run, dtype=np.float64)
        self.rem_run = np.array(rem_run, dtype=np.float64)
        self._models = list(model_index)      # code -> model name
        self._codes = np.array(codes, dtype=np.intp)
        self._xi_cache: dict = {}

    def __len__(self) -> int:
        return len(self.donors)

    def refresh_progress(self, rem_fn=None) -> None:
        """Re-read the donors' remaining iterations (the only per-pass
        mutable column — membership, memory, and iteration times only
        change with placements, which invalidate the whole batch).
        ``rem_fn`` reads a donor's remaining work virtually (e.g.
        ``Simulator.remaining_at``); default is the materialized
        ``remaining_iters``."""
        rem = self.rem_run
        if rem_fn is None:
            for i, d in enumerate(self.donors):
                rem[i] = d.remaining_iters
        else:
            for i, d in enumerate(self.donors):
                rem[i] = rem_fn(d)

    def xi_terms(self, new_model: str, interference: InterferenceModel):
        """Per-donor interference constants against ``new_model``:
        ``(fixed_mask, xi_run_fixed, xi_new_fixed, hit_run, hit_new)``.
        ``fixed_mask`` marks donors whose xi is sub-batch independent
        (global override or two-way pair-table hit — the scalar sweep
        breaks after the first feasible candidate for those);
        ``hit_run``/``hit_new`` carry one-way table hits (NaN where the
        structural model applies). xi depends only on the *model* pair,
        so the lookups run once per distinct donor model and fan out to
        donors through the model-code gather."""
        cached = self._xi_cache.get(new_model)
        if cached is not None:
            return cached
        k = len(self._models)
        fixed_u = np.zeros(k, dtype=bool)
        xi_run_u = np.ones(k, dtype=np.float64)
        xi_new_u = np.ones(k, dtype=np.float64)
        hit_run_u = np.full(k, np.nan, dtype=np.float64)
        hit_new_u = np.full(k, np.nan, dtype=np.float64)
        table = interference.table
        for code, model in enumerate(self._models):
            fixed = interference.pair_fixed(model, new_model)
            if fixed is not None:
                fixed_u[code] = True
                xi_run_u[code], xi_new_u[code] = fixed
                continue
            hr = table.get((model, new_model))
            if hr is not None:
                hit_run_u[code] = hr[0]
            hn = table.get((new_model, model))
            if hn is not None:
                hit_new_u[code] = hn[0]
        codes = self._codes
        cached = (fixed_u[codes], xi_run_u[codes], xi_new_u[codes],
                  hit_run_u[codes], hit_new_u[codes])
        self._xi_cache[new_model] = cached
        return cached


@dataclass
class DonorDecisions:
    """Per-donor Algorithm-2 outcomes for one pending job, as arrays.
    Row ``i`` corresponds to ``donors[i]``; rows with ``feasible[i]``
    False had no memory-feasible sub-batch (the scalar path's
    cannot-share sentinel). The Theorem-1 endpoint timelines are kept
    raw (``t_*0`` kappa=0, ``t_*1`` sequential); :meth:`config`
    materializes the chosen endpoint lazily — the scheduler hot path
    only reads ``share``/``avg_jct``/``sub_batch``."""

    donors: List[Job]
    new_batch: int
    feasible: np.ndarray     # bool[D] — any candidate fits beside donor
    share: np.ndarray        # bool[D] — Theorem-1 SF flag
    sub_batch: np.ndarray    # int[D]
    accum_steps: np.ndarray  # int[D]
    avg_jct: np.ndarray      # float[D] — pair-average JCT t_bar
    t_a0: np.ndarray         # float[D] — kappa=0 endpoint timelines
    t_b0: np.ndarray
    t_a1: np.ndarray         # float[D] — sequential endpoint timelines
    t_b1: np.ndarray
    xi_run: np.ndarray       # float[D]
    xi_new: np.ndarray       # float[D]

    def config(self, i: int) -> SharingConfig:
        """Materialize row ``i`` as the scalar API's SharingConfig."""
        if not self.feasible[i]:
            return SharingConfig(False, self.new_batch, 1, float("inf"), None)
        share = bool(self.share[i])
        avg = float(self.avg_jct[i])
        if share:
            dec = PairDecision(True, 0.0, float(self.t_a0[i]),
                               float(self.t_b0[i]), avg)
        else:
            dec = PairDecision(False, float(self.t_a1[i]),
                               float(self.t_a1[i]), float(self.t_b1[i]), avg)
        return SharingConfig(
            share=share, sub_batch=int(self.sub_batch[i]),
            accum_steps=int(self.accum_steps[i]), avg_jct=avg, decision=dec,
            xi_new=float(self.xi_new[i]), xi_run=float(self.xi_run[i]))


# ---------------------------------------------------------------------- #
def _theorem1(t_run, rem_run, xi_run, t_new, iters_new, xi_new):
    """Both Theorem-1 endpoints as array ops; mirrors
    ``pair.pair_timeline(a, b, 0)`` / ``pair.best_pair_schedule``
    expression-for-expression. Returns ``(share, avg, t_a0, t_b0, t_a1,
    t_b1)`` — the raw endpoint timelines, with ``share``/``avg`` already
    resolved per lane."""
    solo_a = t_run * rem_run
    solo_b = t_new * iters_new
    ta_sh = t_run * xi_run
    tb_sh = t_new * xi_new
    fin_a = rem_run * ta_sh
    fin_b = iters_new * tb_sh
    with np.errstate(divide="ignore", invalid="ignore"):
        # A finishes first: B continues solo with its remaining work.
        t_b_afirst = fin_a + (iters_new - fin_a / tb_sh) * t_new
        # B finishes first: A continues solo.
        t_a_bfirst = fin_b + (rem_run - fin_b / ta_sh) * t_run
    a_first = fin_a <= fin_b
    t_a0 = np.where(a_first, fin_a, t_a_bfirst)
    t_b0 = np.where(a_first, t_b_afirst, fin_b)
    # sequential endpoint, closed form
    t_a1 = solo_a
    t_b1 = solo_a + solo_b
    # kappa=0 >= solo_a (running job already out of work) degenerates to
    # the sequential timeline — same guard as the scalar pair_timeline.
    degen = solo_a <= 0.0
    if degen.any():
        t_a0 = np.where(degen, solo_a, t_a0)
        t_b0 = np.where(degen, t_b1, t_b0)
    avg0 = 0.5 * (t_a0 + t_b0)
    avg1 = 0.5 * (t_a1 + t_b1)
    share = avg0 <= avg1
    avg = np.where(share, avg0, avg1)
    return share, avg, t_a0, t_b0, t_a1, t_b1


def _structural_xi(interference, t_me, t_other, mem_frac):
    """Vectorized mirror of :func:`repro.core.interference.structural_xi`
    at the scheduler's parameterization (contention coefficient, ratio
    capped at 4) — kept as array ops so the donor grid stays NumPy;
    the scalar function is the semantic source of truth."""
    ratio = t_other / np.maximum(t_me, 1e-12)
    xi = 1.0 + interference.contention * np.minimum(ratio, 4.0)
    return np.where(mem_frac > 0.8,
                    xi + interference.hbm_pressure * (mem_frac - 0.8) / 0.2,
                    xi)


def best_sharing_configs(
    new: Job,
    donors: "DonorBatch | Sequence[Job]",
    interference: InterferenceModel,
    gpu_capacity_bytes: float,
) -> DonorDecisions:
    """Batched Algorithm 2: the best sharing configuration of ``new``
    against every donor in one shot. Reproduces
    :func:`repro.core.batch_scaling.best_sharing_config` bit-for-bit per
    donor (including the first-feasible shortcut the scalar sweep takes
    when xi is sub-batch independent)."""
    if not isinstance(donors, DonorBatch):
        donors = DonorBatch(donors)
    bs, ss, t_new_tab, mem_tab = job_candidate_table(new)
    d = len(donors)
    if d == 0:
        empty_f = np.zeros(0, dtype=np.float64)
        empty_b = np.zeros(0, dtype=bool)
        empty_i = np.zeros(0, dtype=np.int64)
        return DonorDecisions(donors.donors, new.batch, empty_b, empty_b,
                              empty_i, empty_i, empty_f, empty_f, empty_f,
                              empty_f, empty_f, empty_f.copy(),
                              empty_f.copy())

    run_mem = donors.run_mem
    t_run = donors.t_run
    rem_run = donors.rem_run
    iters_new = new.iters
    feasible = (mem_tab[None, :] + run_mem[:, None]) <= gpu_capacity_bytes
    any_feasible = feasible.any(axis=1)
    first_idx = np.argmax(feasible, axis=1)

    (fixed_mask, xi_run_fixed, xi_new_fixed,
     hit_run, hit_new) = donors.xi_terms(new.model, interference)

    if fixed_mask.all():
        # Every donor's xi is sub-batch independent: the scalar sweep
        # stops at the first feasible (largest) sub-batch, so only that
        # lane needs evaluating — O(D) instead of O(D x candidates).
        sel = first_idx
        xi_run_sel = xi_run_fixed
        xi_new_sel = xi_new_fixed
        share, avg, t_a0, t_b0, t_a1, t_b1 = _theorem1(
            t_run, rem_run, xi_run_sel, t_new_tab[sel], iters_new,
            xi_new_sel)
    else:
        # (donor x candidate) grid: structural xi depends on the
        # candidate's iteration time and the pair's memory pressure.
        t_new_g = t_new_tab[None, :]
        mem_frac = (run_mem[:, None] + mem_tab[None, :]) / gpu_capacity_bytes
        xi_run_g = _structural_xi(interference, t_run[:, None], t_new_g,
                                  mem_frac)
        xi_new_g = _structural_xi(interference, t_new_g, t_run[:, None],
                                  mem_frac)
        run_const = fixed_mask | ~np.isnan(hit_run)
        new_const = fixed_mask | ~np.isnan(hit_new)
        run_val = np.where(fixed_mask, xi_run_fixed, hit_run)
        new_val = np.where(fixed_mask, xi_new_fixed, hit_new)
        xi_run_g = np.where(run_const[:, None], run_val[:, None], xi_run_g)
        xi_new_g = np.where(new_const[:, None], new_val[:, None], xi_new_g)
        share_g, avg_g, t_a0_g, t_b0_g, _, _ = _theorem1(
            t_run[:, None], rem_run[:, None], xi_run_g, t_new_g,
            iters_new, xi_new_g)
        avg_masked = np.where(feasible, avg_g, np.inf)
        # first-occurrence argmin == the scalar sweep's strict-< update
        # (largest feasible sub-batch wins ties); fixed-xi donors keep
        # the scalar path's first-feasible break.
        sel = np.where(fixed_mask, first_idx, np.argmin(avg_masked, axis=1))
        rows = np.arange(d)
        share = share_g[rows, sel]
        avg = avg_g[rows, sel]
        t_a0 = t_a0_g[rows, sel]
        t_b0 = t_b0_g[rows, sel]
        t_a1 = t_run * rem_run          # candidate-independent endpoints
        t_b1 = t_a1 + t_new_tab[sel] * iters_new
        xi_run_sel = xi_run_g[rows, sel]
        xi_new_sel = xi_new_g[rows, sel]

    # quench rows with no feasible candidate to the scalar sentinel
    share = share & any_feasible
    avg = np.where(any_feasible, avg, np.inf)
    return DonorDecisions(
        donors=donors.donors, new_batch=new.batch, feasible=any_feasible,
        share=share, sub_batch=bs[sel], accum_steps=ss[sel], avg_jct=avg,
        t_a0=t_a0, t_b0=t_b0, t_a1=t_a1, t_b1=t_b1,
        xi_run=xi_run_sel, xi_new=xi_new_sel)


def best_sharing_config_batched(
    running: Job,
    new: Job,
    interference: InterferenceModel,
    gpu_capacity_bytes: float,
) -> SharingConfig:
    """Single-donor convenience wrapper with the scalar API's signature
    and return type (used by the equivalence tests)."""
    res = best_sharing_configs(new, [running], interference,
                               gpu_capacity_bytes)
    return res.config(0)
