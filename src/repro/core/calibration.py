"""Closed-loop calibration pipeline (DESIGN.md §13).

The simulator's performance model has two measured inputs the paper
obtains on its 2080 Ti testbed: the Eq.-3 compute coefficients
(t_comp(b) = alpha + beta*b, fitted from a sub-batch throughput sweep)
and the pairwise interference ratios xi (Eqs. 5-6, measured by really
co-locating job pairs). This module produces both on THIS host by
driving the schedule executor (:mod:`repro.launch.cluster`) over real
reduced-architecture training jobs, and persists them as a **versioned
artifact** (``artifacts/bench/calibration.json``) that the simulator
side loads back:

* ``InterferenceModel.from_artifact`` fills the xi pair table;
* :func:`perf_params_from_artifact` rebuilds Eq.-3/4/7 ``PerfParams``
  from the fitted alpha/beta (single-host jobs: the comm term is inside
  the measured step, so t_comm = 0);
* :class:`MeasuredTaskProfile` duck-types ``repro.core.tasks.
  TaskProfile`` so the trace builders (``repro.core.trace``) generate
  workloads over measured profiles instead of the synthesized tables.

Artifact schema (version 1)::

    {"version": 1, "host": {...}, "iters": n,
     "archs": {name: {"arch", "batch", "seq",
                      "sweep": {"sub_batches": [...], "times": [...]},
                      "alpha_comp", "beta_comp", "t_iter_solo",
                      "param_bytes", "mem_base", "mem_per_sample"}},
     "pairs": {"a+b": {"a", "b", "t_a_solo", "t_b_solo", "t_pair",
                       "xi_a", "xi_b",
                       "xi_a_structural", "xi_b_structural"}}}

Module-level imports stay jax-free: the artifact/fit/profile side is
usable by the (numpy-less, jax-less) simulator core, while the
measurement entry point imports the executor lazily.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..util.errors import ArtifactVersionError
from .perf_model import GPU_2080TI, HardwareSpec, PerfParams, fit_comp_params

CALIBRATION_VERSION = 1


# ---------------------------------------------------------------------- #
# Artifact I/O
# ---------------------------------------------------------------------- #
def save_artifact(payload: Dict, path: str) -> str:
    if payload.get("version") != CALIBRATION_VERSION:
        raise ValueError(f"refusing to save artifact with version "
                         f"{payload.get('version')!r}")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_artifact(path: str) -> Dict:
    with open(path) as f:
        payload = json.load(f)
    version = payload.get("version")
    if version != CALIBRATION_VERSION:
        raise ArtifactVersionError(path, version, CALIBRATION_VERSION,
                                   kind="calibration artifact",
                                   detail="re-run benchmarks/calibrate.py "
                                          "to regenerate")
    return payload


# ---------------------------------------------------------------------- #
# Simulator-side consumers
# ---------------------------------------------------------------------- #
def perf_params_from_artifact(entry: Dict, *, delta: float = 2.0
                              ) -> PerfParams:
    """Eq.-3/4/7 coefficients from one measured arch entry. Single-host
    measurements fold any collective cost into the fitted alpha/beta, so
    the explicit comm term is zero."""
    return PerfParams(
        alpha_comp=float(entry["alpha_comp"]),
        beta_comp=float(entry["beta_comp"]),
        alpha_comm=0.0,
        beta_comm=0.0,
        msg_bytes=0.0,
        delta=delta,
        mem_base=float(entry["mem_base"]),
        mem_per_sample=float(entry["mem_per_sample"]),
        param_bytes=float(entry["param_bytes"]),
        n_workers=1,
    )


@dataclass(frozen=True)
class MeasuredTaskProfile:
    """Duck-types :class:`repro.core.tasks.TaskProfile` for the trace
    builders, but returns the HOST-measured PerfParams whatever the GPU
    count / hardware spec asked for — the measurement already is the
    physical truth for this host's jobs."""

    name: str
    default_batch: int
    params: PerfParams

    def perf_params(self, n_gpus: int,
                    hw: HardwareSpec = GPU_2080TI) -> PerfParams:
        return self.params


def profiles_from_artifact(payload: Dict) -> Dict[str, MeasuredTaskProfile]:
    return {
        name: MeasuredTaskProfile(
            name=name,
            default_batch=int(entry["batch"]),
            params=perf_params_from_artifact(entry))
        for name, entry in payload["archs"].items()
    }


# ---------------------------------------------------------------------- #
# Measurement pipeline (imports the executor lazily — jax territory)
# ---------------------------------------------------------------------- #
def _sweep_points(batch: int, sub_batches: Optional[Sequence[int]]
                  ) -> List[int]:
    if sub_batches is not None:
        pts = sorted({int(b) for b in sub_batches if 1 <= b <= batch},
                     reverse=True)
    else:
        from .batch_scaling import candidate_sub_batches
        pts = candidate_sub_batches(batch)
    if len(pts) < 2:
        raise ValueError(
            f"need >= 2 sub-batch sweep points for the Eq.-3 fit; "
            f"batch={batch} gives {pts}")
    return pts


def run_calibration(
    specs: Dict[str, "JobSpec"],
    *,
    iters: int = 3,
    sub_batches: Optional[Sequence[int]] = None,
    pairs: Optional[Sequence[Tuple[str, str]]] = None,
) -> Dict:
    """Measure everything the simulator needs, on this host.

    Per arch: a sub-batch sweep (each point really trains the model at
    per-step batch b, accum=1, timing post-warmup fused steps via the
    executor) fitted to t_comp(b) = alpha + beta*b; the solo iteration
    time at the spec's own (batch, accum); and analytic memory
    coefficients (param/optimizer bytes from the real parameter count,
    activation bytes per sample from the config dims). Per pair (default
    all unordered pairs incl. self-pairings, or an explicit list): the
    fused pair program's step time and the xi ratios. Each model is
    initialized ONCE; measurements consume cheap copies of the pristine
    master state (donation invalidates buffers)."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.data import make_batch
    from repro.launch.cluster import JobSpec, _make_state  # noqa: F401
    from repro.models import param_count

    from .coschedule import measure_pair, measure_solo, structural_xi

    def copy_state(state, batch=None):
        params, opt, master_batch = state
        clone = jax.tree.map(jnp.array, (params, opt))
        return clone[0], clone[1], master_batch if batch is None else batch

    names = sorted(specs)
    masters = {n: _make_state(specs[n]) for n in names}
    archs: Dict[str, Dict] = {}
    solo: Dict[str, float] = {}

    for name in names:
        spec = specs[name]
        cfg = spec.cfg
        pts = _sweep_points(spec.batch, sub_batches)
        times = []
        for b in pts:
            # per-micro-step time at sub-batch b: one step at batch=b,
            # no accumulation (Eq. 3 is about the micro-step); params/opt
            # are copies of the master state (their shapes are
            # batch-independent), only the data tensor is rebuilt at b
            sub_spec = _dc.replace(spec, batch=b, accum_steps=1)
            state = copy_state(masters[name],
                               batch=make_batch(cfg, b, spec.seq,
                                                seed=spec.seed))
            times.append(measure_solo(sub_spec, iters, state=state))
        alpha, beta = fit_comp_params([float(b) for b in pts], times)
        if spec.accum_steps == 1 and pts[0] == spec.batch:
            # the sweep's first point IS the spec's own configuration
            solo[name] = times[0]
        else:
            solo[name] = measure_solo(spec, iters,
                                      state=copy_state(masters[name]))
        n_params = param_count(masters[name][0])
        param_bytes = 4.0 * n_params
        # params + grads + AdamW moments, plus a small framework floor
        mem_base = 4.0 * param_bytes + 64 * 2 ** 20
        act_per_sample = 4.0 * spec.seq * cfg.d_model * (cfg.n_layers + 2)
        archs[name] = {
            "arch": cfg.name,
            "batch": spec.batch,
            "seq": spec.seq,
            "accum_steps": spec.accum_steps,
            "sweep": {"sub_batches": pts, "times": times},
            "alpha_comp": alpha,
            "beta_comp": beta,
            "t_iter_solo": solo[name],
            "n_params": int(n_params),
            "param_bytes": param_bytes,
            "mem_base": mem_base,
            "mem_per_sample": act_per_sample,
        }

    if pairs is None:
        pairs = [(a, b) for i, a in enumerate(names) for b in names[i:]]
    pair_entries: Dict[str, Dict] = {}
    for a, b in pairs:
        r = measure_pair(specs[a], specs[b], iters=iters,
                         t_a_solo=solo[a], t_b_solo=solo[b],
                         state_a=copy_state(masters[a]),
                         state_b=copy_state(masters[b]))
        pair_entries[f"{a}+{b}"] = {
            "a": a, "b": b, **r,
            "xi_a_structural": structural_xi(r["t_a_solo"], r["t_b_solo"]),
            "xi_b_structural": structural_xi(r["t_b_solo"], r["t_a_solo"]),
        }

    return {
        "version": CALIBRATION_VERSION,
        "created": time.time(),
        "host": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "iters": iters,
        "archs": archs,
        "pairs": pair_entries,
    }
