"""Step-interleaved co-scheduled execution — the TPU analogue of the
paper's GPU sharing (DESIGN.md §4).

A TPU core runs one program at a time (no MPS/time-slicing), so "two jobs
share a slice" becomes ONE jitted SPMD program that advances both jobs'
training states each call: job A runs its step, then job B runs its
(possibly gradient-accumulated, sub-batched) step. The interference ratio
of Eqs. 5-6 is then *structural*:

    xi_A = t_pair / t_A_solo      (and symmetrically for B)

with t_pair >= t_A + t_B for pure time multiplexing; the measured ratios
feed the scheduler's ``InterferenceModel`` exactly as the paper feeds
measured 2080 Ti ratios into its simulator.

This module is also the "physical testbed": `measure_pair` really trains
two models on this host and times the fused program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import make_batch
from repro.models import init_params
from repro.train import (TrainConfig, adamw_init, make_jit_train_step,
                         make_train_step)

from .interference import InterferenceModel


@dataclass
class JobSpec:
    cfg: ArchConfig
    batch: int                  # per-step user batch
    accum_steps: int = 1        # gradient-accumulation sub-steps
    seq: int = 128
    seed: int = 0

    def train_config(self) -> TrainConfig:
        return TrainConfig(accum_steps=self.accum_steps)


def _make_state(spec: JobSpec):
    params = init_params(spec.cfg, jax.random.PRNGKey(spec.seed))
    opt = adamw_init(params)
    batch = make_batch(spec.cfg, spec.batch, spec.seq, seed=spec.seed)
    return params, opt, batch


def make_pair_step(spec_a: JobSpec, spec_b: JobSpec, *, donate: bool = False):
    """One jitted program stepping BOTH jobs (time-multiplexed).

    ``donate=True`` donates both jobs' params/opt-states (in-place
    accumulation + AdamW update, the production configuration); callers
    must then re-bind all four from the outputs each call."""
    step_a = make_train_step(spec_a.cfg, spec_a.train_config())
    step_b = make_train_step(spec_b.cfg, spec_b.train_config())

    def pair_step(pa, oa, ba, pb, ob, bb):
        pa, oa, ma = step_a(pa, oa, ba)
        pb, ob, mb = step_b(pb, ob, bb)
        return pa, oa, ma, pb, ob, mb

    return jax.jit(pair_step, donate_argnums=(0, 1, 3, 4) if donate else ())


def measure_solo(spec: JobSpec, iters: int = 3) -> float:
    """Mean seconds per solo training step (donated train step; state is
    threaded through the timing loop because donation invalidates the
    input buffers)."""
    params, opt, batch = _make_state(spec)
    step = make_jit_train_step(spec.cfg, spec.train_config())
    params, opt, _ = step(params, opt, batch)        # compile + warmup
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(iters):
        params, opt, _ = step(params, opt, batch)
    jax.block_until_ready(params)
    return (time.perf_counter() - t0) / iters


def measure_pair(spec_a: JobSpec, spec_b: JobSpec, iters: int = 3, *,
                 t_a_solo: Optional[float] = None,
                 t_b_solo: Optional[float] = None) -> Dict[str, float]:
    """Times the interleaved pair program and returns per-step solo/pair
    walltimes and the structural interference ratios xi_A, xi_B.

    ``t_a_solo`` / ``t_b_solo`` accept precomputed solo timings (see
    ``calibrate_interference``'s O(n) solo pass); when omitted they are
    measured here."""
    t_a = measure_solo(spec_a, iters) if t_a_solo is None else t_a_solo
    t_b = measure_solo(spec_b, iters) if t_b_solo is None else t_b_solo
    pa, oa, ba = _make_state(spec_a)
    pb, ob, bb = _make_state(spec_b)
    pair = make_pair_step(spec_a, spec_b, donate=True)
    pa, oa, _, pb, ob, _ = pair(pa, oa, ba, pb, ob, bb)   # compile + warmup
    jax.block_until_ready((pa, pb))
    t0 = time.perf_counter()
    for _ in range(iters):
        pa, oa, _, pb, ob, _ = pair(pa, oa, ba, pb, ob, bb)
    jax.block_until_ready((pa, pb))
    t_pair = (time.perf_counter() - t0) / iters
    return {
        "t_a_solo": t_a,
        "t_b_solo": t_b,
        "t_pair": t_pair,
        "xi_a": t_pair / t_a,
        "xi_b": t_pair / t_b,
        "iters": iters,
    }


def structural_xi(t_me: float, t_other: float, *, overlap: float = 0.0,
                  mem_frac: float = 0.0, hbm_pressure: float = 0.15
                  ) -> float:
    """Analytic structural model (no execution): strict time multiplexing
    gives xi_me = (t_me + t_other) / t_me; ``overlap`` in [0,1) credits
    pipelined overlap between the two programs' compute and collectives;
    an HBM-pressure term penalizes near-capacity working sets."""
    xi = (t_me + (1.0 - overlap) * t_other) / t_me
    if mem_frac > 0.8:
        xi += hbm_pressure * (mem_frac - 0.8) / 0.2
    return xi


def calibrate_interference(specs: Dict[str, JobSpec], iters: int = 2,
                           ) -> InterferenceModel:
    """Fill an InterferenceModel table from real pairwise measurements on
    this host (the 'physical' calibration pass of Section VI-A).

    Solo timings are measured ONCE per spec in an O(n) pass and reused
    for every pair — each solo measurement compiles and trains a real
    model, so re-running it for both members of all O(n²) pairs dominated
    calibration walltime."""
    model = InterferenceModel()
    names = sorted(specs)
    solo = {name: measure_solo(specs[name], iters) for name in names}
    for i, a in enumerate(names):
        for b in names[i:]:
            r = measure_pair(specs[a], specs[b], iters=iters,
                             t_a_solo=solo[a], t_b_solo=solo[b])
            model.set_pair(a, b, r["xi_a"], r["xi_b"])
    return model
