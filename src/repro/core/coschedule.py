"""Pair-shaped facade over the schedule-driven executor (DESIGN.md §4, §13).

A TPU core runs one program at a time (no MPS/time-slicing), so "jobs
share a slice" becomes ONE jitted SPMD program that advances every
tenant's training state each call. The N-way fused program, the schedule
timeline, and the mid-run (τ, sub-batch) reconfiguration live in
:mod:`repro.launch.cluster` (:class:`~repro.launch.cluster.
ScheduleExecutor`); this module keeps the historical 2-job measurement
API on top of it:

    xi_A = t_pair / t_A_solo      (and symmetrically for B)

with t_pair >= t_A + t_B for pure time multiplexing; the measured ratios
feed the scheduler's ``InterferenceModel`` exactly as the paper feeds
measured 2080 Ti ratios into its simulator. The full closed loop —
fitting Eq.-3 alpha/beta from a measured sub-batch sweep, persisting the
versioned ``calibration.json`` artifact, and loading it back into the
simulator — is :mod:`repro.core.calibration`.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.launch.cluster import (JobSpec, ScheduleExecutor, _make_state,
                                  make_group_step)

from .interference import InterferenceModel
from .interference import structural_xi as _structural_xi

__all__ = ["JobSpec", "calibrate_interference", "make_pair_step",
           "measure_group", "measure_pair", "measure_solo", "structural_xi"]


def make_pair_step(spec_a: JobSpec, spec_b: JobSpec, *, donate: bool = False):
    """One jitted program stepping BOTH jobs (time-multiplexed) — the
    2-job case of :func:`repro.launch.cluster.make_group_step`, kept for
    the historical flat signature:

        (pa, oa, ba, pb, ob, bb) -> (pa, oa, ma, pb, ob, mb)
    """
    return make_group_step([spec_a, spec_b], donate=donate)


def _measure(specs, iters: int, states=None) -> float:
    """Mean seconds per fused step over ``iters`` post-warmup calls.
    Programs are AOT-compiled by the executor, so neither compile time
    nor the extra warmup step pollutes the mean."""
    ex = ScheduleExecutor(donate=True)
    names = []
    for i, spec in enumerate(specs):
        name = f"j{i}"
        names.append(name)
        ex.submit(name, spec, iters + 1)
        ex.start(name, state=None if states is None else states[i])
    ex.step_group(names)                       # compile + warmup
    return sum(ex.step_group(names)["walltime"]
               for _ in range(iters)) / iters


def measure_solo(spec: JobSpec, iters: int = 3, *,
                 state: Optional[tuple] = None) -> float:
    """Mean seconds per solo training step (donated fused-of-one
    program). ``state`` accepts prebuilt (params, opt, batch) — the
    buffers are consumed (donation), so callers pass copies of a
    pristine master state; when omitted the model is initialized here."""
    return _measure([spec], iters, None if state is None else [state])


def measure_pair(spec_a: JobSpec, spec_b: JobSpec, iters: int = 3, *,
                 t_a_solo: Optional[float] = None,
                 t_b_solo: Optional[float] = None,
                 state_a: Optional[tuple] = None,
                 state_b: Optional[tuple] = None) -> Dict[str, float]:
    """Times the interleaved pair program and returns per-step solo/pair
    walltimes and the structural interference ratios xi_A, xi_B.

    ``t_a_solo`` / ``t_b_solo`` accept precomputed solo timings (see
    ``calibrate_interference``'s O(n) solo pass); ``state_a``/``state_b``
    accept prebuilt states (consumed — donation) so the calibration
    pipeline initializes each model once, not once per pair."""
    t_a = measure_solo(spec_a, iters) if t_a_solo is None else t_a_solo
    t_b = measure_solo(spec_b, iters) if t_b_solo is None else t_b_solo
    t_pair = _measure([spec_a, spec_b], iters,
                      None if state_a is None and state_b is None
                      else [state_a, state_b])
    return {
        "t_a_solo": t_a,
        "t_b_solo": t_b,
        "t_pair": t_pair,
        "xi_a": t_pair / t_a,
        "xi_b": t_pair / t_b,
        "iters": iters,
    }


def measure_group(specs, iters: int = 3, states=None) -> float:
    """Mean seconds per N-way fused group step — the >2-tenant analogue
    of ``measure_pair`` for timing experiments on larger sharing groups
    (the closed-loop pipeline itself only needs solo + pair timings)."""
    return _measure(list(specs), iters, states)


def structural_xi(t_me: float, t_other: float, *, overlap: float = 0.0,
                  mem_frac: float = 0.0, hbm_pressure: float = 0.15
                  ) -> float:
    """Analytic structural model (no execution): strict time multiplexing
    gives xi_me = 1 + t_other/t_me; ``overlap`` in [0,1) credits
    pipelined overlap between the two programs. The single shared
    implementation (with the scheduler's ratio clamp parameterized away)
    is :func:`repro.core.interference.structural_xi`."""
    return _structural_xi(t_me, t_other, contention=1.0 - overlap,
                          ratio_cap=None, mem_frac=mem_frac,
                          hbm_pressure=hbm_pressure)


def calibrate_interference(specs: Dict[str, JobSpec], iters: int = 2,
                           ) -> InterferenceModel:
    """Fill an InterferenceModel table from real pairwise measurements on
    this host (the 'physical' calibration pass of Section VI-A).

    Solo timings are measured ONCE per spec in an O(n) pass and reused
    for every pair. The full pipeline — alpha/beta fits, memory
    estimates, the versioned artifact — is
    :func:`repro.core.calibration.run_calibration`; this wrapper keeps
    the historical measure-and-fill API."""
    model = InterferenceModel()
    names = sorted(specs)
    solo = {name: measure_solo(specs[name], iters) for name in names}
    for i, a in enumerate(names):
        for b in names[i:]:
            r = measure_pair(specs[a], specs[b], iters=iters,
                             t_a_solo=solo[a], t_b_solo=solo[b])
            model.set_pair(a, b, r["xi_a"], r["xi_b"])
    return model
